"""Quickstart: the paper's two APIs and the TAC library in 60 lines.

Demonstrates, on the host task runtime:
1. data-flow tasks (OmpSs-style in/out dependencies);
2. the pause/resume API — a task blocked on a communication wait releases
   its worker (TAMPI blocking mode, paper §6.1);
3. the external-events API — a task finishes immediately while its
   dependency release waits for the operation (TAMPI_Iwait, paper §6.2);
4. the §5 deadlock that TASK_MULTIPLE resolves.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import TaskRuntime, tac

tac.init(tac.TASK_MULTIPLE)


def main():
    world = tac.CommWorld(2)

    # -- 1+2: blocking mode ------------------------------------------------
    with TaskRuntime(num_workers=1) as rt:   # ONE worker on purpose
        def receiver():
            # Task-aware blocking wait: pauses this task, frees the worker.
            msg = world.recv(src=0, dst=1, tag="hello")
            print(f"  receiver got: {msg!r}")

        def sender():
            world.send("hello from task-land", src=0, dst=1, tag="hello")

        rt.submit(receiver)   # submitted FIRST: would deadlock a plain
        rt.submit(sender)     # blocking runtime (§5) — pause/resume saves it
        rt.taskwait()
        print(f"  pause/resume round-trips: {rt.stats['task_blocks']}")

    # -- 3: non-blocking mode (external events) -----------------------------
    with TaskRuntime(num_workers=2) as rt:
        done_order = []

        def comm_task():
            h = world.irecv(src=0, dst=1, tag="evt")
            tac.iwait(h)                       # bind, do NOT wait
            done_order.append("comm body done")

        def consumer():
            done_order.append("consumer ran")

        rt.submit(comm_task, out=["buf"])
        rt.submit(consumer, in_=["buf"])       # gated by the event
        time.sleep(0.2)
        assert done_order == ["comm body done"], done_order
        print("  comm task finished; consumer correctly still waiting...")
        world.isend("payload", src=0, dst=1, tag="evt")  # fulfil the event
        rt.taskwait()
        assert done_order == ["comm body done", "consumer ran"]
        print("  event fulfilled -> dependency released -> consumer ran")
        print(f"  pauses in non-blocking mode: "
              f"{rt.stats.get('task_blocks', 0)} (zero by design)")


if __name__ == "__main__":
    main()
    print("quickstart OK")
