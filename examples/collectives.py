"""Task-aware collectives walkthrough (core/collectives.py).

Shows the subsystem end to end:

1. the seven collectives running correctly over logical ranks (group
   driver — no runtime needed);
2. a blocking-mode allreduce inside tasks: each round's wait pauses the
   task (paper §6.1) so two workers can serve five ranks;
3. an event-bound allreduce: the communication tasks finish immediately,
   their dependency release waits on the collective (paper §6.2 — zero
   pauses), and consumers read ``handle.result``;
4. the deterministic simulator comparing the sentinel-serialized,
   blocking, and event-bound collective schedules on one task graph.

Run:  PYTHONPATH=src python examples/collectives.py
"""

import numpy as np

from repro.core import Collectives, TaskRuntime, tac
from repro.core.collectives import n_rounds
from repro.core.simulate import (Simulator, SimTask, COMM_EVENTS, COMM_HELD,
                                 COMM_PAUSED)


def demo_group_driver():
    print("1. the seven collectives on 5 logical ranks (both algorithms):")
    world = tac.CommWorld(5)
    coll = Collectives(world)
    vals = [np.arange(4.0) + r for r in range(5)]
    for alg in ("ring", "doubling"):
        s = coll.run_group("allreduce", [{"value": v} for v in vals],
                           op="sum", algorithm=alg)
        g = coll.run_group("allgather", [{"value": r} for r in range(5)],
                           algorithm=alg)
        coll.run_group("barrier", [{} for _ in range(5)], algorithm=alg)
        print(f"   {alg:9s} allreduce(sum)={s[0]}  allgather={g[0]}")


def demo_blocking_mode():
    print("\n2. blocking mode: 5 ranks, 2 workers — waits pause the task:")
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(5)
    coll = Collectives(world)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                        algorithm="doubling",
                                        mode="blocking", key="demo")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(5):
            rt.submit(make(r))
        rt.taskwait()
    assert all(float(v) == 10.0 for v in results.values())
    print(f"   sum(0..4) = {float(results[0])}   "
          f"pauses={rt.stats.get('task_blocks', 0)} "
          f"resumes={rt.stats.get('task_resumes', 0)}")


def demo_event_mode():
    print("\n3. event-bound mode: zero pauses, release gated on completion:")
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(4)
    coll = Collectives(world)
    handles, got = {}, {}

    def comm(r):
        def body():
            handles[r] = coll.allreduce(np.float64(r + 1), rank=r, op="max",
                                        algorithm="ring", mode="event",
                                        key="demo")
        return body

    def consume(r):
        def body():
            got[r] = float(handles[r].result)
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(4):
            rt.submit(comm(r), out=[("res", r)])
            rt.submit(consume(r), in_=[("res", r)])
        rt.taskwait()
    assert all(v == 4.0 for v in got.values())
    print(f"   max(1..4) = {got[0]}   pauses="
          f"{rt.stats.get('task_blocks', 0)} (event-bound: none)")


def demo_simulator():
    print("\n4. simulated schedules: rank 0 enters the collective early and")
    print("   has other work queued behind it on its single worker:")
    lat = n_rounds("allreduce", "doubling", 4) * 0.1

    def graph(kind):
        tasks = []
        for r in range(4):
            tasks.append(SimTask(2 * r, r, 1.0 + r, name=f"compute[{r}]"))
            tasks.append(SimTask(2 * r + 1, r, 0.05, kind=kind,
                                 start_deps=[(2 * r, 0.0)], group="ar",
                                 group_latency=lat, name=f"coll[{r}]"))
        tasks.append(SimTask(8, 0, 1.0, start_deps=[(0, 0.0)],
                             name="other[0]"))
        return tasks

    for label, kind in (("sentinel (held)", COMM_HELD),
                        ("blocking (paused)", COMM_PAUSED),
                        ("event-bound", COMM_EVENTS)):
        res = Simulator(4, 1, resume_overhead=0.01).run(graph(kind))
        print(f"   {label:18s} makespan={res.makespan:5.2f}  "
              f"resumes={res.resumes}  held-wait="
              f"{sum(res.held_wait_time.values()):.2f}")


if __name__ == "__main__":
    demo_group_driver()
    demo_blocking_mode()
    demo_event_mode()
    demo_simulator()
