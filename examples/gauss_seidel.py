"""Gauss–Seidel demo (paper §7.1): run all five program versions and show
that removing artificial communication dependencies — the paper's
contribution — is what unlocks the wavefront parallelism.

Prints per-version wall time, runtime statistics (pauses, spawned threads)
and the simulated 16-rank speedups.

Run:  PYTHONPATH=src python examples/gauss_seidel.py
"""

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.gauss_seidel import (grid_dims, run_real, simulate_version,
                                     VERSIONS)


def main():
    print("real execution (2x2 Cartesian rank grid x 2 workers, "
          "halo exchange per iteration):")
    ref, _ = run_real("pure")
    for v in VERSIONS:
        t0 = time.monotonic()
        out, stats = run_real(v)
        dt = time.monotonic() - t0
        err = float(np.abs(out - ref).max())
        assert err < 1e-10, (v, err)
        print(f"  {v:16s} {dt * 1e3:7.1f} ms   pauses="
              f"{stats.get('task_blocks', 0):<3d} "
              f"spawned_threads={stats.get('threads_spawned', 0):<3d} "
              f"(identical numerics: max|Δ|={err:.1e})")

    print("\nsimulated speedup vs Pure-MPI@1rank "
          "(48 workers/rank, paper Fig. 9 analogue):")
    base = simulate_version("pure", n_ranks=1, nby=8, nbx=8)
    for v in VERSIONS:
        sp = []
        for n in (1, 4, 16):
            py, px = grid_dims(n)
            sp.append(base / simulate_version(v, n_ranks=n, nby=8 // py,
                                              nbx=8 // px))
        print(f"  {v:16s} r1={sp[0]:5.2f}  r4={sp[1]:5.2f} r16={sp[2]:5.2f}")
    print("\nThe Interop versions scale because communication tasks carry "
          "no artificial dependencies\n(blocking mode pauses tasks; "
          "non-blocking mode defers dependency release — paper §6).")


if __name__ == "__main__":
    main()
