"""Neighbourhood collectives walkthrough (core/tac.py sub-communicators +
core/collectives.py HaloExchange).

Shows the subsystem end to end:

1. a 2-D Cartesian sub-communicator: coordinates, shifts, and the
   persistent neighbour lists a stencil code needs;
2. one halo-exchange round driven sequentially (group driver — no
   runtime needed): every rank receives exactly its neighbours' edges;
3. the overlap pattern (paper §6.2 applied to neighbourhoods): comm
   tasks bind the exchange to their event counter and finish
   immediately — interior compute runs while the halos fly, boundary
   compute declares a dependency and reads ``handle.result``;
4. hierarchical allreduce over two nested groups built by
   ``CommWorld.split`` (intra-group chain + inter-leader doubling);
5. the deterministic simulator comparing the sentinel-serialized,
   blocking, and event-bound halo schedules on one task graph.

Run:  PYTHONPATH=src python examples/halo_exchange.py
"""

import numpy as np

from repro.core import (HaloExchange, HierarchicalCollectives, TaskRuntime,
                        tac)
from repro.core.simulate import (Simulator, SimTask, COMM_EVENTS, COMM_HELD,
                                 COMM_PAUSED)


def demo_cartesian_topology():
    print("1. a 2x3 Cartesian group over 6 logical ranks:")
    world = tac.CommWorld(6)
    cart = world.cart_create((2, 3))
    for r in range(cart.size):
        print(f"   rank {r} at {cart.coords(r)}  "
              f"neighbours {cart.neighbor_dirs(r)}")
    src, dst = cart.shift(1, 0, 1)
    print(f"   shift(rank 1, dim 0, +1): receive from {src}, send to {dst}")
    return cart


def demo_group_driver(cart):
    print("\n2. one halo round, sequential driver (no runtime):")
    hx = HaloExchange(cart)
    # each rank's "edge" is just a labelled array here
    sends = [{d: np.full(2, 10 * r + d[0]) for d, _ in hx.neighbors(r)}
             for r in range(cart.size)]
    got = hx.run_group(sends)
    r = 4  # centre-ish rank of the 2x3 grid
    for d, nbr in cart.neighbor_dirs(r):
        print(f"   rank {r} received from direction {d} "
              f"(neighbour {nbr}): {got[r][d]}")


def demo_event_overlap():
    print("\n3. event mode: halos overlap interior compute "
          "(2x2 grid, 2 workers):")
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(4)
    cart = world.cart_create((2, 2))
    hx = HaloExchange(cart)
    handles, order, boundary = {}, [], {}

    def comm(r):
        def body():
            sends = {d: np.float64(r) for d, _ in hx.neighbors(r)}
            handles[r] = hx.start(sends, rank=r, mode="event", key="it0")
            order.append(f"halo[{r}] posted")
        return body

    def interior(r):
        def body():
            order.append(f"interior[{r}] done")
        return body

    def boundary_task(r):
        def body():
            boundary[r] = {d: float(v)
                           for d, v in handles[r].result.items()}
            order.append(f"boundary[{r}] done")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(4):
            rt.submit(comm(r), out=[("halo", r)], name=f"halo[{r}]")
            rt.submit(interior(r), name=f"interior[{r}]")
            rt.submit(boundary_task(r), in_=[("halo", r)],
                      name=f"boundary[{r}]")
        rt.taskwait()
    print(f"   pauses={rt.stats.get('task_blocks', 0)} (event-bound: none)")
    print(f"   rank 0 halos: {boundary[0]}")
    assert rt.stats.get("task_blocks", 0) == 0
    assert all(boundary[r][d] == float(nbr)
               for r in range(4) for d, nbr in cart.neighbor_dirs(r))


def demo_hierarchical():
    print("\n4. hierarchical allreduce on 6 ranks (groups of 3 via split):")
    world = tac.CommWorld(6)
    hier = HierarchicalCollectives(world, 3)
    print(f"   intra groups: {sorted({g.ranks for g in hier.intra})}  "
          f"leaders: {hier.leaders.ranks}")
    out = hier.run_group([np.float64(r) for r in range(6)], op="sum")
    print(f"   sum(0..5) = {float(out[0])}   "
          f"critical-path rounds = {hier.n_rounds()}")


def demo_simulator():
    print("\n5. simulated halo round: rank 1 arrives late, rank 0 has")
    print("   independent work queued behind its halo task (1 worker):")
    world = tac.CommWorld(2)
    cart = world.cart_create((2, 1))

    def graph(kind):
        tasks = [SimTask(0, 0, 1.0, name="w0"),
                 SimTask(1, 1, 3.0, name="w1"),
                 SimTask(2, 0, 0.1, kind=kind, start_deps=[(0, 0.0)],
                         name="h0"),
                 SimTask(3, 1, 0.1, kind=kind, start_deps=[(1, 0.0)],
                         name="h1"),
                 SimTask(4, 0, 1.0, start_deps=[(0, 0.0)], name="other")]
        tasks[2].neighbors = [(3, 0.2)]
        tasks[3].neighbors = [(2, 0.2)]
        return tasks

    for label, kind in (("sentinel (held)", COMM_HELD),
                        ("blocking (paused)", COMM_PAUSED),
                        ("event-bound", COMM_EVENTS)):
        res = Simulator(2, 1, resume_overhead=0.01).run(graph(kind))
        print(f"   {label:18s} makespan={res.makespan:5.2f}  "
              f"resumes={res.resumes}  held-wait="
              f"{sum(res.held_wait_time.values()):.2f}")


if __name__ == "__main__":
    cart = demo_cartesian_topology()
    demo_group_driver(cart)
    demo_event_overlap()
    demo_hierarchical()
    demo_simulator()
