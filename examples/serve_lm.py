"""Serving example: batched greedy decoding with prefill→decode cache
handoff on a hybrid (Mamba2 + shared attention) architecture — the cache
carries both SSM states and KV tensors.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-2.7b]
"""

import argparse

from repro.launch import serve


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="zamba2-2.7b")
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args()
    rc = serve.main(["--arch", args.arch, "--scale", "smoke",
                     "--batch", "2", "--prompt-len", "32",
                     "--gen", str(args.gen)])
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
