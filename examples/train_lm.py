"""End-to-end training driver example: train a ~10M-param granite-family
model for a few hundred steps on the synthetic token stream and verify the
loss drops substantially (the stream has learnable structure: every even
position repeats the previous token).

Exercises the full substrate: sharded train step, async prefetch (host
task runtime), async checkpointing (external events), restart determinism.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.launch import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--arch", default="granite-3-2b")
    args = p.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro-train-")
    rc = train.main([
        "--arch", args.arch, "--scale", "smoke",
        "--steps", str(args.steps), "--batch", "16", "--seq", "128",
        "--lr", "3e-3", "--warmup", "30",
        "--ckpt-dir", ckpt, "--ckpt-every", "100",
        "--log-every", "25",
    ])
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
