"""IFSKer benchmark — paper §7.2 (Fig. 14).

Mock-up of the IFS spectral-transform weather code: timestep cycles of
grid-point physics and Fourier transforms with a data transposition
(all-to-all) between phases.  Grid space distributes *points* across ranks;
spectral space distributes *fields*; the transitions redistribute.

Versions (as in the paper — Fork-Join/Sentinel are equivalent to Pure here
because there is one rank per core):

* ``pure``           — sequential phases with a full exchange between them.
* ``interop-blk``    — the transposition is a per-rank ``alltoall`` from
                       the task-aware collectives API in *blocking* mode
                       (pause/resume per round): the exchange overlaps
                       physics/FFTs of other ranks' tasks.
* ``interop-nonblk`` — the same ``alltoall`` in *event-bound* mode: the
                       exchange task finishes immediately, its dependency
                       release waits on the collective — the paper's
                       preferred mode for many small messages.

The data transposition (grid space ↔ spectral space) is exactly MPI's
all-to-all, so this benchmark is the collectives subsystem's end-to-end
exercise (core/collectives.py); the ``pure`` version drives the same
schedule sequentially through ``Collectives.run_group``.

Real executions validate numerics across versions; the simulator replays
the task DAGs for the scaling curve.  CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import Collectives, TaskRuntime, tac
from repro.core import schedule as schedule_ir
from repro.core.simulate import Simulator, SimTask, COMPUTE, COMM_PAUSED, \
    COMM_EVENTS, COMM_HELD

VERSIONS = ("pure", "interop-blk", "interop-nonblk")


def physics(x: np.ndarray) -> np.ndarray:
    return x + 0.1 * np.tanh(x) - 0.01 * x ** 3


def spectral_step(f: np.ndarray) -> np.ndarray:
    F = np.fft.rfft(f)
    F *= np.exp(-0.01 * np.arange(F.size))   # diffusion in spectral space
    return np.fft.irfft(F, n=f.size)


def run_real(version: str, *, n_ranks: int = 2, workers: int = 2,
             n_fields: int = 8, n_grid: int = 64, steps: int = 3,
             seed: int = 0, notify: str = None):
    """Returns (final fields array, runtime stats).

    ``notify`` picks the runtime's completion-notification backend
    ("polling" / "continuation"; None = the REPRO_NOTIFY env default).
    """
    assert n_fields % n_ranks == 0 and n_grid % n_ranks == 0
    rng = np.random.default_rng(seed)
    pts = n_grid // n_ranks
    # grid space: fields[f] split by points across ranks
    grid: Dict = {(f, r): rng.standard_normal(pts)
                  for f in range(n_fields) for r in range(n_ranks)}
    spec: Dict = {}
    world = tac.CommWorld(n_ranks)
    coll = Collectives(world)
    exch: Dict = {}   # alltoall results (or event-bound handles)
    tac.init(tac.TASK_MULTIPLE if version.startswith("interop")
             else tac.THREAD_MULTIPLE)
    rt = TaskRuntime(num_workers=workers, notify=notify)
    rt.start()

    def owner(f: int) -> int:
        return f % n_ranks

    fields_of = {o: [f for f in range(n_fields) if owner(f) == o]
                 for o in range(n_ranks)}
    a2a_mode = "event" if version == "interop-nonblk" else "blocking"

    def phys_task(f, r, it):
        grid[(f, r)] = physics(grid[(f, r)])

    def pack_g2s(r):
        """Block for owner o = my point-slices of o's fields, field order."""
        return [np.concatenate([grid[(f, r)] for f in fields_of[o]])
                for o in range(n_ranks)]

    def pack_s2g(o):
        """Block for rank r = r's point-slices of my fields, field order."""
        return [np.concatenate([spec[f][r * pts:(r + 1) * pts]
                                for f in fields_of[o]])
                for r in range(n_ranks)]

    # exch is keyed by rank only: iteration it+1's exchange task cannot
    # start before iteration it's readers finished (it is gated through
    # unpack → phys), so each slot is safely overwritten and peak memory
    # stays O(n_grid × n_fields) instead of growing with step count.
    def a2a_g2s(r, it):
        exch[("g2s", r)] = coll.alltoall(
            pack_g2s(r), rank=r, mode=a2a_mode, key=("g2s", it))

    def a2a_s2g(o, it):
        exch[("s2g", o)] = coll.alltoall(
            pack_s2g(o), rank=o, mode=a2a_mode, key=("s2g", it))

    def fft_field(f, it):
        o = owner(f)
        parts = exch[("g2s", o)]
        if isinstance(parts, tac.AsyncHandle):
            parts = parts.result
        j = fields_of[o].index(f)
        full = np.concatenate([parts[s][j * pts:(j + 1) * pts]
                               for s in range(n_ranks)])
        spec[f] = spectral_step(full)

    def unpack(r, it):
        parts = exch.pop(("s2g", r))
        if isinstance(parts, tac.AsyncHandle):
            parts = parts.result
        for o in range(n_ranks):
            for j, f in enumerate(fields_of[o]):
                grid[(f, r)] = parts[o][j * pts:(j + 1) * pts]

    for it in range(steps):
        if version == "pure":
            for f in range(n_fields):
                for r in range(n_ranks):
                    phys_task(f, r, it)
            g2s = coll.run_group(
                "alltoall", [{"blocks": pack_g2s(r)}
                             for r in range(n_ranks)], key=("g2s", it))
            for r in range(n_ranks):
                exch[("g2s", r)] = g2s[r]
            for f in range(n_fields):
                fft_field(f, it)
            s2g = coll.run_group(
                "alltoall", [{"blocks": pack_s2g(o)}
                             for o in range(n_ranks)], key=("s2g", it))
            for o in range(n_ranks):
                exch[("s2g", o)] = s2g[o]
            for r in range(n_ranks):
                unpack(r, it)
            continue

        for r in range(n_ranks):
            for f in range(n_fields):
                rt.submit(phys_task, f, r, it, inout=[("g", f, r)],
                          name=f"phys[{f},{r}]@{it}", label="compute",
                          rank=r)
        for r in range(n_ranks):
            rt.submit(a2a_g2s, r, it,
                      in_=[("g", f, r) for f in range(n_fields)],
                      out=[("xg", r, it)], label="comm",
                      name=f"a2a_g2s[{r}]@{it}", rank=r)
        for f in range(n_fields):
            rt.submit(fft_field, f, it, in_=[("xg", owner(f), it)],
                      out=[("s", f)], label="compute",
                      name=f"fft[{f}]@{it}", rank=owner(f))
        for o in range(n_ranks):
            rt.submit(a2a_s2g, o, it,
                      in_=[("s", f) for f in fields_of[o]],
                      out=[("xs", o, it)], label="comm",
                      name=f"a2a_s2g[{o}]@{it}", rank=o)
        for r in range(n_ranks):
            rt.submit(unpack, r, it, in_=[("xs", r, it)],
                      inout=[("g", f, r) for f in range(n_fields)],
                      label="compute", name=f"unp[{r}]@{it}", rank=r)

    rt.taskwait()
    stats = dict(rt.stats)
    rt.close()
    out = np.stack([np.concatenate([grid[(f, r)] for r in range(n_ranks)])
                    for f in range(n_fields)])
    return out, stats


# ---------------------------------------------------------------------------
# elastic execution: checkpoint / injected rank death / shrink / resume
# ---------------------------------------------------------------------------
def _elastic_step(comm, coll, fields: np.ndarray, *, mode, rt, it):
    """One IFSKer timestep of the global ``fields`` array over ``comm``.

    Works for ANY communicator size that divides both axes; physics and
    the spectral step are decomposition-independent, so the result is
    bitwise identical at every rank count — which is what lets a
    shrunken world resume a dead one's checkpoint exactly.
    """
    n_ranks = comm.size
    n_fields, n_grid = fields.shape
    pts = n_grid // n_ranks
    grid = {(f, r): fields[f, r * pts:(r + 1) * pts].copy()
            for f in range(n_fields) for r in range(n_ranks)}
    spec: Dict = {}
    exch: Dict = {}
    fields_of = {o: [f for f in range(n_fields) if f % n_ranks == o]
                 for o in range(n_ranks)}

    def phys_task(f, r):
        grid[(f, r)] = physics(grid[(f, r)])

    def a2a_g2s(r):
        blocks = [np.concatenate([grid[(f, r)] for f in fields_of[o]])
                  for o in range(n_ranks)]
        exch[("g2s", r)] = coll.alltoall(blocks, rank=r, mode=mode,
                                         key=("eg2s", it))

    def fft_field(f):
        o = f % n_ranks
        parts = exch[("g2s", o)]
        if isinstance(parts, tac.AsyncHandle):
            parts = parts.result
        j = fields_of[o].index(f)
        full = np.concatenate([parts[s][j * pts:(j + 1) * pts]
                               for s in range(n_ranks)])
        spec[f] = spectral_step(full)

    def a2a_s2g(o):
        blocks = [np.concatenate([spec[f][r * pts:(r + 1) * pts]
                                  for f in fields_of[o]])
                  for r in range(n_ranks)]
        exch[("s2g", o)] = coll.alltoall(blocks, rank=o, mode=mode,
                                         key=("es2g", it))

    def unpack(r):
        parts = exch[("s2g", r)]
        if isinstance(parts, tac.AsyncHandle):
            parts = parts.result
        for o in range(n_ranks):
            for j, f in enumerate(fields_of[o]):
                grid[(f, r)] = parts[o][j * pts:(j + 1) * pts]

    for r in range(n_ranks):
        for f in range(n_fields):
            rt.submit(phys_task, f, r, inout=[("g", f, r)],
                      name=f"ephys[{f},{r}]@{it}", label="compute",
                      rank=r)
    for r in range(n_ranks):
        rt.submit(a2a_g2s, r, in_=[("g", f, r) for f in range(n_fields)],
                  out=[("xg", r, it)], label="comm",
                  name=f"ea2a_g2s[{r}]@{it}", rank=r)
    for f in range(n_fields):
        rt.submit(fft_field, f, in_=[("xg", f % n_ranks, it)],
                  out=[("s", f)], label="compute", name=f"efft[{f}]@{it}",
                  rank=f % n_ranks)
    for o in range(n_ranks):
        rt.submit(a2a_s2g, o, in_=[("s", f) for f in fields_of[o]],
                  out=[("xs", o, it)], label="comm",
                  name=f"ea2a_s2g[{o}]@{it}", rank=o)
    for r in range(n_ranks):
        rt.submit(unpack, r, in_=[("xs", r, it)],
                  inout=[("g", f, r) for f in range(n_fields)],
                  label="compute", name=f"eunp[{r}]@{it}", rank=r)
    rt.taskwait()
    return np.stack([np.concatenate([grid[(f, r)]
                                     for r in range(n_ranks)])
                     for f in range(n_fields)])


def run_elastic(ckpt_dir: str, *, n_ranks: int = 4, workers: int = 2,
                n_fields: int = 12, n_grid: int = 24, steps: int = 4,
                kill_step: int = None, kill_rank: int = 0,
                kill_after_ops: int = 1, mode: str = "event",
                notify: str = None, seed: int = 0):
    """Fault-tolerant IFSKer: checkpoint each step, survive an injected
    rank death mid-transposition, shrink, resume (see
    ``gauss_seidel.run_elastic`` for the recovery protocol).  The axes
    must divide every rank count the run may shrink to (defaults: 12
    fields / 24 points over 4 ranks survive the loss of one).

    Returns ``(final fields, info)``.
    """
    from repro import checkpoint as checkpoint_lib
    from repro.core import resilience
    from repro.core.executor import TaskError

    world = tac.CommWorld(n_ranks)
    injector = resilience.FaultInjector(world)
    tac.init(tac.TASK_MULTIPLE)

    step = checkpoint_lib.latest_step(ckpt_dir)
    if step is None:
        rng = np.random.default_rng(seed)
        fields = rng.standard_normal((n_fields, n_grid))
        checkpoint_lib.save_checkpoint(ckpt_dir, {"fields": fields}, 0)
        step = 0
    else:
        state, step = checkpoint_lib.restore_checkpoint(
            ckpt_dir, {"fields": np.empty((n_fields, n_grid))})
        fields = state["fields"]

    comm = world
    coll = Collectives(world)
    rt = TaskRuntime(num_workers=workers, notify=notify)
    rt.start()
    info = {"recoveries": []}

    try:
        while step < steps:
            it = step + 1
            if kill_step is not None and it == kill_step \
                    and not injector.killed:
                injector.arm(kill_rank, after_ops=kill_after_ops)
            try:
                fields = _elastic_step(comm, coll, fields, mode=mode,
                                       rt=rt, it=it)
            except TaskError:
                injector.disarm()
                rt.close()
                shrunk = resilience.recover(world)
                if n_fields % shrunk.size or n_grid % shrunk.size:
                    raise ValueError(
                        f"{n_fields} fields / {n_grid} points do not "
                        f"divide over {shrunk.size} survivors")
                comm, coll = shrunk, Collectives(shrunk)
                rt = TaskRuntime(num_workers=workers, notify=notify)
                rt.start()
                state, step = checkpoint_lib.restore_checkpoint(
                    ckpt_dir, {"fields": np.empty((n_fields, n_grid))})
                fields = state["fields"]
                info["recoveries"].append(
                    {"at_step": it, "killed": list(world.failed),
                     "survivors": comm.size, "resumed_step": step})
                continue
            step = it
            checkpoint_lib.save_checkpoint(ckpt_dir, {"fields": fields},
                                           step)
    finally:
        rt.close()
    info["size"] = comm.size
    return fields, info


# ---------------------------------------------------------------------------
# simulated scaling (Fig. 14)
# ---------------------------------------------------------------------------
def build_sim(version, *, n_ranks, n_fields=64, steps=6, t_phys=1.0,
              t_fft=1.0, t_comm=0.02, latency=0.05):
    """Replays the DAG the real versions now execute: per-rank ``alltoall``
    collective nodes for each transposition (g2s / s2g), with the waiting
    discipline of the version (held / paused / event-bound)."""
    tasks: List[SimTask] = []
    index: Dict[str, int] = {}

    def add(rank, cost, kind=COMPUTE, start=(), name="", group=None,
            group_latency=0.0):
        t = SimTask(len(tasks), rank, cost, kind=kind,
                    start_deps=[(index[s], 0.0) for s in start
                                if s and s in index],
                    name=name, group=group, group_latency=group_latency)
        tasks.append(t)
        index[name] = t.id

    kind = {"interop-blk": COMM_PAUSED,
            "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
    fl = n_fields // n_ranks  # fields per rank in spectral space
    tp = t_phys / fl          # physics cost per (field, rank) slice
    # pairwise all-to-all latency from the IR cost model (α = per-message
    # latency, wires free — equals the old rounds × latency count)
    a2a_lat = schedule_ir.build("alltoall", "ring", n_ranks).cost(
        latency, 0.0, 0)

    for it in range(steps):
        for r in range(n_ranks):
            for f in range(n_fields):
                add(r, tp, start=[f"unp[{r}]@{it - 1}"] if it else [],
                    name=f"phys[{f},{r}]@{it}")
        for r in range(n_ranks):
            add(r, t_comm, kind=kind,
                start=[f"phys[{f},{r}]@{it}" for f in range(n_fields)],
                group=f"g2s@{it}", group_latency=a2a_lat,
                name=f"a2a_g2s[{r}]@{it}")
        for f in range(n_fields):
            o = f % n_ranks
            add(o, t_fft / fl, start=[f"a2a_g2s[{o}]@{it}"],
                name=f"fft[{f}]@{it}")
        for o in range(n_ranks):
            add(o, t_comm, kind=kind,
                start=[f"fft[{f}]@{it}" for f in range(n_fields)
                       if f % n_ranks == o],
                group=f"s2g@{it}", group_latency=a2a_lat,
                name=f"a2a_s2g[{o}]@{it}")
        for r in range(n_ranks):
            add(r, t_comm, start=[f"a2a_s2g[{r}]@{it}"],
                name=f"unp[{r}]@{it}")
    return tasks


def simulate_version(version, *, n_ranks, workers=4, **kw):
    tasks = build_sim(version, n_ranks=n_ranks, **kw)
    sim = Simulator(n_ranks, 1 if version == "pure" else workers,
                    task_overhead=0.001, resume_overhead=0.005)
    return sim.run(tasks).makespan


def bench(print_fn=print):
    rows = []
    ref, _ = run_real("pure")
    for v in VERSIONS[1:]:
        out, stats = run_real(v)
        err = float(np.abs(out - ref).max())
        assert err < 1e-10, (v, err)

    # end-to-end notification-backend legs: both engines, same numerics.
    for v in VERSIONS[1:]:
        for nb in ("polling", "continuation"):
            t0 = time.monotonic()
            out, _ = run_real(v, notify=nb)
            dt = (time.monotonic() - t0) / 3
            assert float(np.abs(out - ref).max()) < 1e-10, (v, nb)
            rows.append((f"ifsker_e2e_{v}_{nb}", dt * 1e6, "notify-leg"))

    for v in VERSIONS:
        t0 = time.monotonic()
        _, stats = run_real(v)
        dt = (time.monotonic() - t0) / 3
        rows.append((f"ifsker_real_{v}", dt * 1e6,
                     f"blocks={stats.get('task_blocks', 0)}"))

    base = simulate_version("pure", n_ranks=1)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            mk = simulate_version(v, n_ranks=n)
            rows.append((f"ifsker_sim_{v}_r{n}", mk * 1e6,
                         f"speedup={base / mk:.2f}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


# ---------------------------------------------------------------------------
# traced leg: Perfetto timeline for the transposition pipeline
# ---------------------------------------------------------------------------
def run_traced(trace_path: str, *, print_fn=print):
    """``--trace`` leg: one interop-nonblk run under the tracer.

    Exports the task/handle/collective timeline of the event-bound
    transposition pipeline as Perfetto JSON with the per-rank overlap
    fractions and straggler scores in ``otherData``; exits non-zero if
    the document violates ``repro.obs.SPAN_SCHEMA``.
    """
    from repro import obs

    with obs.tracing(capacity=1 << 18) as tr:
        run_real("interop-nonblk", n_ranks=2, workers=2,
                 n_fields=8, n_grid=128, steps=3)
        events = tr.events()
    overlap = obs.overlap_fraction(events)
    doc = obs.export_trace(trace_path, events=events, extra={
        "benchmark": "ifsker",
        "overlap_fraction": overlap,
        "per_rank_overlap": {str(r): f for r, f in
                             obs.per_rank_overlap(events).items()},
        "straggler_scores": {str(r): s for r, s in
                             obs.straggler_scores(events).items()},
    })
    obs.assert_valid_trace(doc)
    print_fn(f"ifsker_trace_overlap,{overlap * 1e6:.1f},"
             f"overlap-fraction-ppm")
    print_fn(f"ifsker_trace_events,{len(events)},file={trace_path}")
    return overlap


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="IFSKer benchmark (paper §7.2)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="run the traced interop-nonblk leg and write "
                         "Perfetto JSON here (skips the plain bench)")
    ns = ap.parse_args()
    if ns.trace:
        run_traced(ns.trace)
    else:
        bench()
