"""IFSKer benchmark — paper §7.2 (Fig. 14).

Mock-up of the IFS spectral-transform weather code: timestep cycles of
grid-point physics and Fourier transforms with a data transposition
(all-to-all) between phases.  Grid space distributes *points* across ranks;
spectral space distributes *fields*; the transitions redistribute.

Versions (as in the paper — Fork-Join/Sentinel are equivalent to Pure here
because there is one rank per core):

* ``pure``           — sequential phases with a full exchange between them.
* ``interop-blk``    — per-field communication tasks using task-aware
                       blocking waits (TAMPI blocking mode): transposition
                       overlaps physics/FFTs of other fields.
* ``interop-nonblk`` — receives bound to event counters (TAMPI_Iwait):
                       same overlap, no pause/resume cost — the paper's
                       preferred mode for many small messages.

Real executions validate numerics across versions; the simulator replays
the task DAGs for the scaling curve.  CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import TaskRuntime, tac
from repro.core.simulate import Simulator, SimTask, COMPUTE, COMM_PAUSED, \
    COMM_EVENTS, COMM_HELD

VERSIONS = ("pure", "interop-blk", "interop-nonblk")


def physics(x: np.ndarray) -> np.ndarray:
    return x + 0.1 * np.tanh(x) - 0.01 * x ** 3


def spectral_step(f: np.ndarray) -> np.ndarray:
    F = np.fft.rfft(f)
    F *= np.exp(-0.01 * np.arange(F.size))   # diffusion in spectral space
    return np.fft.irfft(F, n=f.size)


def run_real(version: str, *, n_ranks: int = 2, workers: int = 2,
             n_fields: int = 8, n_grid: int = 64, steps: int = 3,
             seed: int = 0):
    """Returns (final fields array, runtime stats)."""
    assert n_fields % n_ranks == 0 and n_grid % n_ranks == 0
    rng = np.random.default_rng(seed)
    pts = n_grid // n_ranks
    # grid space: fields[f] split by points across ranks
    grid: Dict = {(f, r): rng.standard_normal(pts)
                  for f in range(n_fields) for r in range(n_ranks)}
    spec: Dict = {}
    world = tac.CommWorld(n_ranks)
    tac.init(tac.TASK_MULTIPLE if version.startswith("interop")
             else tac.THREAD_MULTIPLE)
    rt = TaskRuntime(num_workers=workers)
    rt.start()

    def owner(f: int) -> int:
        return f % n_ranks

    def phys_task(f, r, it):
        grid[(f, r)] = physics(grid[(f, r)])

    def send_slice(f, r, it):
        world.isend(grid[(f, r)].copy(), src=r, dst=owner(f),
                    tag=("g2s", f, r, it))

    def gather_fft(f, it):
        o = owner(f)
        parts = []
        handles = [world.irecv(src=r, dst=o, tag=("g2s", f, r, it))
                   for r in range(n_ranks)]
        if version == "interop-nonblk":
            # bind all receives; a successor task does the FFT
            tac.iwaitall(handles)
            spec[(f, it, "handles")] = handles
        else:
            parts = [tac.wait(h) for h in handles]
            spec[f] = spectral_step(np.concatenate(parts))

    def fft_after_events(f, it):
        handles = spec.pop((f, it, "handles"))
        parts = [h.result for h in handles]
        spec[f] = spectral_step(np.concatenate(parts))

    def scatter(f, it):
        full = spec[f]
        for r in range(n_ranks):
            world.isend(full[r * pts:(r + 1) * pts].copy(), src=owner(f),
                        dst=r, tag=("s2g", f, r, it))

    def recv_slice(f, r, it):
        h = world.irecv(src=owner(f), dst=r, tag=("s2g", f, r, it))
        if version == "interop-nonblk":
            tac.iwait(h)
            grid[(f, r, "h")] = h
        else:
            grid[(f, r)] = tac.wait(h)

    def unpack(f, r, it):
        h = grid.pop((f, r, "h"), None)
        if h is not None:
            grid[(f, r)] = h.result

    for it in range(steps):
        if version == "pure":
            for f in range(n_fields):
                for r in range(n_ranks):
                    phys_task(f, r, it)
            for f in range(n_fields):
                for r in range(n_ranks):
                    send_slice(f, r, it)
            for f in range(n_fields):
                o = owner(f)
                parts = [world.irecv(src=r, dst=o,
                                     tag=("g2s", f, r, it)).result
                         for r in range(n_ranks)]
                spec[f] = spectral_step(np.concatenate(parts))
            for f in range(n_fields):
                scatter(f, it)
            for f in range(n_fields):
                for r in range(n_ranks):
                    grid[(f, r)] = world.irecv(
                        src=owner(f), dst=r, tag=("s2g", f, r, it)).result
            continue

        for f in range(n_fields):
            for r in range(n_ranks):
                rt.submit(phys_task, f, r, it, inout=[("g", f, r)],
                          name=f"phys[{f},{r}]@{it}", label="compute")
                rt.submit(send_slice, f, r, it, in_=[("g", f, r)],
                          name=f"snd[{f},{r}]@{it}", label="comm")
            rt.submit(gather_fft, f, it, out=[("s", f)],
                      name=f"fft[{f}]@{it}", label="comm")
            if version == "interop-nonblk":
                rt.submit(fft_after_events, f, it, inout=[("s", f)],
                          name=f"fin[{f}]@{it}", label="compute")
            rt.submit(scatter, f, it, in_=[("s", f)],
                      name=f"sct[{f}]@{it}", label="comm")
            for r in range(n_ranks):
                rt.submit(recv_slice, f, r, it, out=[("g", f, r)],
                          name=f"rcv[{f},{r}]@{it}", label="comm")
                if version == "interop-nonblk":
                    rt.submit(unpack, f, r, it, inout=[("g", f, r)],
                              name=f"unp[{f},{r}]@{it}", label="compute")

    rt.taskwait()
    stats = dict(rt.stats)
    rt.close()
    out = np.stack([np.concatenate([grid[(f, r)] for r in range(n_ranks)])
                    for f in range(n_fields)])
    return out, stats


# ---------------------------------------------------------------------------
# simulated scaling (Fig. 14)
# ---------------------------------------------------------------------------
def build_sim(version, *, n_ranks, n_fields=64, steps=6, t_phys=1.0,
              t_fft=1.0, t_comm=0.02, latency=0.05):
    tasks: List[SimTask] = []
    index: Dict[str, int] = {}

    def add(rank, cost, kind=COMPUTE, start=(), events=(), name=""):
        t = SimTask(len(tasks), rank, cost, kind=kind,
                    start_deps=[(index[s], 0.0) for s in start
                                if s and s in index],
                    event_deps=[(index[e], latency) for e in events
                                if e and e in index], name=name)
        tasks.append(t)
        index[name] = t.id

    kind = {"interop-blk": COMM_PAUSED,
            "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
    fl = n_fields // n_ranks  # fields per rank in spectral space
    tp = t_phys / fl          # physics cost per (field, rank) slice

    for it in range(steps):
        # physics + sends, all fields
        for f in range(n_fields):
            for r in range(n_ranks):
                dep = [f"rcv[{f},{r}]@{it - 1}"] if it else []
                if version == "pure" and it:
                    dep = [f"stepend[{r}]@{it - 1}"]
                add(r, tp, start=dep, name=f"phys[{f},{r}]@{it}")
                add(r, t_comm / n_ranks, start=[f"phys[{f},{r}]@{it}"],
                    name=f"snd[{f},{r}]@{it}")
        if version == "pure":
            # barrier: the sequential exchange completes before any FFT
            for r in range(n_ranks):
                add(r, 0.0,
                    start=[f"snd[{f},{r}]@{it}" for f in range(n_fields)],
                    name=f"sent[{r}]@{it}")
        # FFT phase (spectral owners) + scatter back
        for f in range(n_fields):
            o = f % n_ranks
            if version == "pure":
                add(o, t_fft / fl,
                    start=[f"sent[{r}]@{it}" for r in range(n_ranks)],
                    name=f"fft[{f}]@{it}")
            else:
                add(o, t_fft / fl, kind=kind,
                    start=[f"snd[{f},{o}]@{it}"],
                    events=[f"snd[{f},{r}]@{it}" for r in range(n_ranks)
                            if r != o],
                    name=f"fft[{f}]@{it}")
            add(o, t_comm, start=[f"fft[{f}]@{it}"], name=f"sct[{f}]@{it}")
        for f in range(n_fields):
            for r in range(n_ranks):
                # pure: blocking receives run in program order — after the
                # rank's own scatter phase (otherwise a held receive would
                # occupy the sequential flow before its sender ran: §5)
                start = ([f"sct[{f2}]@{it}" for f2 in range(n_fields)
                          if f2 % n_ranks == r] if version == "pure"
                         else [])
                add(r, t_comm / n_ranks,
                    kind=kind if version != "pure" else COMM_HELD,
                    start=start,
                    events=[f"sct[{f}]@{it}"], name=f"rcv[{f},{r}]@{it}")
        if version == "pure":
            for r in range(n_ranks):
                add(r, 0.0, start=[f"rcv[{f},{r}]@{it}"
                                   for f in range(n_fields)],
                    name=f"stepend[{r}]@{it}")
    return tasks


def simulate_version(version, *, n_ranks, workers=4, **kw):
    tasks = build_sim(version, n_ranks=n_ranks, **kw)
    sim = Simulator(n_ranks, 1 if version == "pure" else workers,
                    task_overhead=0.001, resume_overhead=0.005)
    return sim.run(tasks).makespan


def bench(print_fn=print):
    rows = []
    ref, _ = run_real("pure")
    for v in VERSIONS[1:]:
        out, stats = run_real(v)
        err = float(np.abs(out - ref).max())
        assert err < 1e-10, (v, err)

    for v in VERSIONS:
        t0 = time.monotonic()
        _, stats = run_real(v)
        dt = (time.monotonic() - t0) / 3
        rows.append((f"ifsker_real_{v}", dt * 1e6,
                     f"blocks={stats.get('task_blocks', 0)}"))

    base = simulate_version("pure", n_ranks=1)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            mk = simulate_version(v, n_ranks=n)
            rows.append((f"ifsker_sim_{v}_r{n}", mk * 1e6,
                         f"speedup={base / mk:.2f}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench()
