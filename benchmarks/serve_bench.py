"""Serving benchmark: event-bound vs blocking-sentinel completion.

Drives :class:`repro.serving.engine.ServingEngine` over a synthetic
multi-tenant trace — Poisson arrivals, two priority tenants, mixed
generation lengths — once per completion leg, on the SAME trace and the
SAME adapter (``repro.serving.synthetic.SyntheticAdapter``: device
micro-steps complete asynchronously on a device-queue thread pool, host
detokenisation is real GIL-releasing work).  The structural claim under
test is the paper's: the blocking-sentinel leg parks one runtime worker
inside every device wait, so at most ``--workers`` requests make
progress regardless of admitted slots, while the event-bound leg
(``tac.iwait`` → continuation engine) frees the worker at dispatch and
every in-flight chain advances at device latency.

Hard acceptance (exits non-zero on violation):

* the two legs emit bit-identical token streams;
* event-bound tokens/s >= blocking tokens/s;
* event-bound p99 latency <= blocking p99 latency.

Writes ``BENCH_serve.json`` with gated calibration rows
``serve.event`` / ``serve.blocking`` (``measured_s`` + linear cost
features + ``overhead_class "serve:<leg>"``) and ``gate_scope:
["serve"]`` so ``tools/calibrate.py --gate`` holds this bench
accountable for exactly its own baseline rows.  Features (per leg, both
legs identical — only ``measured_s`` differs): ``rounds`` = device
micro-steps, ``wire_bytes`` = device-occupancy proxy (micro-steps ×
device latency in µs), ``combine_bytes`` = host detok bytes
(micro-steps × hash rounds × 64 KiB).

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

from repro.serving import (Request, ServingEngine, SyntheticAdapter,
                           token_at)

HOST_BUF_BYTES = 64 * 1024


def make_trace(n: int, *, seed: int, rate_per_s: float,
               gen_choices) -> list:
    """Poisson multi-tenant trace: two priority classes, mixed lengths."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.exponential(1.0 / rate_per_s)
        reqs.append(Request(
            rid=i, prompt=100 + 17 * i,
            gen_len=int(rng.choice(gen_choices)),
            priority=int(rng.random() < 0.25),   # 25% batch tenant
            arrival_s=t))
    return reqs


def run_leg(leg: str, trace, adapter, *, slots: int,
            workers: int) -> dict:
    engine = ServingEngine(adapter, slots=slots, completion=leg,
                           num_workers=workers)
    # fresh Request objects per leg: state machines are single-use
    reqs = [Request(rid=r.rid, prompt=r.prompt, gen_len=r.gen_len,
                    priority=r.priority, arrival_s=r.arrival_s)
            for r in trace]
    report = engine.run(reqs)
    for r in reqs:
        want = [token_at(r.prompt, s) for s in range(r.gen_len)]
        if report.outputs[r.rid] != want:
            raise SystemExit(
                f"serve_bench: token parity violation on the {leg} leg, "
                f"request {r.rid}: got {report.outputs[r.rid]}, "
                f"want {want}")
    return report


def bench(*, smoke: bool = False, seed: int = 0,
          json_path: str = "BENCH_serve.json",
          print_fn=print) -> dict:
    n, gen_choices = (24, (4, 8, 12)) if smoke else (64, (8, 16, 24))
    slots, workers = 16, 4
    dev_ms, host_rounds = 30.0, 8
    trace = make_trace(n, seed=seed, rate_per_s=400.0,
                       gen_choices=gen_choices)
    total_steps = sum(r.gen_len for r in trace)
    features = {
        "rounds": float(total_steps),
        "wire_bytes": float(total_steps) * dev_ms * 1e3,
        "combine_bytes": float(total_steps) * host_rounds
                         * HOST_BUF_BYTES,
    }

    adapter = SyntheticAdapter(dev_ms=dev_ms, host_rounds=host_rounds,
                               streams=slots)
    adapter.warmup()
    report = {"requests": n, "slots": slots, "workers": workers,
              "dev_ms": dev_ms, "host_rounds": host_rounds,
              "serve": {}}
    legs = {}
    try:
        for leg in ("event", "blocking"):
            # untimed warm pass: thread pools, runtime, code paths
            run_leg(leg, make_trace(4, seed=seed + 1, rate_per_s=1e6,
                                    gen_choices=(2,)),
                    adapter, slots=slots, workers=workers)
            rep = run_leg(leg, trace, adapter, slots=slots,
                          workers=workers)
            legs[leg] = rep
            report["serve"][leg] = {
                "measured_s": rep.wall_s,
                "features": features,
                "overhead_class": f"serve:{leg}",
                "tokens": rep.tokens,
                "tokens_per_s": rep.tokens_per_s,
                "p50_ms": rep.p50_ms,
                "p99_ms": rep.p99_ms,
            }
            print_fn(f"serve_{leg},{rep.wall_s / max(rep.tokens, 1) * 1e6:.1f},"
                     f"tok_s={rep.tokens_per_s:.0f};p50={rep.p50_ms:.1f};"
                     f"p99={rep.p99_ms:.1f}")
    finally:
        adapter.close()

    ev, bl = legs["event"], legs["blocking"]
    report["speedup_tokens_per_s"] = ev.tokens_per_s / bl.tokens_per_s
    report["p99_ratio"] = ev.p99_ms / bl.p99_ms
    report["gate_scope"] = ["serve"]
    pathlib.Path(json_path).write_text(json.dumps(report, indent=2))
    print_fn(f"serve_report_json,0.0,{json_path}")
    print_fn(f"serve_speedup,{report['speedup_tokens_per_s']:.2f},"
             f"p99_ratio={report['p99_ratio']:.2f}")

    if ev.tokens_per_s < bl.tokens_per_s:
        raise SystemExit(
            f"serve_bench: event-bound leg slower than blocking sentinel "
            f"({ev.tokens_per_s:.0f} vs {bl.tokens_per_s:.0f} tok/s) — "
            f"the task-aware completion path regressed")
    if ev.p99_ms > bl.p99_ms:
        raise SystemExit(
            f"serve_bench: event-bound p99 above blocking sentinel "
            f"({ev.p99_ms:.1f} vs {bl.p99_ms:.1f} ms) — the task-aware "
            f"completion path regressed")
    return report


def run_traced(trace_path: str, *, seed: int = 0, print_fn=print) -> float:
    """``--trace`` leg: one event-bound serve run under the tracer.

    Exports the serving micro-step timeline (device_step / detok spans,
    token instants, handle in-flight windows) as Perfetto JSON; exits
    non-zero if the document violates ``repro.obs.SPAN_SCHEMA``.
    """
    from repro import obs

    trace = make_trace(16, seed=seed, rate_per_s=400.0,
                       gen_choices=(4, 8))
    adapter = SyntheticAdapter(dev_ms=10.0, host_rounds=4, streams=16)
    adapter.warmup()
    try:
        with obs.tracing(capacity=1 << 18) as tr:
            rep = run_leg("event", trace, adapter, slots=16, workers=4)
            events = tr.events()
    finally:
        adapter.close()
    counts = obs.summarize(events)["counts"]
    doc = obs.export_trace(trace_path, events=events, extra={
        "benchmark": "serve_bench", "completion": "event",
        "tokens": rep.tokens, "tokens_per_s": rep.tokens_per_s,
        "p99_ms": rep.p99_ms,
    })
    obs.assert_valid_trace(doc)
    if not counts.get("serving/device_step[X]"):
        raise SystemExit("serve_bench --trace: no device_step spans "
                         "recorded")
    print_fn(f"serve_trace_events,{len(events)},file={trace_path};"
             f"tokens={rep.tokens}")
    return rep.tokens_per_s


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="BENCH_serve.json")
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="run one traced event-bound leg and write Perfetto "
                        "JSON here (skips the comparison bench)")
    args = p.parse_args(argv)
    if args.trace:
        run_traced(args.trace, seed=args.seed)
        return 0
    bench(smoke=args.smoke, seed=args.seed, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
