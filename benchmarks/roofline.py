"""Roofline analysis over dry-run records (§Roofline deliverable).

Reads the JSON written by ``repro.launch.dryrun`` and derives, per
(arch × shape × mesh) cell, the three roofline terms on TPU v5e:

  compute   = HLO_FLOPs_per_device / PEAK_FLOPS          (197 TFLOP/s bf16)
  memory    = HLO_bytes_per_device / HBM_BW              (819 GB/s)
  collective= wire_bytes_per_device / LINK_BW            (~50 GB/s/link ICI)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for training and
2·N·D for inference steps, the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs × n_devices), the dominant term, and the achieved
roofline fraction  model_time_bound / max(term)s.

CSV: name,us_per_call,derived   (us_per_call = dominant term in µs)
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

# ---------------------------------------------------------------------------
# Fused collective-stage roofline (the Pallas executor tier)
# ---------------------------------------------------------------------------
# Per-element HBM bytes of one reduce-scatter combine stage (fp32
# accumulator, the wire dtype on the received chunk) and of the fused
# Gauss–Seidel stencil stage.  The fused kernels read each operand and
# write the result ONCE; the unfused XLA shape additionally materialises
# the fp32 cast/dequant intermediate (combine) or re-reads the block for
# the residual pass (stencil).  These are the roofline-model numbers the
# bench gate pins: every narrow-wire fused stage must come in at
# ≤ STAGE_MAX_FUSED_RATIO × the unfused bytes.
STAGE_MAX_FUSED_RATIO = 0.6
_ACC_B = 4
_WIRE_B = {"fp32": 4, "bf16": 2, "int8": 1}


def stage_bytes_per_elem(wire: str, fused: bool) -> int:
    per = _WIRE_B[wire] + 2 * _ACC_B          # read got + read acc + write
    if not fused and wire != "fp32":
        per += 2 * _ACC_B                     # fp32 temp: write + read back
    return per


def gs_stage_bytes_per_elem(fused: bool) -> int:
    return 2 * _ACC_B if fused else 4 * _ACC_B


def stage_rows(elems: int = 1 << 20):
    """Fused-vs-unfused stage roofline rows (always emitted — they are
    analytic, needing no dry-run record) + the hard bytes-ratio assert."""
    rows = []
    for wire in ("fp32", "bf16", "int8"):
        per_f = stage_bytes_per_elem(wire, True)
        per_u = stage_bytes_per_elem(wire, False)
        ratio = per_f / per_u
        assert wire == "fp32" or ratio <= STAGE_MAX_FUSED_RATIO, \
            (wire, ratio)
        t_f = per_f * elems / HBM_BW
        t_u = per_u * elems / HBM_BW
        rows.append((f"roofline_stage_combine_{wire}", t_f * 1e6,
                     f"fused_bytes={per_f * elems};"
                     f"unfused_bytes={per_u * elems};"
                     f"ratio={ratio:.3f};unfused_us={t_u * 1e6:.1f}"))
    per_f, per_u = gs_stage_bytes_per_elem(True), gs_stage_bytes_per_elem(
        False)
    ratio = per_f / per_u
    assert ratio <= STAGE_MAX_FUSED_RATIO, ratio
    rows.append(("roofline_stage_gs_stencil", per_f * elems / HBM_BW * 1e6,
                 f"fused_bytes={per_f * elems};"
                 f"unfused_bytes={per_u * elems};ratio={ratio:.3f};"
                 f"unfused_us={per_u * elems / HBM_BW * 1e6:.1f}"))
    return rows


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    n_dev = rec["n_devices"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    vmem_dev = rec["cost"].get("vmem_resident_bytes", 0.0)
    wire_dev = rec["collectives"]["total_wire_bytes"]

    t_compute = flops_dev / PEAK_FLOPS
    # kernel-adjusted: tiles tagged vmem-resident stay in VMEM inside the
    # validated Pallas kernels on TPU; the raw jnp-path number is also kept.
    t_memory = (bytes_dev - vmem_dev) / HBM_BW
    t_memory_raw = bytes_dev / HBM_BW
    t_coll = wire_dev / LINK_BW

    # useful model flops for this step
    mult = 6 if rec["kind"] == "train" else 2
    n_params = rec["active_params"]
    model_flops = mult * n_params * rec["tokens"]
    t_model = model_flops / (n_dev * PEAK_FLOPS)

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_raw_s": t_memory_raw,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(flops_dev * n_dev, 1.0),
        "roofline_fraction": t_model / max(bound, 1e-12),
        "hbm_gib": rec["memory"]["peak_device_bytes"] / 2**30,
        "fits_hbm": rec["memory"]["peak_device_bytes"] < 16 * 2**30,
    }


def bench(print_fn=print, path: str = "results/dryrun_single.json"):
    # the fused-stage rows are analytic — emitted (and asserted) whether
    # or not a dry-run record exists.
    rows = stage_rows()
    if not os.path.exists(path):
        for r in rows:
            print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
        print_fn(f"roofline,0.0,skipped (no {path}; run repro.launch.dryrun"
                 " --all --out results/dryrun_single.json)")
        return rows
    with open(path) as f:
        records = json.load(f)
    for rec in records:
        a = analyze(rec)
        if a is None:
            rows.append((f"roofline_{rec['arch']}_{rec['shape']}"
                         f"_{rec.get('mesh', '?')}", 0.0, "FAILED"))
            continue
        name = f"roofline_{a['arch']}_{a['shape']}_{a['mesh']}"
        dom_us = {"compute": a["t_compute_s"], "memory": a["t_memory_s"],
                  "collective": a["t_collective_s"]}[a["dominant"]] * 1e6
        rows.append((name, dom_us,
                     f"dominant={a['dominant']}"
                     f";frac={a['roofline_fraction']:.3f}"
                     f";useful={a['useful_ratio']:.2f}"
                     f";hbm={a['hbm_gib']:.1f}GiB"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench(path=sys.argv[1] if len(sys.argv) > 1 else
          "results/dryrun_single.json")
