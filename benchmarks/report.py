"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run
JSON records.

  PYTHONPATH=src python -m benchmarks.report results/dryrun_single.json \
      results/dryrun_multi.json > /tmp/tables.md
"""

from __future__ import annotations

import json
import sys

from .roofline import analyze, PEAK_FLOPS, HBM_BW, LINK_BW


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(records):
    out = ["| arch | shape | mesh | compile s | HBM GiB/dev | HLO GFLOP/dev "
           "| coll GiB wire/dev | coll ops |",
           "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                       f"| FAILED | — | — | — | — |")
            continue
        c = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']:.1f} "
            f"| {fmt_bytes(r['memory']['peak_device_bytes'])} "
            f"| {r['cost']['flops'] / 1e9:.0f} "
            f"| {c['total_wire_bytes'] / 2**30:.2f} "
            f"| {int(sum(c['counts'].values()))} |")
    return "\n".join(out)


def roofline_table(records):
    out = ["| arch | shape | t_compute s | t_mem adj s | t_mem raw s "
           "| t_collective s | dominant | useful | frac | fits HBM |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        a = analyze(r)
        if a is None:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — "
                       f"| FAILED | — | — | — |")
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3f} "
            f"| {a['t_memory_s']:.3f} | {a['t_memory_raw_s']:.3f} "
            f"| {a['t_collective_s']:.3f} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.3f} "
            f"| {'yes' if a['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def collective_mix(records):
    out = ["| arch | shape | all-reduce | all-gather | reduce-scatter "
           "| all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    for r in records:
        if not r.get("ok"):
            continue
        w = r["collectives"]["wire_bytes"]
        out.append(f"| {r['arch']} | {r['shape']} | "
                   + " | ".join(f"{w.get(k, 0) / 2**30:.2f}"
                                for k in ("all-reduce", "all-gather",
                                          "reduce-scatter", "all-to-all",
                                          "collective-permute")) + " |")
    return "\n".join(out)


def main():
    for path in sys.argv[1:]:
        records = json.load(open(path))
        print(f"\n### Records: {path} "
              f"({sum(1 for r in records if r.get('ok'))}/{len(records)} ok)"
              f"\n")
        print("#### Dry-run\n")
        print(dryrun_table(records))
        print("\n#### Roofline (v5e: 197 TF bf16, 819 GB/s HBM, "
              "50 GB/s link)\n")
        print(roofline_table(records))
        print("\n#### Collective wire-bytes mix (GiB/dev)\n")
        print(collective_mix(records))


if __name__ == "__main__":
    main()
