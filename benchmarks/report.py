"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run
JSON records.

  PYTHONPATH=src python -m benchmarks.report results/dryrun_single.json \
      results/dryrun_multi.json > /tmp/tables.md

With ``--trace``, the arguments are trace-event JSON files instead
(``repro.obs.export_trace`` artifacts, e.g. the ``--trace`` legs of
``gauss_seidel``/``ifsker``/``serve_bench``) and the output is the
per-rank straggler and overlap tables derived from the spans:

  PYTHONPATH=src python -m benchmarks.report --trace trace-gs.json
"""

from __future__ import annotations

import json
import sys

from .roofline import analyze, PEAK_FLOPS, HBM_BW, LINK_BW


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(records):
    out = ["| arch | shape | mesh | compile s | HBM GiB/dev | HLO GFLOP/dev "
           "| coll GiB wire/dev | coll ops |",
           "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                       f"| FAILED | — | — | — | — |")
            continue
        c = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']:.1f} "
            f"| {fmt_bytes(r['memory']['peak_device_bytes'])} "
            f"| {r['cost']['flops'] / 1e9:.0f} "
            f"| {c['total_wire_bytes'] / 2**30:.2f} "
            f"| {int(sum(c['counts'].values()))} |")
    return "\n".join(out)


def roofline_table(records):
    out = ["| arch | shape | t_compute s | t_mem adj s | t_mem raw s "
           "| t_collective s | dominant | useful | frac | fits HBM |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        a = analyze(r)
        if a is None:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — "
                       f"| FAILED | — | — | — |")
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3f} "
            f"| {a['t_memory_s']:.3f} | {a['t_memory_raw_s']:.3f} "
            f"| {a['t_collective_s']:.3f} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.3f} "
            f"| {'yes' if a['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def collective_mix(records):
    out = ["| arch | shape | all-reduce | all-gather | reduce-scatter "
           "| all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    for r in records:
        if not r.get("ok"):
            continue
        w = r["collectives"]["wire_bytes"]
        out.append(f"| {r['arch']} | {r['shape']} | "
                   + " | ".join(f"{w.get(k, 0) / 2**30:.2f}"
                                for k in ("all-reduce", "all-gather",
                                          "reduce-scatter", "all-to-all",
                                          "collective-permute")) + " |")
    return "\n".join(out)


def straggler_table(events):
    """Per-rank slowdown table from task run spans (repro.obs traces).

    ``score`` is each rank's busy time over the median rank's — the
    per-rank straggler signal ``executor._straggler_service`` acts on,
    recomputed offline from the exported spans.
    """
    from repro.obs import analysis

    scores = analysis.straggler_scores(events)
    overlap = analysis.per_rank_overlap(events)
    out = ["| rank | tasks | busy s | slowdown ×median | overlap |",
           "|---|---|---|---|---|"]
    for rank in sorted(scores):
        s = scores[rank]
        out.append(f"| {rank} | {s['tasks']} | {s['busy']:.4f} "
                   f"| {s['score']:.2f} "
                   f"| {overlap.get(rank, 0.0):.3f} |")
    return "\n".join(out)


def trace_report(paths, print_fn=print):
    """The ``--trace`` mode: straggler/overlap tables per trace file."""
    from repro.obs import analysis, trace as trace_mod

    for path in paths:
        doc = json.load(open(path))
        problems = trace_mod.validate_trace(doc)
        events = doc["traceEvents"] if isinstance(doc, dict) else doc
        summary = analysis.summarize(events)
        print_fn(f"\n### Trace: {path} ({summary['events']} events, "
                 f"{len(problems)} schema problems)\n")
        print_fn(f"overall overlap fraction: "
                 f"{summary['overlap_fraction']:.3f}\n")
        print_fn("#### Per-rank stragglers\n")
        print_fn(straggler_table(events))
        if problems:
            print_fn("\n#### Schema problems\n")
            for p in problems[:20]:
                print_fn(f"- {p}")


def main():
    argv = sys.argv[1:]
    if argv and argv[0] == "--trace":
        trace_report(argv[1:])
        return
    for path in argv:
        records = json.load(open(path))
        print(f"\n### Records: {path} "
              f"({sum(1 for r in records if r.get('ok'))}/{len(records)} ok)"
              f"\n")
        print("#### Dry-run\n")
        print(dryrun_table(records))
        print("\n#### Roofline (v5e: 197 TF bf16, 819 GB/s HBM, "
              "50 GB/s link)\n")
        print(roofline_table(records))
        print("\n#### Collective wire-bytes mix (GiB/dev)\n")
        print(collective_mix(records))


if __name__ == "__main__":
    main()
