"""Benchmark aggregator — one section per paper table/figure.

  gauss_seidel   — paper §7.1 Figs. 9-13 (5 versions, scaling, granularity)
  ifsker         — paper §7.2 Fig. 14
  overlap_bench  — Level-B grad-sync schedules (beyond-paper)
  lm_step        — per-arch substrate regression timings
  roofline       — §Roofline terms from the dry-run records (if present)

Prints ``name,us_per_call,derived`` CSV lines.
"""

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    from . import gauss_seidel, ifsker, overlap_bench, lm_step, roofline
    for mod in (gauss_seidel, ifsker, overlap_bench, lm_step, roofline):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---")
        try:
            mod.bench()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
