"""Gauss–Seidel / heat-equation benchmark — paper §7.1 (Figs. 9–13).

Five versions of the blocked Gauss–Seidel iteration, mirroring the paper:

* ``pure``            — sequential compute per rank, ordered boundary
                        exchange (Pure MPI).
* ``forkjoin``        — parallel compute tasks; sequential communication
                        phase in the main thread; a taskwait barrier per
                        iteration.
* ``sentinel``        — taskified communication serialised by an artificial
                        sentinel dependency (what you must write WITHOUT
                        TASK_MULTIPLE, §6.3).  Note the ordering
                        constraint: sends are chained before receives or
                        the chain itself deadlocks — exactly the paper's
                        point about blocking calls in tasks (§5).
* ``interop-blk``     — TAMPI blocking mode: comm tasks use task-aware
                        waits (pause/resume); no artificial dependencies.
* ``interop-nonblk``  — TAMPI non-blocking mode: comm tasks bind receives
                        to their event counter (TAMPI_Iwait) and finish
                        immediately.

Measurements: (a) REAL execution on the host task runtime at small scale
(all versions must agree numerically); (b) deterministic makespans of the
same task DAGs under the paper's machine model (core/simulate.py) — the
scaling curves.  CSV schema: name,us_per_call,derived

Each iteration additionally computes the global residual through the
task-aware collectives API (core/collectives.py): a scalar ``allreduce``
per iteration, executed per version as a sequential group call (pure /
fork-join), a serialized group inside the sentinel chain, a task-aware
blocking allreduce (interop-blk), or an event-bound allreduce
(interop-nonblk).  The simulator models it as a collective node group.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import Collectives, TaskRuntime, tac
from repro.core.collectives import n_rounds
from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_HELD,
                                 COMM_PAUSED, COMM_EVENTS)

VERSIONS = ("pure", "forkjoin", "sentinel", "interop-blk", "interop-nonblk")


def gs_block(block, top, left, bottom, right):
    padded = np.pad(block, 1)
    padded[0, 1:-1] = top
    padded[-1, 1:-1] = bottom
    padded[1:-1, 0] = left
    padded[1:-1, -1] = right
    return 0.25 * (padded[:-2, 1:-1] + padded[2:, 1:-1]
                   + padded[1:-1, :-2] + padded[1:-1, 2:])


# ---------------------------------------------------------------------------
# real execution on the host runtime
# ---------------------------------------------------------------------------
def run_real(version: str, *, n_ranks: int = 2, workers: int = 2,
             nby: int = 2, nbx: int = 4, bs: int = 32, iters: int = 3,
             seed: int = 0):
    """Returns (final grid, stats).

    Dataflow: grids[it][gy][bx]; block (gy,bx) at iteration it reads
    up/left from iteration it (spatial wavefront) and self/down/right from
    it-1 (temporal wavefront) — the paper's Fig. 7 pattern.  Cross-rank
    halos travel through a tac.CommWorld.
    """
    rng = np.random.default_rng(seed)
    NY = n_ranks * nby
    grids: Dict[int, list] = {
        0: [[rng.standard_normal((bs, bs)) for _ in range(nbx)]
            for _ in range(NY)]}
    for it in range(1, iters + 1):
        grids[it] = [[None] * nbx for _ in range(NY)]
    halos: Dict = {}
    zeros = np.zeros(bs)
    world = tac.CommWorld(n_ranks)
    coll = Collectives(world)
    residuals: Dict = {}   # (rank, it) -> float | CollectiveHandle
    tac.init(tac.TASK_MULTIPLE if version.startswith("interop")
             else tac.THREAD_MULTIPLE)
    rt = TaskRuntime(num_workers=workers)
    rt.start()

    def compute_block(gy, bx, it):
        g_cur, g_prev = grids[it], grids[it - 1]
        r = gy // nby
        top = halos.get(("top", gy, bx, it))
        if isinstance(top, tac.AsyncHandle):
            top = top.result
        if top is None:
            top = g_cur[gy - 1][bx][-1] if gy > 0 else zeros
        bottom = halos.get(("bot", gy, bx, it))
        if isinstance(bottom, tac.AsyncHandle):
            bottom = bottom.result
        if bottom is None:
            bottom = g_prev[gy + 1][bx][0] if gy < NY - 1 else zeros
        left = g_cur[gy][bx - 1][:, -1] if bx > 0 else zeros
        right = g_prev[gy][bx + 1][:, 0] if bx < nbx - 1 else zeros
        grids[it][gy][bx] = gs_block(g_prev[gy][bx], top, left, bottom,
                                     right)

    def comm_pairs(it):
        """(kind, src_rank, dst_rank, gy_src, gy_dst, bx) for iteration it.

        'up' halo: rank r's top-row compute at `it` needs neighbour
        (r-1)'s bottom row of iteration `it` (spatial wavefront) — sent as
        soon as that block is computed.  'down' halo: needs neighbour
        (r+1)'s top row of `it-1`.
        """
        out = []
        for r in range(n_ranks):
            for bx in range(nbx):
                if r > 0:
                    out.append(("dn", r - 1, r, r * nby - 1, r * nby, bx,
                                it))       # their bottom@it -> my top halo
                if r < n_ranks - 1:
                    out.append(("up", r + 1, r, (r + 1) * nby,
                                r * nby + nby - 1, bx, it))  # top@it-1
        return out

    def make_recv(kind, src, dst, gy_dst, bx, it):
        hkey = ("top", gy_dst, bx, it) if kind == "dn" else \
            ("bot", gy_dst, bx, it)

        def recv():
            h = world.irecv(src=src, dst=dst, tag=(kind, bx, it))
            if version == "interop-nonblk":
                tac.iwait(h)
                halos[hkey] = h     # resolved by release time
            else:
                halos[hkey] = tac.wait(h)
        return recv, hkey

    for it in range(1, iters + 1):
        pairs = comm_pairs(it)
        if version in ("pure", "forkjoin"):
            if version == "forkjoin":
                rt.taskwait()   # barrier: previous iteration fully done
            # sequential communication phase in the main thread
            for kind, src, dst, gy_src, gy_dst, bx, _ in pairs:
                if kind == "up":  # prev-iteration data: available now
                    world.isend(grids[it - 1][gy_src][bx][0].copy(),
                                src=src, dst=dst, tag=(kind, bx, it))
                    h = world.irecv(src=src, dst=dst, tag=(kind, bx, it))
                    halos[("bot", gy_dst, bx, it)] = h.result
            # 'dn' halos for pure/forkjoin: computed this iteration —
            # resolved by direct grid access below (single address space),
            # matching the sequential-communication semantics.
        else:
            sentinel = [("comm-sentinel",)] if version == "sentinel" else []

            def submit_pair(kind, src, dst, gy_src, gy_dst, bx):
                def send(kind=kind, src=src, dst=dst, gy_src=gy_src, bx=bx,
                         it=it):
                    src_it = it if kind == "dn" else it - 1
                    row = grids[src_it][gy_src][bx][-1 if kind == "dn"
                                                    else 0]
                    world.isend(row.copy(), src=src, dst=dst,
                                tag=(kind, bx, it))
                rt.submit(send, in_=[("blk", gy_src, bx,
                                      it if kind == "dn" else it - 1)],
                          inout=list(sentinel), label="comm",
                          name=f"s{kind}[{gy_src},{bx}]@{it}")
                recv, hkey = make_recv(kind, src, dst, gy_dst, bx, it)
                rt.submit(recv, out=[hkey], inout=list(sentinel),
                          label="comm", name=f"r{kind}[{gy_dst},{bx}]@{it}")

            # 'up' halos carry it-1 data — submit their pairs up front.
            # 'dn' halos carry same-iteration data: their send must be
            # submitted AFTER the compute that writes the row (submission
            # order defines data versions), interleaved below.
            for kind, src, dst, gy_src, gy_dst, bx, _ in pairs:
                if kind == "up":
                    submit_pair(kind, src, dst, gy_src, gy_dst, bx)

        dn_by_src = {}
        for p in pairs:
            if p[0] == "dn":
                dn_by_src.setdefault((p[3], p[5]), p)  # (gy_src, bx)

        for gy in range(NY):
            r = gy // nby
            for bx in range(nbx):
                deps = [("blk", gy, bx, it - 1)]
                if bx > 0:
                    deps.append(("blk", gy, bx - 1, it))
                if bx < nbx - 1:
                    deps.append(("blk", gy, bx + 1, it - 1))
                if gy > 0:
                    if (gy - 1) // nby == r or version in ("pure",
                                                           "forkjoin"):
                        deps.append(("blk", gy - 1, bx, it))
                    else:
                        deps.append(("top", gy, bx, it))
                if gy < NY - 1:
                    if (gy + 1) // nby == r or version in ("pure",
                                                           "forkjoin"):
                        deps.append(("blk", gy + 1, bx, it - 1))
                    else:
                        deps.append(("bot", gy, bx, it))
                if version == "pure":
                    compute_block(gy, bx, it)
                else:
                    rt.submit(compute_block, gy, bx, it,
                              out=[("blk", gy, bx, it)], in_=deps,
                              label="compute", name=f"c[{gy},{bx}]@{it}")
                    # boundary row produced -> launch its 'dn' exchange now
                    p = dn_by_src.get((gy, bx))
                    if p is not None and version not in ("pure",
                                                         "forkjoin"):
                        kind, src, dst, gy_src, gy_dst, bx2, _ = p
                        submit_pair(kind, src, dst, gy_src, gy_dst, bx2)

        # -- global residual: one allreduce per iteration (collectives) --
        def local_residual(r2, it2):
            tot = 0.0
            for gy2 in range(r2 * nby, (r2 + 1) * nby):
                for bx2 in range(nbx):
                    tot += float(np.abs(grids[it2][gy2][bx2]
                                        - grids[it2 - 1][gy2][bx2]).sum())
            return np.float64(tot)

        if version in ("pure", "forkjoin"):
            if version == "forkjoin":
                rt.taskwait()       # fork-join: iteration fully done
            vals = coll.run_group(
                "allreduce",
                [{"value": local_residual(r2, it)}
                 for r2 in range(n_ranks)],
                op="sum", algorithm="doubling", key=("res", it))
            for r2 in range(n_ranks):
                residuals[(r2, it)] = float(vals[r2])
        elif version == "sentinel":
            # Without TASK_MULTIPLE the collective must be serialised into
            # the comm chain — one task drives the whole group.
            def res_group(it2=it):
                vals = coll.run_group(
                    "allreduce",
                    [{"value": local_residual(r2, it2)}
                     for r2 in range(n_ranks)],
                    op="sum", algorithm="doubling", key=("res", it2))
                for r2 in range(n_ranks):
                    residuals[(r2, it2)] = float(vals[r2])
            rt.submit(res_group,
                      in_=[("blk", gy2, bx2, it) for gy2 in range(NY)
                           for bx2 in range(nbx)],
                      inout=[("comm-sentinel",)], label="comm",
                      name=f"res@{it}")
        else:
            for r2 in range(n_ranks):
                def res_task(r2=r2, it2=it):
                    v = local_residual(r2, it2)
                    if version == "interop-nonblk":
                        residuals[(r2, it2)] = coll.allreduce(
                            v, rank=r2, op="sum", algorithm="doubling",
                            mode="event", key=("res", it2))
                    else:
                        residuals[(r2, it2)] = float(coll.allreduce(
                            v, rank=r2, op="sum", algorithm="doubling",
                            mode="blocking", key=("res", it2)))
                rt.submit(res_task,
                          in_=[("blk", gy2, bx2, it)
                               for gy2 in range(r2 * nby, (r2 + 1) * nby)
                               for bx2 in range(nbx)],
                          label="comm", name=f"res[{r2}]@{it}")

    rt.taskwait()
    stats = dict(rt.stats)
    # Resolve event-bound handles and check every rank saw the same value.
    res_by_it: Dict[int, float] = {}
    for (r2, it2), v in sorted(residuals.items()):
        if isinstance(v, tac.AsyncHandle):
            v = float(v.result)
        prev = res_by_it.setdefault(it2, v)
        assert abs(prev - v) < 1e-9, ("residual disagreement", it2, prev, v)
    stats["residuals"] = res_by_it
    rt.close()
    return np.block(grids[iters]), stats


# ---------------------------------------------------------------------------
# simulated scaling (paper Figs. 9/11/12/13)
# ---------------------------------------------------------------------------
def build_sim_graph(version, *, n_ranks, nby, nbx, iters,
                    t_block=1.0, t_comm=0.05, latency=0.1):
    tasks: List[SimTask] = []
    index: Dict[str, int] = {}

    def add(rank, compute, kind=COMPUTE, start=(), events=(), name="",
            group=None, group_latency=0.0):
        t = SimTask(len(tasks), rank, compute, kind=kind,
                    start_deps=[(index[s], 0.0) for s in start
                                if s and s in index],
                    event_deps=[(index[e], latency) for e in events
                                if e and e in index], name=name,
                    group=group, group_latency=group_latency)
        tasks.append(t)
        index[name] = t.id

    comm_kind = {"sentinel": COMM_HELD, "interop-blk": COMM_PAUSED,
                 "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
    NY = n_ranks * nby
    last_comm = [None] * n_ranks

    for it in range(iters):
        if version not in ("pure", "forkjoin"):
            # sends (chained for sentinel), then receives
            sends, recvs = [], []
            for r in range(n_ranks):
                for bx in range(nbx):
                    if r > 0:
                        gy = r * nby
                        sends.append((r - 1, f"c[{gy - 1},{bx}]@{it}",
                                      f"sd[{gy - 1},{bx}]@{it}"))
                        recvs.append((r, f"sd[{gy - 1},{bx}]@{it}",
                                      f"rt[{gy},{bx}]@{it}"))
                    if r < n_ranks - 1:
                        gy = r * nby + nby - 1
                        sends.append((r + 1,
                                      f"c[{gy + 1},{bx}]@{it - 1}" if it
                                      else "", f"su[{gy + 1},{bx}]@{it}"))
                        recvs.append((r, f"su[{gy + 1},{bx}]@{it}",
                                      f"rb[{gy},{bx}]@{it}"))
            for rank, dep, name in sends:
                chain = last_comm[rank] if version == "sentinel" else None
                add(rank, t_comm, kind=COMPUTE,   # send is buffered: cheap
                    start=[dep, chain or ""], name=name)
                if version == "sentinel":
                    last_comm[rank] = name
            for rank, ev, name in recvs:
                chain = last_comm[rank] if version == "sentinel" else None
                add(rank, t_comm, kind=comm_kind, start=[chain or ""],
                    events=[ev], name=name)
                if version == "sentinel":
                    last_comm[rank] = name

        for r in range(n_ranks):
            for ly in range(nby):
                gy = r * nby + ly
                for bx in range(nbx):
                    deps = []
                    if it:
                        deps.append(f"c[{gy},{bx}]@{it - 1}")
                        if version == "forkjoin":
                            deps.append(f"barrier@{it - 1}")
                        if bx + 1 < nbx:
                            deps.append(f"c[{gy},{bx + 1}]@{it - 1}")
                        if gy + 1 < NY:
                            if (gy + 1) // nby == r or version in (
                                    "pure", "forkjoin"):
                                deps.append(f"c[{gy + 1},{bx}]@{it - 1}")
                            else:
                                deps.append(f"rb[{gy},{bx}]@{it}")
                    if bx > 0:
                        deps.append(f"c[{gy},{bx - 1}]@{it}")
                    if gy > 0:
                        if (gy - 1) // nby == r:
                            deps.append(f"c[{gy - 1},{bx}]@{it}")
                        elif version in ("pure", "forkjoin"):
                            # sequential whole-boundary exchange: rank r
                            # waits for rank r-1's ENTIRE iteration (the
                            # Fig. 10a cascade)
                            deps.extend(f"c[{gy - 1},{b2}]@{it}"
                                        for b2 in range(nbx))
                        else:
                            deps.append(f"rt[{gy},{bx}]@{it}")
                    add(r, t_block, start=deps, name=f"c[{gy},{bx}]@{it}")

        if version == "forkjoin":
            for r2 in range(n_ranks):
                add(r2, 0.0,
                    start=[f"c[{r2 * nby + ly},{bx}]@{it}"
                           for ly in range(nby) for bx in range(nbx)],
                    name=f"b[{r2}]@{it}")
            add(0, 0.0, start=[f"b[{r2}]@{it}" for r2 in range(n_ranks)],
                name=f"barrier@{it}")

        # residual allreduce: one collective node per rank per iteration
        res_kind = {"interop-blk": COMM_PAUSED,
                    "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
        res_lat = n_rounds("allreduce", "doubling", n_ranks) * latency
        for r in range(n_ranks):
            deps = [f"c[{r * nby + ly},{bx}]@{it}"
                    for ly in range(nby) for bx in range(nbx)]
            if version == "forkjoin":
                deps.append(f"barrier@{it}")
            if version == "sentinel":
                deps.append(last_comm[r] or "")
            add(r, t_comm, kind=res_kind, start=deps,
                group=f"res@{it}", group_latency=res_lat,
                name=f"res[{r}]@{it}")
            if version == "sentinel":
                last_comm[r] = f"res[{r}]@{it}"
    return tasks


def simulate_version(version, *, n_ranks, workers=48, nby=4, nbx=16,
                     iters=10, t_block=1.0):
    if version == "pure":
        workers = 1   # Pure MPI: one sequential flow per rank
    tasks = build_sim_graph(version, n_ranks=n_ranks, nby=nby, nbx=nbx,
                            iters=iters, t_block=t_block)
    sim = Simulator(n_ranks, workers, task_overhead=0.002,
                    resume_overhead=0.01)
    return sim.run(tasks).makespan


# ---------------------------------------------------------------------------
def bench(print_fn=print):
    rows = []
    ref, ref_stats = run_real("pure")
    for v in VERSIONS[1:]:
        out, st = run_real(v)
        err = float(np.abs(out - ref).max())
        assert err < 1e-10, (v, err)
        for it, val in ref_stats["residuals"].items():
            assert abs(st["residuals"][it] - val) < 1e-9, (v, it)

    for v in VERSIONS:
        t0 = time.monotonic()
        _, stats = run_real(v)
        dt = (time.monotonic() - t0) / 3
        rows.append((f"gs_real_{v}", dt * 1e6,
                     f"blocks={stats.get('task_blocks', 0)}"
                     f";threads={stats.get('threads_spawned', 0)}"))

    # strong scaling (Fig. 9): fixed 32 block-rows total, split over ranks
    base_s = simulate_version("pure", n_ranks=1, nby=32)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            mk = simulate_version(v, n_ranks=n, nby=32 // n)
            rows.append((f"gs_strong_{v}_r{n}", mk * 1e6,
                         f"speedup={base_s / mk:.2f}"))

    # weak scaling (Fig. 11): 4 block-rows per rank
    base_w = simulate_version("pure", n_ranks=1)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            mk = simulate_version(v, n_ranks=n)
            rows.append((f"gs_weak_{v}_r{n}", mk * 1e6,
                         f"efficiency={base_w / mk:.2f}"))

    base6 = simulate_version("pure", n_ranks=1, iters=6)
    for v in ("interop-blk", "interop-nonblk"):
        for scale, label in ((1, "1024bs"), (2, "512bs"), (4, "256bs")):
            mk = simulate_version(v, n_ranks=8, nby=4 * scale,
                                  nbx=16 * scale, iters=6,
                                  t_block=1.0 / (scale * scale))
            rows.append((f"gs_gran_{v}_{label}", mk * 1e6,
                         f"speedup={base6 / mk:.2f}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench()
