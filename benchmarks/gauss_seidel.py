"""Gauss–Seidel / heat-equation benchmark — paper §7.1 (Figs. 9–13).

Five versions of the blocked Gauss–Seidel iteration, mirroring the paper:

* ``pure``            — sequential compute per rank, sequential halo
                        exchange between iterations (Pure MPI).
* ``forkjoin``        — parallel compute tasks; sequential communication
                        phase in the main thread; a taskwait barrier per
                        iteration.
* ``sentinel``        — taskified communication serialised by an artificial
                        sentinel dependency (what you must write WITHOUT
                        TASK_MULTIPLE, §6.3): one chained task drives each
                        whole halo round and the residual collective.
* ``interop-blk``     — TAMPI blocking mode: per-rank halo tasks use
                        task-aware waits (pause/resume); no artificial
                        dependencies.
* ``interop-nonblk``  — TAMPI non-blocking mode: per-rank halo tasks bind
                        the exchange to their event counter and finish
                        immediately; boundary compute declares dependencies.

Communication structure (since the sub-communicator PR): ranks form a 2-D
Cartesian grid (``CommWorld.cart_create``) and each rank owns a tile of
``nby × nbx`` blocks.  The per-block point-to-point wiring of the previous
revision is replaced by ONE :class:`~repro.core.collectives.HaloExchange`
round per rank per iteration — boundary rows/columns travel as single
per-neighbour messages.  Cross-rank coupling therefore uses iteration
``t-1`` data in every version (the classic halo-exchange hybrid:
Gauss–Seidel wavefront *inside* a rank, Jacobi coupling *across* ranks),
which is what makes the whole exchange postable at once.  The per-iteration
global residual runs through the hierarchical allreduce
(:class:`~repro.core.collectives.HierarchicalCollectives` — intra-row
chain + inter-leader doubling over two nested groups built by
``CommWorld.split``).

Measurements: (a) REAL execution on the host task runtime at small scale
(all versions must agree numerically); (b) deterministic makespans of the
same task DAGs under the paper's machine model (core/simulate.py) — the
scaling curves.  Halo rounds appear in the simulated graphs as
neighbourhood nodes (``SimTask(neighbors=...)``).  CSV schema:
name,us_per_call,derived
"""

from __future__ import annotations

import math
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (Collectives, HaloExchange, HierarchicalCollectives,
                        TaskRuntime, tac)
from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_HELD,
                                 COMM_PAUSED, COMM_EVENTS)

VERSIONS = ("pure", "forkjoin", "sentinel", "interop-blk", "interop-nonblk")


def grid_dims(n_ranks: int) -> Tuple[int, int]:
    """Most-square 2-D factorization of ``n_ranks`` (py >= px)."""
    for d in range(int(math.isqrt(n_ranks)), 0, -1):
        if n_ranks % d == 0:
            return (n_ranks // d, d)
    return (n_ranks, 1)


def edge_blocks(cart, nby, nbx, r, d):
    """Block coordinates of rank ``r``'s tile edge facing direction ``d``.

    The single source of boundary geometry shared by the real execution
    (halo payloads, task deps) and the simulated graph — an edit here
    changes both sides together.
    """
    ry, rx = cart.coords(r)
    dim, disp = d
    if dim == 0:
        gy = ry * nby if disp < 0 else (ry + 1) * nby - 1
        return [(gy, rx * nbx + j) for j in range(nbx)]
    gx = rx * nbx if disp < 0 else (rx + 1) * nbx - 1
    return [(ry * nby + i, gx) for i in range(nby)]


def gs_block(block, top, left, bottom, right):
    padded = np.pad(block, 1)
    padded[0, 1:-1] = top
    padded[-1, 1:-1] = bottom
    padded[1:-1, 0] = left
    padded[1:-1, -1] = right
    return 0.25 * (padded[:-2, 1:-1] + padded[2:, 1:-1]
                   + padded[1:-1, :-2] + padded[1:-1, 2:])


# ---------------------------------------------------------------------------
# real execution on the host runtime
# ---------------------------------------------------------------------------
def run_real(version: str, *, n_ranks: int = 4, workers: int = 2,
             nby: int = 2, nbx: int = 2, bs: int = 16, iters: int = 3,
             seed: int = 0, notify: str = None, block_impl: str = None):
    """Returns (final grid, stats).

    ``notify`` picks the runtime's completion-notification backend
    ("polling" / "continuation"; None = the REPRO_NOTIFY env default) —
    the end-to-end parity legs run the same benchmark under both.

    ``block_impl`` routes the per-block stage through the fused stencil
    kernel (:func:`repro.kernels.ops.gs_stencil`;
    "ref"/"pallas_interpret"/"pallas"): ONE pass over the block produces
    the interior update, the rank-local residual contribution AND the
    four packed boundary edges — the halo payloads and residual sums are
    then read from the per-block caches instead of re-slicing and
    re-reading the grids (the unfused path's extra passes).  The kernel
    computes in fp32; ``None`` keeps the float64 numpy path bit-exact.

    Dataflow: grids[it][gy][gx]; block (gy,gx) at iteration it reads
    up/left from iteration it when the neighbour block is on the SAME
    rank (spatial wavefront) and self/down/right from it-1; every
    cross-rank side reads the neighbour rank's it-1 boundary, delivered
    by that iteration's halo exchange.
    """
    if block_impl is not None:
        import jax.numpy as jnp
        from repro.kernels import ops as kernel_ops
    py, px = grid_dims(n_ranks)
    NYb, NXb = py * nby, px * nbx
    rng = np.random.default_rng(seed)
    grids: Dict[int, list] = {
        0: [[rng.standard_normal((bs, bs)) for _ in range(NXb)]
            for _ in range(NYb)]}
    for it in range(1, iters + 1):
        grids[it] = [[None] * NXb for _ in range(NYb)]
    zeros = np.zeros(bs)

    world = tac.CommWorld(n_ranks)
    cart = world.cart_create((py, px))
    hx = HaloExchange(cart)
    hier = HierarchicalCollectives(world, px)   # intra-row + leader column
    # persistent residual allreduce (MPI_Allreduce_init analogue): the
    # three-stage hierarchical schedule is resolved once and re-posted
    # every iteration with key=("res", it).
    residual_coll = hier.persistent(op="sum")
    halos: Dict = {}       # (rank, it) -> {direction: edge} | handle
    residuals: Dict = {}   # (rank, it) -> float | CollectiveHandle
    res_cache: Dict = {}   # (gy, gx, it) -> fused per-block residual
    edge_cache: Dict = {}  # (gy, gx, it) -> (top, bottom, left, right)
    tac.init(tac.TASK_MULTIPLE if version.startswith("interop")
             else tac.THREAD_MULTIPLE)
    rt = TaskRuntime(num_workers=workers, notify=notify)
    rt.start()

    def rank_of(gy, gx):
        return cart.rank_at((gy // nby, gx // nbx))

    def packed_edge(gy, gx, it, d):
        """A boundary edge from the fused kernel's boundary-pack output."""
        te, be, le, re = edge_cache[(gy, gx, it)]
        dim, disp = d
        if dim == 0:
            return te if disp < 0 else be
        return le if disp < 0 else re

    def halo_sends(r, it):
        """Outgoing it-1 boundary edges, one concatenated array per
        neighbour direction.  On the fused path the edges come packed
        from the stencil kernel's boundary outputs (no grid re-slice);
        iteration 0 has no kernel pass, so its edges slice the initial
        grid as usual."""
        out = {}
        for d, _ in hx.neighbors(r):
            cells = edge_blocks(cart, nby, nbx, r, d)
            if block_impl is not None and \
                    (cells[0] + (it - 1,)) in edge_cache:
                out[d] = np.concatenate(
                    [packed_edge(gy, gx, it - 1, d) for gy, gx in cells])
                continue
            dim, disp = d
            edge = 0 if disp < 0 else -1
            out[d] = np.concatenate(
                [grids[it - 1][gy][gx][edge, :].copy() if dim == 0
                 else grids[it - 1][gy][gx][:, edge].copy()
                 for gy, gx in cells])
        return out

    def boundary_blocks(r):
        """Block coordinates whose it-1 data feeds r's outgoing halos."""
        keys = set()
        for d, _ in hx.neighbors(r):
            keys.update(edge_blocks(cart, nby, nbx, r, d))
        return sorted(keys)

    def halo_edge(r, it, d, offset):
        h = halos[(r, it)]
        if isinstance(h, tac.AsyncHandle):
            h = h.result
        return h[d][offset * bs:(offset + 1) * bs]

    def compute_block(gy, gx, it):
        r = rank_of(gy, gx)
        ry, rx = gy // nby, gx // nbx
        g_cur, g_prev = grids[it], grids[it - 1]
        if gy == 0:
            top = zeros
        elif gy % nby == 0:
            top = halo_edge(r, it, (0, -1), gx - rx * nbx)
        else:
            top = g_cur[gy - 1][gx][-1, :]
        if gx == 0:
            left = zeros
        elif gx % nbx == 0:
            left = halo_edge(r, it, (1, -1), gy - ry * nby)
        else:
            left = g_cur[gy][gx - 1][:, -1]
        if gy == NYb - 1:
            bottom = zeros
        elif (gy + 1) % nby == 0:
            bottom = halo_edge(r, it, (0, 1), gx - rx * nbx)
        else:
            bottom = g_prev[gy + 1][gx][0, :]
        if gx == NXb - 1:
            right = zeros
        elif (gx + 1) % nbx == 0:
            right = halo_edge(r, it, (1, 1), gy - ry * nby)
        else:
            right = g_prev[gy][gx + 1][:, 0]
        if block_impl is None:
            grids[it][gy][gx] = gs_block(g_prev[gy][gx], top, left,
                                         bottom, right)
            return
        new, res, edges = kernel_ops.gs_stencil(
            jnp.asarray(g_prev[gy][gx], jnp.float32),
            jnp.asarray(top, jnp.float32), jnp.asarray(left, jnp.float32),
            jnp.asarray(bottom, jnp.float32),
            jnp.asarray(right, jnp.float32), impl=block_impl)
        grids[it][gy][gx] = np.asarray(new, np.float64)
        res_cache[(gy, gx, it)] = float(res)
        edge_cache[(gy, gx, it)] = tuple(np.asarray(e, np.float64)
                                         for e in edges)

    def block_deps(gy, gx, it):
        """Region deps for the compute task (task versions only)."""
        r = rank_of(gy, gx)
        deps = [("blk", gy, gx, it - 1)]
        crosses = False
        if gy > 0:
            if gy % nby:
                deps.append(("blk", gy - 1, gx, it))
            else:
                crosses = True
        if gx > 0:
            if gx % nbx:
                deps.append(("blk", gy, gx - 1, it))
            else:
                crosses = True
        if gy < NYb - 1:
            if (gy + 1) % nby:
                deps.append(("blk", gy + 1, gx, it - 1))
            else:
                crosses = True
        if gx < NXb - 1:
            if (gx + 1) % nbx:
                deps.append(("blk", gy, gx + 1, it - 1))
            else:
                crosses = True
        if crosses:
            deps.append(("halo", r, it))
        return deps

    def local_residual(r, it):
        ry, rx = cart.coords(r)
        tot = 0.0
        for gy in range(ry * nby, (ry + 1) * nby):
            for gx in range(rx * nbx, (rx + 1) * nbx):
                if block_impl is not None:
                    # fused path: the kernel already produced the
                    # per-block |new - old| sum — no grid re-read.
                    tot += res_cache[(gy, gx, it)]
                else:
                    tot += float(np.abs(grids[it][gy][gx]
                                        - grids[it - 1][gy][gx]).sum())
        return np.float64(tot)

    for it in range(1, iters + 1):
        # ---- halo phase --------------------------------------------------
        if version in ("pure", "forkjoin"):
            if version == "forkjoin":
                rt.taskwait()   # barrier: previous iteration fully done
            got = hx.run_group([halo_sends(r, it) for r in range(n_ranks)],
                               key=("h", it))
            for r in range(n_ranks):
                halos[(r, it)] = got[r]
        elif version == "sentinel":
            # Without TASK_MULTIPLE a blocking halo round inside per-rank
            # tasks would deadlock (§5) — the whole neighbourhood
            # collective is serialised into the sentinel chain instead.
            def halo_group(it2=it):
                got = hx.run_group(
                    [halo_sends(r, it2) for r in range(n_ranks)],
                    key=("h", it2))
                for r in range(n_ranks):
                    halos[(r, it2)] = got[r]
            rt.submit(halo_group,
                      in_=[("blk", gy, gx, it - 1)
                           for r in range(n_ranks)
                           for gy, gx in boundary_blocks(r)],
                      out=[("halo", r, it) for r in range(n_ranks)],
                      inout=[("comm-sentinel",)], label="comm",
                      name=f"halo@{it}")
        else:
            mode = "event" if version == "interop-nonblk" else "blocking"

            def halo_task(r, it2=it, mode=mode):
                def body():
                    halos[(r, it2)] = hx.start(halo_sends(r, it2), rank=r,
                                               mode=mode, key=("h", it2))
                return body
            for r in range(n_ranks):
                rt.submit(halo_task(r),
                          in_=[("blk", gy, gx, it - 1)
                               for gy, gx in boundary_blocks(r)],
                          out=[("halo", r, it)], label="comm",
                          name=f"halo[{r}]@{it}", rank=r)

        # ---- compute phase (intra-rank wavefront) ------------------------
        for gy in range(NYb):
            for gx in range(NXb):
                if version == "pure":
                    compute_block(gy, gx, it)
                else:
                    rt.submit(compute_block, gy, gx, it,
                              out=[("blk", gy, gx, it)],
                              in_=block_deps(gy, gx, it),
                              label="compute", name=f"c[{gy},{gx}]@{it}",
                              rank=rank_of(gy, gx))

        # ---- global residual: hierarchical allreduce ---------------------
        if version in ("pure", "forkjoin"):
            if version == "forkjoin":
                rt.taskwait()       # fork-join: iteration fully done
            vals = residual_coll.run_group(
                [local_residual(r, it) for r in range(n_ranks)],
                key=("res", it))
            for r in range(n_ranks):
                residuals[(r, it)] = float(vals[r])
        elif version == "sentinel":
            def res_group(it2=it):
                vals = residual_coll.run_group(
                    [local_residual(r, it2) for r in range(n_ranks)],
                    key=("res", it2))
                for r in range(n_ranks):
                    residuals[(r, it2)] = float(vals[r])
            rt.submit(res_group,
                      in_=[("blk", gy, gx, it) for gy in range(NYb)
                           for gx in range(NXb)],
                      inout=[("comm-sentinel",)], label="comm",
                      name=f"res@{it}")
        else:
            for r in range(n_ranks):
                def res_task(r=r, it2=it):
                    v = local_residual(r, it2)
                    if version == "interop-nonblk":
                        residuals[(r, it2)] = residual_coll.start(
                            v, rank=r, mode="event", key=("res", it2))
                    else:
                        residuals[(r, it2)] = float(residual_coll.start(
                            v, rank=r, mode="blocking", key=("res", it2)))
                ry, rx = cart.coords(r)
                rt.submit(res_task,
                          in_=[("blk", gy, gx, it)
                               for gy in range(ry * nby, (ry + 1) * nby)
                               for gx in range(rx * nbx, (rx + 1) * nbx)],
                          label="comm", name=f"res[{r}]@{it}", rank=r)

    rt.taskwait()
    stats = dict(rt.stats)
    # Resolve event-bound handles and check every rank saw the same value.
    res_by_it: Dict[int, float] = {}
    for (r, it), v in sorted(residuals.items()):
        if isinstance(v, tac.AsyncHandle):
            v = float(v.result)
        prev = res_by_it.setdefault(it, v)
        assert abs(prev - v) < 1e-9, ("residual disagreement", it, prev, v)
    stats["residuals"] = res_by_it
    rt.close()
    return np.block(grids[iters]), stats


# ---------------------------------------------------------------------------
# elastic execution: checkpoint / injected rank death / shrink / resume
# ---------------------------------------------------------------------------
def _blocks_of(grid: np.ndarray, NYb: int, NXb: int, bs: int):
    """Re-block a global grid into the benchmark's NYb x NXb tile list."""
    return [[grid[gy * bs:(gy + 1) * bs, gx * bs:(gx + 1) * bs].copy()
             for gx in range(NXb)] for gy in range(NYb)]


def _elastic_iteration(cart, hx, coll, prev, *, nby, nbx, bs, mode, rt, it):
    """One halo-coupled Gauss–Seidel iteration over ANY decomposition.

    ``prev`` is the previous iteration's global block list; returns
    ``(next block list, global residual)``.  Per-rank halo tasks post the
    neighbourhood exchange in TAMPI mode ``mode``; per-rank residual
    tasks run the allreduce over the (possibly shrunken) communicator.
    An injected rank death surfaces here as
    :class:`~repro.core.executor.TaskError` out of the taskwait — the
    machine that observes the dead peer revokes the communicator, so
    every surviving task fails promptly instead of parking.
    """
    n_ranks = cart.size
    NYb, NXb = len(prev), len(prev[0])
    cur = [[None] * NXb for _ in range(NYb)]
    zeros = np.zeros(bs)
    halos: Dict[int, object] = {}
    res: Dict[int, object] = {}

    def rank_of(gy, gx):
        return cart.rank_at((gy // nby, gx // nbx))

    def halo_sends(r):
        out = {}
        for d, _ in hx.neighbors(r):
            dim, disp = d
            edge = 0 if disp < 0 else -1
            out[d] = np.concatenate(
                [prev[gy][gx][edge, :].copy() if dim == 0
                 else prev[gy][gx][:, edge].copy()
                 for gy, gx in edge_blocks(cart, nby, nbx, r, d)])
        return out

    def halo_edge(r, d, offset):
        h = halos[r]
        if isinstance(h, tac.AsyncHandle):
            h = h.result
        return h[d][offset * bs:(offset + 1) * bs]

    def compute_block(gy, gx):
        r = rank_of(gy, gx)
        ry, rx = gy // nby, gx // nbx
        if gy == 0:
            top = zeros
        elif gy % nby == 0:
            top = halo_edge(r, (0, -1), gx - rx * nbx)
        else:
            top = cur[gy - 1][gx][-1, :]
        if gx == 0:
            left = zeros
        elif gx % nbx == 0:
            left = halo_edge(r, (1, -1), gy - ry * nby)
        else:
            left = cur[gy][gx - 1][:, -1]
        if gy == NYb - 1:
            bottom = zeros
        elif (gy + 1) % nby == 0:
            bottom = halo_edge(r, (0, 1), gx - rx * nbx)
        else:
            bottom = prev[gy + 1][gx][0, :]
        if gx == NXb - 1:
            right = zeros
        elif (gx + 1) % nbx == 0:
            right = halo_edge(r, (1, 1), gy - ry * nby)
        else:
            right = prev[gy][gx + 1][:, 0]
        cur[gy][gx] = gs_block(prev[gy][gx], top, left, bottom, right)

    def halo_task(r):
        def body():
            halos[r] = hx.start(halo_sends(r), rank=r, mode=mode,
                                key=("eh", it))
        return body

    for r in range(n_ranks):
        rt.submit(halo_task(r), out=[("halo", r, it)], label="comm",
                  name=f"ehalo[{r}]@{it}", rank=r)
    for gy in range(NYb):
        for gx in range(NXb):
            r = rank_of(gy, gx)
            deps = [("halo", r, it)]
            if gy % nby:
                deps.append(("blk", gy - 1, gx, it))
            if gx % nbx:
                deps.append(("blk", gy, gx - 1, it))
            rt.submit(compute_block, gy, gx, in_=deps,
                      out=[("blk", gy, gx, it)], label="compute",
                      name=f"ec[{gy},{gx}]@{it}", rank=r)
    for r in range(n_ranks):
        def res_task(r=r):
            ry, rx = cart.coords(r)
            tot = np.float64(sum(
                float(np.abs(cur[gy][gx] - prev[gy][gx]).sum())
                for gy in range(ry * nby, (ry + 1) * nby)
                for gx in range(rx * nbx, (rx + 1) * nbx)))
            res[r] = coll.allreduce(tot, rank=r, mode=mode, key=("er", it))
        ry, rx = cart.coords(r)
        rt.submit(res_task,
                  in_=[("blk", gy, gx, it)
                       for gy in range(ry * nby, (ry + 1) * nby)
                       for gx in range(rx * nbx, (rx + 1) * nbx)],
                  label="comm", name=f"eres[{r}]@{it}", rank=r)
    rt.taskwait()
    vals = {r: float(v.result if isinstance(v, tac.AsyncHandle) else v)
            for r, v in res.items()}
    first = next(iter(vals.values()))
    assert all(abs(v - first) < 1e-9 for v in vals.values()), vals
    return cur, first


def run_elastic(ckpt_dir: str, *, n_ranks: int = 4, workers: int = 2,
                nby: int = 3, nbx: int = 3, bs: int = 8, iters: int = 4,
                kill_iter: int = None, kill_rank: int = 0,
                kill_after_ops: int = 1, mode: str = "event",
                notify: str = None, seed: int = 0):
    """Fault-tolerant Gauss–Seidel: the ULFM recovery loop end to end.

    Every completed iteration checkpoints the global grid to
    ``ckpt_dir`` (mesh-agnostic — the restore side may re-decompose).
    With ``kill_iter`` set, a :class:`~repro.core.resilience.FaultInjector`
    arms rank ``kill_rank`` to die at its ``kill_after_ops``-th posted
    operation of that iteration (mid-halo / mid-collective); the failure
    surfaces out of the taskwait, the survivors revoke + shrink
    (:func:`repro.core.resilience.recover`), re-shape as a fresh
    Cartesian grid over whatever decomposition divides the global blocks,
    and resume from the last completed checkpoint step.  If a run starts
    with checkpoints already in ``ckpt_dir`` it resumes from the latest
    (which is how the parity test builds its clean reference).

    Returns ``(final grid, info)`` where ``info`` records the residual
    per completed step, the surviving decomposition, and each recovery.
    """
    from repro import checkpoint as checkpoint_lib
    from repro.core import resilience
    from repro.core.executor import TaskError

    py, px = grid_dims(n_ranks)
    NYb, NXb = py * nby, px * nbx
    world = tac.CommWorld(n_ranks)
    injector = resilience.FaultInjector(world)
    tac.init(tac.TASK_MULTIPLE)

    step = checkpoint_lib.latest_step(ckpt_dir)
    if step is None:
        rng = np.random.default_rng(seed)
        grid = np.block([[rng.standard_normal((bs, bs))
                          for _ in range(NXb)] for _ in range(NYb)])
        checkpoint_lib.save_checkpoint(ckpt_dir, {"grid": grid}, 0)
        step = 0
    else:
        state, step = checkpoint_lib.restore_checkpoint(
            ckpt_dir, {"grid": np.empty((NYb * bs, NXb * bs))})
        grid = state["grid"]

    def shape_over(group_or_world, n):
        spy, spx = grid_dims(n)
        if NYb % spy or NXb % spx:
            raise ValueError(f"global {NYb}x{NXb} blocks do not divide "
                             f"over {n} survivors ({spy}x{spx})")
        cart = (group_or_world.cart((spy, spx))
                if hasattr(group_or_world, "cart")
                else group_or_world.cart_create((spy, spx)))
        return cart, NYb // spy, NXb // spx

    cart, cur_nby, cur_nbx = shape_over(world, n_ranks)
    hx, coll = HaloExchange(cart), Collectives(cart)
    rt = TaskRuntime(num_workers=workers, notify=notify)
    rt.start()
    info = {"residuals": {}, "recoveries": []}

    try:
        while step < iters:
            it = step + 1
            if kill_iter is not None and it == kill_iter \
                    and not injector.killed:
                injector.arm(kill_rank, after_ops=kill_after_ops)
            try:
                blocks, resid = _elastic_iteration(
                    cart, hx, coll, _blocks_of(grid, NYb, NXb, bs),
                    nby=cur_nby, nbx=cur_nbx, bs=bs, mode=mode, rt=rt,
                    it=it)
            except TaskError:
                # ULFM recovery: revoke (unstick peers), shrink
                # (agreement on the survivors), re-decompose, restore.
                injector.disarm()
                rt.close()
                shrunk = resilience.recover(world)
                cart, cur_nby, cur_nbx = shape_over(shrunk, shrunk.size)
                hx, coll = HaloExchange(cart), Collectives(cart)
                rt = TaskRuntime(num_workers=workers, notify=notify)
                rt.start()
                state, step = checkpoint_lib.restore_checkpoint(
                    ckpt_dir, {"grid": np.empty((NYb * bs, NXb * bs))})
                grid = state["grid"]
                info["recoveries"].append(
                    {"at_iter": it, "killed": list(world.failed),
                     "survivors": cart.size, "resumed_step": step})
                continue
            grid = np.block(blocks)
            step = it
            info["residuals"][step] = resid
            checkpoint_lib.save_checkpoint(ckpt_dir, {"grid": grid}, step)
    finally:
        rt.close()
    info["decomposition"] = (cart.size, cur_nby, cur_nbx)
    return grid, info


# ---------------------------------------------------------------------------
# simulated scaling (paper Figs. 9/11/12/13)
# ---------------------------------------------------------------------------
def build_sim_graph(version, *, n_ranks, nby, nbx, iters,
                    t_block=1.0, t_comm=0.05, latency=0.1):
    py, px = grid_dims(n_ranks)
    world = tac.CommWorld(n_ranks)
    cart = world.cart_create((py, px))
    NYb, NXb = py * nby, px * nbx
    tasks: List[SimTask] = []
    index: Dict[str, int] = {}

    def add(rank, compute, kind=COMPUTE, start=(), events=(), name="",
            group=None, group_latency=0.0):
        t = SimTask(len(tasks), rank, compute, kind=kind,
                    start_deps=[(index[s], 0.0) for s in start
                                if s and s in index],
                    event_deps=[(index[e], latency) for e in events
                                if e and e in index], name=name,
                    group=group, group_latency=group_latency)
        tasks.append(t)
        index[name] = t.id

    def rank_of(gy, gx):
        return cart.rank_at((gy // nby, gx // nbx))

    comm_kind = {"sentinel": COMM_HELD, "interop-blk": COMM_PAUSED,
                 "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
    # hierarchical residual latency from the schedule IR's α-β cost model
    # (α = per-message latency, wires/combines free — the pure-latency
    # point of the model, identical to the old rounds × latency count)
    res_lat = HierarchicalCollectives(world, px).cost(latency, 0.0, 0)
    last_comm = [None] * n_ranks

    def boundary_names(r, it):
        keys = set()
        for d, _ in cart.neighbor_dirs(r):
            keys.update(edge_blocks(cart, nby, nbx, r, d))
        return [f"c[{gy},{gx}]@{it}" for gy, gx in sorted(keys)]

    for it in range(iters):
        # one neighbourhood-collective node per rank per iteration; the
        # version decides how tightly it is gated:
        #   pure      — after the rank's ENTIRE previous iteration (comm
        #               phase follows compute phase, one flow per rank)
        #   forkjoin  — after the global barrier (main-thread comm phase)
        #   sentinel  — chained on the rank's previous comm task
        #   interop-* — only after the boundary blocks it actually ships
        for r in range(n_ranks):
            if not it:
                start = []
            elif version == "pure":
                ry, rx = cart.coords(r)
                start = [f"c[{gy},{gx}]@{it - 1}"
                         for gy in range(ry * nby, (ry + 1) * nby)
                         for gx in range(rx * nbx, (rx + 1) * nbx)]
            elif version == "forkjoin":
                start = [f"barrier@{it - 1}"]
            else:
                start = boundary_names(r, it - 1)
            if version == "sentinel":
                start = start + [last_comm[r] or ""]
            add(r, t_comm, kind=comm_kind, start=start,
                name=f"h[{r}]@{it}")
            if version == "sentinel":
                last_comm[r] = f"h[{r}]@{it}"
        for r in range(n_ranks):
            tasks[index[f"h[{r}]@{it}"]].neighbors = [
                (index[f"h[{nbr}]@{it}"], latency)
                for nbr in cart.neighbors(r)]

        for gy in range(NYb):
            for gx in range(NXb):
                r = rank_of(gy, gx)
                deps = []
                crosses = False
                if it:
                    deps.append(f"c[{gy},{gx}]@{it - 1}")
                    if version == "forkjoin":
                        deps.append(f"barrier@{it - 1}")
                if gy > 0:
                    if gy % nby:
                        deps.append(f"c[{gy - 1},{gx}]@{it}")
                    else:
                        crosses = True
                if gx > 0:
                    if gx % nbx:
                        deps.append(f"c[{gy},{gx - 1}]@{it}")
                    else:
                        crosses = True
                if gy < NYb - 1:
                    if (gy + 1) % nby:
                        deps.append(f"c[{gy + 1},{gx}]@{it - 1}")
                    else:
                        crosses = True
                if gx < NXb - 1:
                    if (gx + 1) % nbx:
                        deps.append(f"c[{gy},{gx + 1}]@{it - 1}")
                    else:
                        crosses = True
                if crosses:
                    # it == 0 still crosses: the first round ships the
                    # initial boundary data (it reads nothing, so the
                    # filtered @-1 deps leave it immediately ready)
                    deps.append(f"h[{r}]@{it}")
                add(r, t_block, start=deps, name=f"c[{gy},{gx}]@{it}")

        if version == "forkjoin":
            for r in range(n_ranks):
                ry, rx = cart.coords(r)
                add(r, 0.0,
                    start=[f"c[{gy},{gx}]@{it}"
                           for gy in range(ry * nby, (ry + 1) * nby)
                           for gx in range(rx * nbx, (rx + 1) * nbx)],
                    name=f"b[{r}]@{it}")
            add(0, 0.0, start=[f"b[{r}]@{it}" for r in range(n_ranks)],
                name=f"barrier@{it}")

        # residual allreduce: one collective node per rank per iteration
        res_kind = {"interop-blk": COMM_PAUSED,
                    "interop-nonblk": COMM_EVENTS}.get(version, COMM_HELD)
        for r in range(n_ranks):
            ry, rx = cart.coords(r)
            deps = [f"c[{gy},{gx}]@{it}"
                    for gy in range(ry * nby, (ry + 1) * nby)
                    for gx in range(rx * nbx, (rx + 1) * nbx)]
            if version == "forkjoin":
                deps.append(f"barrier@{it}")
            if version == "sentinel":
                deps.append(last_comm[r] or "")
            add(r, t_comm, kind=res_kind, start=deps,
                group=f"res@{it}", group_latency=res_lat,
                name=f"res[{r}]@{it}")
            if version == "sentinel":
                last_comm[r] = f"res[{r}]@{it}"
    return tasks


def simulate_version(version, *, n_ranks, workers=48, nby=4, nbx=16,
                     iters=10, t_block=1.0):
    if version == "pure":
        workers = 1   # Pure MPI: one sequential flow per rank
    tasks = build_sim_graph(version, n_ranks=n_ranks, nby=nby, nbx=nbx,
                            iters=iters, t_block=t_block)
    sim = Simulator(n_ranks, workers, task_overhead=0.002,
                    resume_overhead=0.01)
    return sim.run(tasks).makespan


# ---------------------------------------------------------------------------
def bench(print_fn=print, smoke: bool = False):
    rows = []
    ref, ref_stats = run_real("pure")
    for v in VERSIONS[1:]:
        out, st = run_real(v)
        err = float(np.abs(out - ref).max())
        assert err < 1e-10, (v, err)
        for it, val in ref_stats["residuals"].items():
            assert abs(st["residuals"][it] - val) < 1e-9, (v, it)

    # end-to-end notification-backend legs: the same interop run under
    # the polling engine and the continuation engine must agree with the
    # pure reference bit for bit (and with each other).
    for v in ("interop-blk", "interop-nonblk"):
        for nb in ("polling", "continuation"):
            t0 = time.monotonic()
            out, _ = run_real(v, notify=nb)
            dt = (time.monotonic() - t0) / 3
            assert float(np.abs(out - ref).max()) < 1e-10, (v, nb)
            rows.append((f"gs_e2e_{v}_{nb}", dt * 1e6, "notify-leg"))

    # fused-stencil leg (Pallas executor tier): interior update, residual
    # and boundary-pack in ONE kernel pass per block — halo payloads and
    # residual sums come from the kernel outputs, not grid re-reads.  The
    # kernel computes in fp32, so the bound is fp32 epsilon (~1e-5 after
    # 3 iterations), not the float64 paths' 1e-10.
    t0 = time.monotonic()
    out_f, st_f = run_real("interop-nonblk", block_impl="pallas_interpret")
    dt = (time.monotonic() - t0) / 3
    err_f = float(np.abs(out_f - ref).max())
    assert err_f < 1e-4, err_f
    for it, val in ref_stats["residuals"].items():
        assert abs(st_f["residuals"][it] - val) <= 1e-4 * max(1.0, val), it
    rows.append(("gs_fused_stencil_interop", dt * 1e6,
                 f"maxerr={err_f:.1e}"))

    if smoke:
        # CI bench-smoke job: all five versions numerically agree (above)
        # and the schedule acceptance ordering holds on one simulated
        # point — event-bound strictly beats the sentinel serialisation.
        mks = {v: simulate_version(v, n_ranks=4, nby=4, nbx=4, iters=4)
               for v in VERSIONS}
        assert mks["interop-nonblk"] < mks["sentinel"], mks
        for v in VERSIONS:
            rows.append((f"gs_smoke_{v}", mks[v] * 1e6, "smoke"))
        for r in rows:
            print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
        return rows

    for v in VERSIONS:
        t0 = time.monotonic()
        _, stats = run_real(v)
        dt = (time.monotonic() - t0) / 3
        rows.append((f"gs_real_{v}", dt * 1e6,
                     f"blocks={stats.get('task_blocks', 0)}"
                     f";threads={stats.get('threads_spawned', 0)}"))

    # strong scaling (Fig. 9): fixed 8x8 global blocks, split over ranks
    base_s = simulate_version("pure", n_ranks=1, nby=8, nbx=8)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            py, px = grid_dims(n)
            mk = simulate_version(v, n_ranks=n, nby=8 // py, nbx=8 // px)
            rows.append((f"gs_strong_{v}_r{n}", mk * 1e6,
                         f"speedup={base_s / mk:.2f}"))

    # weak scaling (Fig. 11): 4x4 blocks per rank
    base_w = simulate_version("pure", n_ranks=1, nby=4, nbx=4)
    for v in VERSIONS:
        for n in (1, 2, 4, 8, 16):
            mk = simulate_version(v, n_ranks=n, nby=4, nbx=4)
            rows.append((f"gs_weak_{v}_r{n}", mk * 1e6,
                         f"efficiency={base_w / mk:.2f}"))

    base6 = simulate_version("pure", n_ranks=1, nby=4, nbx=4, iters=6)
    for v in ("interop-blk", "interop-nonblk"):
        for scale, label in ((1, "1024bs"), (2, "512bs"), (4, "256bs")):
            mk = simulate_version(v, n_ranks=8, nby=4 * scale,
                                  nbx=4 * scale, iters=6,
                                  t_block=1.0 / (scale * scale))
            rows.append((f"gs_gran_{v}_{label}", mk * 1e6,
                         f"speedup={base6 / mk:.2f}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


# ---------------------------------------------------------------------------
# traced leg: Perfetto timeline + overlap accounting (repro.obs)
# ---------------------------------------------------------------------------
def run_traced(trace_path: str, *, smoke: bool = False,
               print_fn=print) -> Dict[str, float]:
    """``--trace`` leg: real runs under the tracer, one Perfetto artifact.

    Runs the sentinel, interop-blk, and interop-nonblk versions under ONE
    :class:`repro.obs.Tracer` (so the exported timeline shows the three
    legs back to back), slices the event stream per leg, and derives the
    paper's headline number per leg — the overlap fraction (share of
    handle in-flight time covered by concurrent compute spans,
    :func:`repro.obs.analysis.overlap_fraction`).  Writes the trace-event
    JSON to ``trace_path`` with the derived metrics in ``otherData``.

    Hard acceptance checks (SystemExit on violation):

    * the document validates against ``repro.obs.SPAN_SCHEMA``;
    * the interop-blk leg recorded task pause spans (§4.1 pause/resume
      made visible);
    * the event-bound leg's overlap fraction is STRICTLY greater than
      the sentinel leg's (taskified-serialised comm cannot overlap).
    """
    from repro import obs

    params = dict(n_ranks=4, workers=2, nby=2, nbx=2,
                  bs=24 if smoke else 48, iters=3)
    legs = ("sentinel", "interop-blk", "interop-nonblk")
    windows: Dict[str, Tuple[float, float]] = {}
    with obs.tracing(capacity=1 << 18) as tr:
        for v in legs:
            t0 = (time.monotonic() - tr.epoch) * 1e6
            run_real(v, **params)
            windows[v] = (t0, (time.monotonic() - tr.epoch) * 1e6)
        events = tr.events()

    def leg_events(v):
        lo, hi = windows[v]
        return [e for e in events if lo <= e["ts"] < hi]

    overlaps = {v: obs.overlap_fraction(leg_events(v)) for v in legs}
    nonblk = leg_events("interop-nonblk")
    per_rank = obs.per_rank_overlap(nonblk)
    stragglers = obs.straggler_scores(nonblk)
    doc = obs.export_trace(trace_path, events=events, extra={
        "benchmark": "gauss_seidel",
        "legs": {v: {"window_us": list(windows[v]),
                     "overlap_fraction": overlaps[v]} for v in legs},
        "per_rank_overlap": {str(r): f for r, f in per_rank.items()},
        "straggler_scores": {str(r): s for r, s in stragglers.items()},
    })
    obs.assert_valid_trace(doc)
    pauses = sum(1 for e in leg_events("interop-blk")
                 if e["ph"] == "X" and e["cat"] == "task"
                 and e["name"] == "pause")
    if pauses == 0:
        raise SystemExit("traced leg: interop-blk recorded no task pause "
                         "spans — §4.1 pause/resume not visible")
    if not overlaps["interop-nonblk"] > overlaps["sentinel"]:
        raise SystemExit(
            f"overlap ordering violated: event-bound "
            f"{overlaps['interop-nonblk']:.3f} <= sentinel "
            f"{overlaps['sentinel']:.3f}")
    for v in legs:
        print_fn(f"gs_trace_{v},{overlaps[v] * 1e6:.1f},"
                 f"overlap-fraction-ppm")
    print_fn(f"gs_trace_events,{len(events)},file={trace_path}"
             f";pauses={pauses}")
    return overlaps


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Gauss-Seidel benchmark (paper §7.1)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI leg: parity checks + one simulated point")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="run the traced legs and write Perfetto JSON here "
                         "(skips the plain bench)")
    ns = ap.parse_args()
    if ns.trace:
        run_traced(ns.trace, smoke=ns.smoke)
    else:
        bench(smoke=ns.smoke)
