"""Gradient-synchronisation schedule benchmark (Level-B TAMPI adaptation).

Compares the three in-graph communication schedules (core/overlap.py):
``fused`` (fork-join analogue), ``bucketed`` (interop analogue) and
``sentinel`` (artificial serialisation) on a real LM train step:

* REAL execution wall time on the local mesh (DP-only — CPU backend
  restriction documented in tests/test_distributed.py);
* structural collective counts from the pre-optimisation StableHLO (the
  program as written — the TPU combiner threshold is the production knob
  that trades these back, see EXPERIMENTS.md §Perf).

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax

from repro import configs, optim
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh


def bench(print_fn=print):
    rows = []
    cfg = configs.smoke("granite_3_2b").scaled(dtype="float32", n_layers=8)
    opt_cfg = optim.OptimConfig()
    key = jax.random.PRNGKey(0)
    state = steps.init_train_state(cfg, opt_cfg, key)
    batch = inputs.make_batch(cfg, batch=8, seq=64, key=key)
    abatch = jax.eval_shape(lambda: batch)
    mesh = make_mesh((1, 1), ("data", "model"))  # 1-core box: schedule
    # structure is mesh-size independent; wall time measures overheads

    for mode in ("fused", "bucketed", "sentinel"):
        policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None,
                                grad_sync=mode)
        with mesh:
            make = steps.build_train_step_manual(
                cfg, mesh, policy, opt_cfg, bucket_bytes=1 << 16)
            f = make(jax.eval_shape(lambda: state), abatch)
            lowered = f.lower(state, batch)
            txt = lowered.as_text()
            n_ar = txt.count("all_reduce")
            n_barrier = txt.count("optimization_barrier")
            compiled = lowered.compile()
            s, m = compiled(state, batch)          # warmup
            jax.block_until_ready(m["loss"])
            t0 = time.monotonic()
            n = 5
            for _ in range(n):
                s, m = compiled(s, batch)
            jax.block_until_ready(m["loss"])
            dt = (time.monotonic() - t0) / n
        rows.append((f"gradsync_{mode}", dt * 1e6,
                     f"all_reduces={n_ar};barriers={n_barrier}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench()
