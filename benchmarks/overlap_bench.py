"""Gradient-synchronisation schedule benchmark (Level-B TAMPI adaptation).

Compares the three in-graph communication schedules (core/overlap.py over
core/lowering.py): ``fused`` (fork-join analogue), ``bucketed`` (interop
analogue) and ``sentinel`` (artificial serialisation) on a real LM train
step, plus the **hierarchical two-axis allreduce** (one
``schedule.build_hierarchical`` IR object lowered over an
(inter × intra) mesh) against the flat ring and the fused native node:

* REAL execution wall time on the local mesh (the module forces 8 host
  devices so the (2 × 4) two-axis mesh is real; CPU backend restriction
  documented in tests/test_distributed.py);
* structural collective counts from the pre-optimisation StableHLO;
* **α-β predicted times** from the schedule IR
  (`repro.core.schedule.Schedule.cost`) under the NOMINAL constants
  below, plus the linear **cost features** — critical-path rounds ``R``,
  one-port wire bytes ``W``, one-port combine bytes ``V`` — next to every
  measurement, so ``tools/calibrate.py`` can least-squares fit
  CALIBRATED α/β/γ (+ per-call overhead) from the same file and the
  bench-smoke CI job can gate on measured-vs-calibrated-predicted drift
  against the committed ``BENCH_baseline.json``.  Reporting both
  predictions is what makes the gate compare like with like: the nominal
  constants under-predict wall time by 20–70× on this host (they model a
  production interconnect, and ``measured_s`` includes the whole step),
  while the calibrated fit absorbs machine speed and per-call overhead.

Plus the host-runtime **progress leg** (``bench_progress``): the
polling-vs-continuation notification backends swept over in-flight
event-bound op counts — the polling registry's per-completion cost is
linear in the in-flight count while the continuation engine's
(`repro.core.continuations.ContinuationEngine`) stays flat, asserted
hard (a notification regression fails the job) and recorded with cost
features so the calibrated gate covers both backends.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import os
import re
import sys

# The two-axis hierarchical leg needs a real (inter × intra) device grid;
# force 8 host devices BEFORE jax initialises (same flag the lowering
# tests use in subprocesses).  Harmless for the 1-core legs.  A
# preexisting smaller count can't be overridden once set by the caller's
# environment — reject it up front instead of failing opaquely at mesh
# construction.
_FLAG = "--xla_force_host_platform_device_count"
_m = re.search(_FLAG + r"=(\d+)", os.environ.get("XLA_FLAGS", ""))
if _m is None:
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()
elif int(_m.group(1)) < 8:
    raise SystemExit(
        f"overlap_bench needs >= 8 host devices for the two-axis mesh; "
        f"XLA_FLAGS already pins {_m.group(0)} — unset it or raise it")

import contextlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax import lax

from repro import configs, optim
from repro.kernels import ops as kernel_ops
from repro.core import lowering
from repro.core import schedule as schedule_ir
from repro.core import simulate, tac
from repro.core.collectives import (Collectives, CollectiveHandle,
                                    ProgressEngine, _Machine)
from repro.core.continuations import ContinuationEngine
from repro.core.overlap import _make_buckets
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.compat import shard_map

# Nominal host-interconnect model for the predicted times (per-message
# latency, seconds per byte on the wire, combine seconds per byte).
ALPHA, BETA, GAMMA = 5e-6, 1e-9, 2.5e-10
REF_RANKS = 8               # predicted times quoted for an 8-way DP mesh
INTER, INTRA = 2, 4         # the two-axis (pod × data) bench grid


def features(sched: schedule_ir.Schedule, size: float) -> dict:
    """Linear α-β(-γ) cost features of one schedule at one payload size.

    ``cost(α, β, size, γ) ≈ α·rounds + β·wire_bytes + γ·combine_bytes``
    with each term read off the DAG in isolation — the linearisation
    ``tools/calibrate.py`` fits measured times against.  (The exact DAG
    cost may be below the sum where transport overlaps combines; the
    bench and the calibrator use the SAME linear form, so the gate is
    self-consistent.)
    """
    return {"rounds": sched.cost(1.0, 0.0, 0.0),
            "wire_bytes": sched.cost(0.0, 1.0, size),
            "combine_bytes": sched.cost(0.0, 0.0, size, gamma=1.0)}


def _sum_features(fs) -> dict:
    out = {"rounds": 0.0, "wire_bytes": 0.0, "combine_bytes": 0.0}
    for f in fs:
        for k in out:
            out[k] += f[k]
    return out


def predict(mode: str, leaf_bytes: list, bucket_bytes: int,
            n: int = REF_RANKS) -> dict:
    """α-β predicted seconds for one grad-sync under a given schedule.

    Buckets come from the SAME greedy bucketing the real step uses
    (`repro.core.overlap._make_buckets` over the actual per-leaf wire
    bytes), each bucket's algorithm/segment count from the IR's own
    selection (`repro.core.schedule.best_schedule`); the mode decides how
    bucket costs compose: one fused node, overlapped buckets (max —
    dependencies alone order them), or sentinel-serialised buckets (sum).
    The composed linear ``features`` follow the same rule (argmax bucket
    for the overlapped modes, sum for sentinel).
    """
    total = sum(leaf_bytes)
    if mode == "fused":
        bucket_sizes = [total]
    else:
        buckets = _make_buckets(leaf_bytes, bucket_bytes)
        bucket_sizes = [sum(leaf_bytes[i] for i in b) for b in buckets]
    costs, feats, algs, segs = [], [], set(), set()
    for sz in bucket_sizes:
        sched = schedule_ir.best_schedule("allreduce", n, sz,
                                          alpha=ALPHA, beta=BETA,
                                          gamma=GAMMA)
        costs.append(sched.cost(ALPHA, BETA, sz, gamma=GAMMA))
        feats.append(features(sched, sz))
        algs.add(sched.algorithm)
        segs.add(sched.segments)
    if mode == "sentinel":
        cost, feat = sum(costs), _sum_features(feats)
    else:
        cost = max(costs)
        feat = feats[costs.index(cost)]
    return {"predicted_s": cost, "features": feat,
            "algorithms": sorted(algs),
            "segments": sorted(segs), "n_buckets": len(bucket_sizes),
            "bucket_bytes_max": max(bucket_sizes), "ref_ranks": n}


N_BATCHES = 5       # timing batches per leg; the median batch is reported


def _median(samples) -> float:
    return sorted(samples)[len(samples) // 2]


def _time_call(fn, arg, reps: int) -> float:
    """Median of ``N_BATCHES`` timed batches of ``reps`` calls each.

    A single timing batch on a shared runner can sit 2×+ off the steady
    state (neighbor noise, frequency ramps); the drift gate compares
    per-row ratios against a committed baseline, so noise containment
    here is what gives the ×tolerance its headroom.
    """
    out = fn(arg)                       # warmup / compile
    jax.block_until_ready(out)
    samples = []
    for _ in range(N_BATCHES):
        t0 = time.monotonic()
        for _ in range(reps):
            out = fn(arg)
        jax.block_until_ready(out)
        samples.append((time.monotonic() - t0) / reps)
    return _median(samples)


def bench_hierarchical(reps: int, elems: int) -> dict:
    """The two-axis leg: hierarchical vs flat ring vs fused native psum.

    One `repro.core.schedule.build_hierarchical` schedule drives the
    (INTER × INTRA) lowering; the flat ring runs the same payload over a
    single 8-way axis; ``native`` is one fused psum over both axes.
    Each entry carries the nominal predicted seconds and the linear cost
    features for the calibration fit.
    """
    from jax.sharding import PartitionSpec as P
    n = INTER * INTRA
    mesh2d = make_mesh((INTER, INTRA), ("pod", "data"))
    mesh1d = make_mesh((n,), ("data",))
    nbytes = elems * 4
    x = jax.random.normal(jax.random.PRNGKey(3), (n * elems,))

    def lowered(mesh, axes, **kw):
        def f(xl):
            return lowering.allreduce(xl, axes, **kw)
        spec = P(tuple(mesh.axis_names))
        return jax.jit(shard_map(f, mesh=mesh, in_specs=spec, out_specs=P(),
                                 axis_names=set(mesh.axis_names),
                                 check_vma=False))

    hier_sched = schedule_ir.build_hierarchical(INTRA, INTER)
    ring_sched = schedule_ir.build("allreduce", "ring", n)
    legs = {
        "hierarchical": (lowered(mesh2d, ("pod", "data"),
                                 algorithm="hierarchical"), hier_sched),
        "flat_ring": (lowered(mesh1d, ("data",), algorithm="ring"),
                      ring_sched),
        "native": (lowered(mesh2d, ("pod", "data")), None),
    }
    report = {"inter": INTER, "intra": INTRA, "payload_bytes": nbytes}
    for name, (fn, sched) in legs.items():
        txt = fn.lower(x).as_text()
        entry = {"measured_s": _time_call(fn, x, reps),
                 "collective_permutes": txt.count("collective_permute"),
                 "all_reduces": txt.count("all_reduce")}
        if sched is not None:
            entry["predicted_s"] = sched.cost(ALPHA, BETA, nbytes,
                                              gamma=GAMMA)
            entry["features"] = features(sched, nbytes)
        report[name] = entry
    return report


# ---------------------------------------------------------------------------
# Fused-stage microbench: the Pallas executor tier vs unfused elementwise
# ---------------------------------------------------------------------------
# Analytic HBM traffic of one reduce-scatter combine stage, bytes per
# element: the fused kernel reads the wire payload and the fp32
# accumulator and writes the result ONCE; the unfused path additionally
# materialises the fp32 copy of the cast/dequantised wire payload (one
# write + one read-back).  fp32 has no cast stage, so fused == unfused
# there — the ≤0.6× bytes claim is about the narrow-wire legs.
_ACC_B = 4
_WIRE_B = {"fp32": 4, "bf16": 2, "int8": 1}
STAGE_MAX_FUSED_RATIO = 0.6


def stage_hbm_bytes(wire: str, elems: int, fused: bool) -> float:
    per = _WIRE_B[wire] + 2 * _ACC_B          # read got + read acc + write
    if not fused and wire != "fp32":
        per += 2 * _ACC_B                     # fp32 temp: write + read back
    return float(per * elems)


def gs_stage_hbm_bytes(h: int, w: int, fused: bool) -> float:
    # fused: one read of the block + one write of the update (residual
    # and edges accumulate in-register; O(H+W) edge bytes are noise).
    # unfused: the residual pass re-reads BOTH the new and the old block.
    per = 2 * _ACC_B if fused else 4 * _ACC_B
    return float(per * h * w)


def bench_stages(smoke: bool = False) -> dict:
    """Fused vs unfused collective-stage legs (the tentpole's bench gate).

    Each wire dtype is timed both ways on the same payload: ``unfused``
    materialises the cast/dequant intermediate behind an
    ``optimization_barrier`` (the separate-pass shape XLA emits when the
    stages stay distinct HLO ops), ``fused`` is the single-pass
    :func:`repro.kernels.ops.combine_stage` (the jnp oracle off-TPU — one
    XLA fusion — and the Pallas kernel on TPU).  Rows carry the ANALYTIC
    per-stage HBM bytes as their ``combine_bytes`` feature under the
    ``stage:*`` overhead classes, so ``tools/calibrate.py`` fits a
    per-stage γ (seconds per HBM byte) with per-variant intercepts, and
    the drift gate tracks both variants.  HARD ASSERTS fused bytes ≤
    ``STAGE_MAX_FUSED_RATIO`` × unfused on every narrow-wire leg, and the
    same for the fused Gauss–Seidel stencil stage.
    """
    elems = 1 << 18 if smoke else 1 << 20
    reps = 3 if smoke else 10
    key = jax.random.PRNGKey(7)
    acc = jax.random.normal(key, (elems,), jnp.float32)
    got32 = jax.random.normal(jax.random.PRNGKey(8), (elems,), jnp.float32)
    report: dict = {"elems": elems, "max_fused_ratio": STAGE_MAX_FUSED_RATIO}

    def wire_payload(wire):
        if wire == "bf16":
            return got32.astype(jnp.bfloat16), None
        if wire == "int8":
            return kernel_ops.quantize_stage(got32, impl="ref")
        return got32, None

    def unfused_fn(wire):
        def f(args):
            a, g, s = args
            if wire == "int8":
                deq = g.astype(jnp.float32) * s
            elif wire == "bf16":
                deq = g.astype(jnp.float32)
            else:
                deq = g
            # keep the cast a SEPARATE materialised pass — the unfused
            # HLO shape (without this, XLA fuses and measures the fused
            # path twice).
            deq, a2 = lax.optimization_barrier((deq, a))
            return a2 + deq
        return jax.jit(f)

    def fused_fn(wire):
        def f(args):
            a, g, s = args
            return kernel_ops.combine_stage(a, g, s, impl="ref")
        return jax.jit(f)

    for wire in ("fp32", "bf16", "int8"):
        g, s = wire_payload(wire)
        arg = (acc, g, s)
        for variant, fn in (("unfused", unfused_fn(wire)),
                            ("fused", fused_fn(wire))):
            hbm = stage_hbm_bytes(wire, elems, variant == "fused")
            report[f"combine_{wire}_{variant}"] = {
                "measured_s": _time_call(fn, arg, reps),
                "hbm_bytes": hbm,
                "features": {"rounds": 0.0, "wire_bytes": 0.0,
                             "combine_bytes": hbm},
                "overhead_class": f"stage:{variant}",
            }
        ratio = (report[f"combine_{wire}_fused"]["hbm_bytes"]
                 / report[f"combine_{wire}_unfused"]["hbm_bytes"])
        report[f"combine_{wire}_fused"]["bytes_ratio"] = ratio
        if wire != "fp32" and ratio > STAGE_MAX_FUSED_RATIO:
            raise SystemExit(
                f"fused {wire} combine stage lost its bytes win: "
                f"{ratio:.2f}x unfused (max {STAGE_MAX_FUSED_RATIO})")

    # Gauss–Seidel stencil stage: update + residual + boundary-pack in
    # one pass vs the update-then-re-read shape.
    H = W = 256 if smoke else 512
    blk = jax.random.normal(key, (H, W), jnp.float32)
    edges = (jax.random.normal(key, (W,), jnp.float32),
             jax.random.normal(key, (H,), jnp.float32),
             jax.random.normal(key, (W,), jnp.float32),
             jax.random.normal(key, (H,), jnp.float32))

    def gs_unfused(args):
        b, (t, l, bt, r) = args
        up = jnp.concatenate([t[None, :], b[:-1]], axis=0)
        down = jnp.concatenate([b[1:], bt[None, :]], axis=0)
        left = jnp.concatenate([l[:, None], b[:, :-1]], axis=1)
        right = jnp.concatenate([b[:, 1:], r[:, None]], axis=1)
        new = 0.25 * (up + down + left + right)
        new2, b2 = lax.optimization_barrier((new, b))   # separate passes
        res = jnp.sum(jnp.abs(new2 - b2))
        return new, res, (new2[0], new2[-1], new2[:, 0], new2[:, -1])

    def gs_fused(args):
        b, (t, l, bt, r) = args
        return kernel_ops.gs_stencil(b, t, l, bt, r, impl="ref")

    for variant, fn in (("unfused", jax.jit(gs_unfused)),
                        ("fused", jax.jit(gs_fused))):
        hbm = gs_stage_hbm_bytes(H, W, variant == "fused")
        report[f"gs_stencil_{variant}"] = {
            "measured_s": _time_call(fn, (blk, (edges[0], edges[1],
                                                edges[2], edges[3])), reps),
            "hbm_bytes": hbm,
            "features": {"rounds": 0.0, "wire_bytes": 0.0,
                         "combine_bytes": hbm},
            "overhead_class": f"stage:{variant}",
        }
    gs_ratio = (report["gs_stencil_fused"]["hbm_bytes"]
                / report["gs_stencil_unfused"]["hbm_bytes"])
    report["gs_stencil_fused"]["bytes_ratio"] = gs_ratio
    if gs_ratio > STAGE_MAX_FUSED_RATIO:
        raise SystemExit(f"fused stencil stage lost its bytes win: "
                         f"{gs_ratio:.2f}x unfused")
    return report


def bench_lowered_stages(reps: int, elems: int) -> dict:
    """Level-B fused-vs-unfused legs: the SAME flat-ring allreduce
    lowered with and without the fused stage tier (plus the bf16-wire
    variant), measured on the real 8-device mesh.  The wire-bytes
    feature of the bf16 leg is halved — the narrow transport the fused
    dequant-combine makes free."""
    from jax.sharding import PartitionSpec as P
    n = REF_RANKS
    mesh = make_mesh((n,), ("data",))
    nbytes = elems * 4
    x = jax.random.normal(jax.random.PRNGKey(5), (n * elems,))
    sched = schedule_ir.build("allreduce", "ring", n)
    base_feat = features(sched, nbytes)

    def lowered(**kw):
        def f(xl):
            return lowering.allreduce(xl, ("data",), algorithm="ring",
                                      **kw)
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                 out_specs=P(), axis_names={"data"},
                                 check_vma=False))

    legs = {
        "unfused": (lowered(), dict(base_feat)),
        "fused": (lowered(stage_impl="ref"), dict(base_feat)),
        "fused_bf16": (lowered(stage_impl="ref", wire="bf16"),
                       dict(base_feat,
                            wire_bytes=base_feat["wire_bytes"] / 2)),
    }
    report = {"ranks": n, "payload_bytes": nbytes}
    for name, (fn, feat) in legs.items():
        txt = fn.lower(x).as_text()
        report[name] = {
            "measured_s": _time_call(fn, x, reps),
            "collective_permutes": txt.count("collective_permute"),
            "features": feat,
        }
    return report


def bench_inter(reps: int, elems: int) -> dict:
    """Inter-axis (pod-level) butterfly legs: measured rows under the
    ``inter:butterfly`` overhead class, so the calibration fit carries a
    separate ``inter`` α/β family — the two-tier constants
    ``best_schedule`` uses to cost ``build_hierarchical`` candidates
    (``algorithm="auto"`` over two-level topologies)."""
    from jax.sharding import PartitionSpec as P
    report: dict = {"payload_bytes": elems * 4}
    for n_pods, shape in ((2, (2, 4)), (4, (4, 2))):
        mesh = make_mesh(shape, ("pod", "data"))
        rounds = n_pods.bit_length() - 1

        def f(xl):
            return lowering._butterfly_allreduce(xl, "pod", n_pods)
        sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                               out_specs=P("pod"),
                               axis_names={"pod", "data"},
                               check_vma=False))
        x = jax.random.normal(jax.random.PRNGKey(9), (n_pods * elems,))
        nbytes = elems * 4
        report[f"butterfly_{n_pods}pods"] = {
            "measured_s": _time_call(sf, x, reps),
            "features": {"rounds": float(rounds),
                         "wire_bytes": float(rounds * nbytes),
                         "combine_bytes": float(rounds * nbytes)},
            "overhead_class": "inter:butterfly",
        }
    return report


# ---------------------------------------------------------------------------
# Level-A executor microbench: compiled vs interpreted schedule programs
# ---------------------------------------------------------------------------
LEVEL_A_RANKS = 8
LEVEL_A_ALGORITHMS = ("ring", "doubling")
LEVEL_A_ELEMS = (64, 1 << 14)       # float64 payloads: 512 B and 128 KiB
# raw small-payload regression guard: compiled may never be SLOWER than
# the interpreter it replaces (the calibrated ≤0.5× overhead bar lives
# in tools/calibrate.py, where shared per-transfer cost is factored
# out — raw wall time is transport-dominated, so the raw ratio only
# needs to catch a fast path that stopped being fast).
LEVEL_A_MAX_SMALL_RATIO = 0.98


def serial_features(sched: schedule_ir.Schedule, size: float) -> dict:
    """Linear cost features of one schedule under a SERIAL driver.

    ``Collectives.run_group`` drives every rank's program round-robin on
    one thread, so wall time tracks the schedule's TOTAL work — transfers
    executed, bytes moved, bytes combined, summed over all ranks — not
    the one-port critical path :func:`features` reads off the DAG for the
    overlapped XLA legs.  α then fits the per-transfer host transport
    cost (shared by both executors on the same wire) and each executor
    class's ``overhead`` intercept absorbs its per-call fixed cost.
    """
    rounds = wire = combine = 0.0
    for prog in sched.programs:
        for op in prog:
            if isinstance(op, schedule_ir.Send):
                rounds += 1.0
                wire += op.frac * size
            elif isinstance(op, schedule_ir.Combine):
                combine += op.frac * size
    return {"rounds": rounds, "wire_bytes": wire, "combine_bytes": combine}


def bench_level_a(smoke: bool = False) -> dict:
    """The executor leg: compiled per-rank programs vs the interpreter.

    The SAME host collectives (8-rank allreduce over a ``CommWorld``,
    both wire-compatible executors) timed under the serial group driver
    at two payload sizes × two algorithms, rows tagged with
    ``overhead_class`` so ``tools/calibrate.py`` fits a separate
    per-call overhead constant per executor (α/β/γ shared).  HARD
    ASSERTS the raw small-payload win — a compiled-path regression fails
    the bench-smoke job before the calibrated gate even runs.
    """
    import numpy as np
    n = LEVEL_A_RANKS
    reps = 10 if smoke else 30
    report: dict = {"ranks": n, "reps": reps,
                    "compiled": {}, "interpreted": {}}
    small = {"compiled": 0.0, "interpreted": 0.0}

    def runner(executor, algorithm, elems):
        world = tac.CommWorld(n)
        coll = Collectives(world, executor=executor)
        kw = [{"value": np.arange(elems, dtype=np.float64) + r}
              for r in range(n)]
        return lambda _: coll.run_group("allreduce", kw,
                                        algorithm=algorithm)

    # Executors INTERLEAVED per configuration (and one untimed warm pass
    # first): allocator/code warmup and neighbor noise hit both classes
    # alike, so the compiled/interpreted ratio stays honest even when
    # absolute times wander.
    for algorithm in LEVEL_A_ALGORITHMS:
        for elems in LEVEL_A_ELEMS:
            for executor in ("compiled", "interpreted"):
                runner(executor, algorithm, elems)(None)
    for algorithm in LEVEL_A_ALGORITHMS:
        for elems in LEVEL_A_ELEMS:
            sched = schedule_ir.build("allreduce", algorithm, n)
            nbytes = elems * 8
            for executor in ("compiled", "interpreted"):
                # _time_call's warmup call also compiles + caches the
                # per-rank programs: steady-state timing for both.
                dt = _time_call(runner(executor, algorithm, elems),
                                None, reps)
                report[executor][f"{algorithm}_{elems}"] = {
                    "algorithm": algorithm, "payload_bytes": nbytes,
                    "measured_s": dt,
                    "features": serial_features(sched, nbytes),
                    "overhead_class": f"level_a:{executor}",
                }
                if elems == LEVEL_A_ELEMS[0]:
                    small[executor] += dt
    ratio = small["compiled"] / small["interpreted"]
    report["small_payload_ratio"] = ratio
    if ratio > LEVEL_A_MAX_SMALL_RATIO:
        raise SystemExit(
            f"compiled executor lost its small-payload win: "
            f"compiled/interpreted = {ratio:.2f} "
            f"(max {LEVEL_A_MAX_SMALL_RATIO}); the per-call fast path "
            f"regressed")
    return report


# ---------------------------------------------------------------------------
# Progress-path microbench: polling vs continuation notification
# ---------------------------------------------------------------------------
IN_FLIGHT_SWEEP = (8, 16, 32, 64)
PROGRESS_OPS_BUDGET = 20_000    # ~progress ops per timed batch (noise floor)
TEST_S = DISPATCH_S = 1e-6      # nominal per-progress-op model constants


def _progress_setup(backend: str, n: int):
    """Arm ``n`` in-flight event-bound machines on a fresh engine.

    Returns ``(handles, drain, counters)``: ``drain()`` completes one
    handle per tick and services the engine once per completion (the
    steady-state shape), ``counters()`` reads the progress-op totals —
    the polling backend re-tests every pending machine each tick
    (``n + (n-1) + ... + 1`` tests, linear in the in-flight count per
    completion) while the continuation backend pays one queue dispatch
    per completion, flat.
    """
    handles = [tac.EventHandle() for _ in range(n)]

    def gen(h):
        res = yield h
        return res

    if backend == "polling":
        eng = ProgressEngine()
        service, counters = eng.poll, lambda: (eng.stats["tests"], 0)
    else:
        engine = ContinuationEngine()
        eng = ProgressEngine(notify="continuation", continuations=engine)
        service = engine.service
        counters = lambda: (engine.stats["tests"],          # noqa: E731
                            engine.stats["dispatches"])
    for h in handles:
        eng.submit(_Machine(gen(h), CollectiveHandle()))

    def drain():
        for i, h in enumerate(handles):
            h.complete(i)
            service(None)
        if eng.pending:
            raise SystemExit(f"progress bench: {eng.pending} machines "
                             f"stuck under {backend}")
    return handles, drain, counters


def bench_progress(smoke: bool = False) -> dict:
    """The polling-vs-continuation leg: progress cost over an in-flight
    sweep.

    Every row carries linear cost features (``rounds`` = progress ops —
    handle tests + callback dispatches) beside ``measured_s`` so
    ``tools/calibrate.py`` fits the per-op cost and the CI gate covers
    both backends, plus the nominal
    `repro.core.simulate.progress_cost` prediction.  The function HARD
    ASSERTS the scaling claim — continuation progress ops per completion
    flat (sub-linear in the in-flight count), polling linear — so a
    notification regression fails the bench-smoke job outright.
    """
    budget = 4_000 if smoke else PROGRESS_OPS_BUDGET
    report: dict = {"sweep": list(IN_FLIGHT_SWEEP),
                    "test_s": TEST_S, "dispatch_s": DISPATCH_S}
    per_completion = {}
    for backend in ("polling", "continuation"):
        rows = {}
        for n in IN_FLIGHT_SWEEP:
            _, drain, counters = _progress_setup(backend, n)
            drain()
            tests, dispatches = counters()      # deterministic totals
            ops = tests + dispatches
            reps = max(1, budget // max(ops, 1))
            samples = []
            for _ in range(N_BATCHES):
                # arm outside the clock: measured_s is the PROGRESS cost
                # (completion + notification), not machine setup.
                drains = [_progress_setup(backend, n)[1]
                          for _ in range(reps)]
                t0 = time.monotonic()
                for d in drains:
                    d()
                samples.append((time.monotonic() - t0) / reps)
            # one completion per tick: the mean in-flight count is (n+1)/2
            predicted = simulate.progress_cost(
                backend, in_flight=(n + 1) / 2, ticks=n, completions=n,
                test_s=TEST_S, dispatch_s=DISPATCH_S)
            rows[f"inflight_{n}"] = {
                "in_flight": n, "completions": n, "tests": tests,
                "dispatches": dispatches,
                "ops_per_completion": ops / n,
                "measured_s": _median(samples),
                "predicted_s": predicted,
                "features": {"rounds": float(ops), "wire_bytes": 0.0,
                             "combine_bytes": 0.0},
            }
        report[backend] = rows
        per_completion[backend] = {
            n: rows[f"inflight_{n}"]["ops_per_completion"]
            for n in IN_FLIGHT_SWEEP}
    lo, hi = min(IN_FLIGHT_SWEEP), max(IN_FLIGHT_SWEEP)
    cont, poll = per_completion["continuation"], per_completion["polling"]
    if max(cont.values()) > 2.0 or cont[hi] > 1.5 * cont[lo]:
        raise SystemExit(
            f"continuation progress cost is NOT flat in in-flight ops: "
            f"ops/completion {cont} (expected O(1) dispatches per "
            f"completion)")
    if poll[hi] < 2.0 * poll[lo]:
        raise SystemExit(
            f"polling progress cost unexpectedly flat: ops/completion "
            f"{poll} (the baseline the continuation backend beats)")
    return report


# ---------------------------------------------------------------------------
# Observability sentinel: the disabled tracer must be (near-)free
# ---------------------------------------------------------------------------
OBS_MAX_OVERHEAD = 0.02     # NullTracer guard cost budget: ≤ 2% of hot path
OBS_GUARD_LOOPS = 1_000_000


def bench_obs(smoke: bool = False) -> dict:
    """The ``repro.obs`` overhead sentinel: tracing off must cost ≤ 2%.

    Every instrumentation site in the runtime is guarded by one module
    attribute read (``if trace.TRACING: ...``).  This leg bounds that
    cost on the hottest instrumented path (the continuation-backend
    drain of ``bench_progress``): it counts the guarded emissions one
    drain performs under a real tracer, measures the per-check guard
    cost with tracing disabled, and HARD ASSERTS
    ``emissions × guard_cost ≤ OBS_MAX_OVERHEAD × drain_time`` — the
    NullTracer overhead an untraced run pays for carrying the
    instrumentation.  Rows ``obs.null`` / ``obs.active`` (measured drain
    time without/with an active tracer, event counts as the ``rounds``
    feature) feed the calibrated drift gate under the ``obs`` scope.
    """
    from repro import obs
    from repro.obs import trace as _tr

    n = max(IN_FLIGHT_SWEEP)
    reps = 20 if smoke else 50

    # (1) guarded emissions per drain, counted under a real tracer.
    with obs.tracing() as tr:
        _, drain, counters = _progress_setup("continuation", n)
        drain()
        n_events = len(tr.events())
        tests, dispatches = counters()
    if n_events == 0:
        raise SystemExit("obs sentinel: a traced drain emitted no events "
                         "— the instrumentation went dead")

    # (2) per-check guard cost on the disabled path (tracing is off
    # here, so the loop body is exactly what every untraced site pays).
    # The empty-loop baseline is subtracted so the number is the
    # attribute read itself, not the timing loop around it; the 0.5 ns
    # floor keeps the bound honest when the subtraction lands in noise.
    assert not _tr.TRACING
    hits = 0
    t0 = time.monotonic()
    for _ in range(OBS_GUARD_LOOPS):
        if _tr.TRACING:
            hits += 1      # pragma: no cover - tracing is off
    t_guarded = time.monotonic() - t0
    t0 = time.monotonic()
    for _ in range(OBS_GUARD_LOOPS):
        pass
    t_empty = time.monotonic() - t0
    guard_s = max((t_guarded - t_empty) / OBS_GUARD_LOOPS, 0.5e-9)
    assert hits == 0

    # (3) the drain itself, untraced (NullTracer) and traced.
    def timed(active: bool) -> float:
        samples = []
        for _ in range(N_BATCHES):
            with contextlib.ExitStack() as stack:
                if active:
                    stack.enter_context(obs.tracing())
                drains = [_progress_setup("continuation", n)[1]
                          for _ in range(reps)]
                t0 = time.monotonic()
                for d in drains:
                    d()
                samples.append((time.monotonic() - t0) / reps)
        return _median(samples)

    t_null = timed(False)
    t_active = timed(True)
    overhead = n_events * guard_s / max(t_null, 1e-12)
    report = {
        "in_flight": n,
        "events_per_drain": n_events,
        "guard_ns": guard_s * 1e9,
        "overhead_fraction": overhead,
        "max_overhead": OBS_MAX_OVERHEAD,
        "null": {
            "measured_s": t_null,
            "features": {"rounds": float(tests + dispatches),
                         "wire_bytes": 0.0, "combine_bytes": 0.0},
            "overhead_class": "obs:null",
        },
        "active": {
            "measured_s": t_active,
            "events": n_events,
            "features": {"rounds": float(n_events), "wire_bytes": 0.0,
                         "combine_bytes": 0.0},
            "overhead_class": "obs:active",
        },
    }
    if overhead > OBS_MAX_OVERHEAD:
        raise SystemExit(
            f"obs sentinel: NullTracer overhead {overhead * 100:.2f}% of "
            f"the continuation drain exceeds the "
            f"{OBS_MAX_OVERHEAD * 100:.0f}% budget "
            f"({n_events} guarded sites × {guard_s * 1e9:.1f} ns vs "
            f"{t_null * 1e6:.1f} µs hot path) — an instrumentation site "
            f"stopped being guard-only")
    return report


def bench(print_fn=print, smoke: bool = False,
          json_path: str = "BENCH_overlap.json"):
    rows = []
    n_layers = 2 if smoke else 8
    reps = 2 if smoke else 5
    cfg = configs.smoke("granite_3_2b").scaled(dtype="float32",
                                               n_layers=n_layers)
    opt_cfg = optim.OptimConfig()
    key = jax.random.PRNGKey(0)
    state = steps.init_train_state(cfg, opt_cfg, key)
    batch = inputs.make_batch(cfg, batch=8, seq=64, key=key)
    abatch = jax.eval_shape(lambda: batch)
    # A REAL 8-way DP mesh (the module forces 8 host devices): the
    # measured all-reduces are genuine 8-rank collectives, so the
    # REF_RANKS=8 cost features describe the schedule that actually
    # executes and a bucketed/sentinel serialisation regression moves
    # measured_s.  (Pre-calibration this bench ran on a (1, 1) mesh,
    # where every mode's collective was a 1-rank no-op and the gate
    # would have tracked pure compute.)
    mesh = make_mesh((8, 1), ("data", "model"))
    bucket_bytes = 1 << 16
    # fp32 training: grads travel in their own (fp32) dtype, so the wire
    # bytes ARE size × itemsize — the same list sync_grads buckets by.
    leaf_bytes = [int(l.size) * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(state.params)]
    grad_bytes = sum(leaf_bytes)

    report = {"alpha": ALPHA, "beta": BETA, "gamma": GAMMA,
              "grad_bytes": grad_bytes, "bucket_bytes": bucket_bytes,
              "modes": {}}
    for mode in ("fused", "bucketed", "sentinel"):
        policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None,
                                grad_sync=mode)
        with mesh:
            make = steps.build_train_step_manual(
                cfg, mesh, policy, opt_cfg, bucket_bytes=bucket_bytes)
            f = make(jax.eval_shape(lambda: state), abatch)
            lowered = f.lower(state, batch)
            txt = lowered.as_text()
            n_ar = txt.count("all_reduce")
            n_barrier = txt.count("optimization_barrier")
            compiled = lowered.compile()
            s, m = compiled(state, batch)          # warmup
            jax.block_until_ready(m["loss"])
            samples = []
            for _ in range(N_BATCHES):             # median batch (see
                t0 = time.monotonic()              # _time_call)
                for _ in range(reps):
                    s, m = compiled(s, batch)
                jax.block_until_ready(m["loss"])
                samples.append((time.monotonic() - t0) / reps)
            dt = _median(samples)
        rows.append((f"gradsync_{mode}", dt * 1e6,
                     f"all_reduces={n_ar};barriers={n_barrier}"))
        report["modes"][mode] = dict(
            predict(mode, leaf_bytes, bucket_bytes),
            measured_s=dt, all_reduces=n_ar, barriers=n_barrier)

    # hierarchical two-axis leg on the real (2 × 4) device grid; the
    # per-call cost is microseconds, so many reps cost nothing and keep
    # the gated ratios out of timer-resolution noise.
    hier = bench_hierarchical(max(reps * 5, 10),
                              elems=1 << 14 if smoke else 1 << 16)
    report["hierarchical"] = hier
    for name in ("hierarchical", "flat_ring", "native"):
        e = hier[name]
        rows.append((f"allreduce_{name}", e["measured_s"] * 1e6,
                     f"ppermutes={e['collective_permutes']};"
                     f"all_reduces={e['all_reduces']}"))

    # fused-stage legs (Pallas executor tier): per-stage HBM-bytes rows
    # for the stage:* γ fit, the hard ≤0.6× bytes assert, and the
    # Level-B fused-vs-unfused ring lowering on the real mesh.
    stages = bench_stages(smoke)
    report["stages"] = stages
    for name, e in stages.items():
        if isinstance(e, dict) and "measured_s" in e:
            rows.append((f"stage_{name}", e["measured_s"] * 1e6,
                         f"hbm_bytes={e['hbm_bytes']:.0f};"
                         f"class={e['overhead_class']}"))
    lowered_stages = bench_lowered_stages(max(reps * 5, 10),
                                          elems=1 << 14 if smoke
                                          else 1 << 16)
    report["lowered_stages"] = lowered_stages
    for name in ("unfused", "fused", "fused_bf16"):
        e = lowered_stages[name]
        rows.append((f"lowered_ring_{name}", e["measured_s"] * 1e6,
                     f"ppermutes={e['collective_permutes']}"))

    # inter-axis butterfly rows: the two-tier (inter family) constants
    # for hierarchical candidates under algorithm="auto".
    inter = bench_inter(max(reps * 5, 10), elems=1 << 14 if smoke
                        else 1 << 16)
    report["inter"] = inter
    for name, e in inter.items():
        if isinstance(e, dict) and "measured_s" in e:
            rows.append((f"inter_{name}", e["measured_s"] * 1e6,
                         f"rounds={e['features']['rounds']:.0f}"))

    # compiled vs interpreted schedule executors (Level-A host path):
    # per-executor overhead_class rows for the per-class calibration fit
    # (small-payload win hard-asserted)
    level_a = bench_level_a(smoke)
    report["level_a"] = level_a
    for executor in ("compiled", "interpreted"):
        for name, e in level_a[executor].items():
            rows.append((f"level_a_{executor}_{name}",
                         e["measured_s"] * 1e6,
                         f"payload_bytes={e['payload_bytes']};"
                         f"class={e['overhead_class']}"))

    # polling vs continuation notification: progress cost over an
    # in-flight sweep (flat vs linear per completion; hard-asserted)
    progress = bench_progress(smoke)
    report["progress"] = progress
    for backend in ("polling", "continuation"):
        for n in IN_FLIGHT_SWEEP:
            e = progress[backend][f"inflight_{n}"]
            rows.append((f"progress_{backend}_{n}", e["measured_s"] * 1e6,
                         f"tests={e['tests']};dispatches={e['dispatches']};"
                         f"ops_per_completion={e['ops_per_completion']:.2f}"))

    # observability sentinel: NullTracer guard cost bounded (hard assert)
    # + untraced/traced drain rows for the calibrated gate.
    obs_report = bench_obs(smoke)
    report["obs"] = obs_report
    for leg in ("null", "active"):
        e = obs_report[leg]
        rows.append((f"obs_{leg}", e["measured_s"] * 1e6,
                     f"rounds={e['features']['rounds']:.0f};"
                     f"class={e['overhead_class']}"))
    rows.append(("obs_overhead",
                 obs_report["overhead_fraction"] * 1e6,
                 f"fraction-ppm;max={OBS_MAX_OVERHEAD}"))

    # segmented vs unsegmented ring under the same model: the pipelining
    # claim the simulator verifies (tests/test_schedule.py) quoted here
    # for the bench report.
    un = schedule_ir.build("allreduce", "ring", REF_RANKS)
    seg = schedule_ir.build("allreduce", "ring", REF_RANKS, segments=4)
    report["segmented_ring"] = {
        "payload_bytes": grad_bytes,
        "unsegmented_s": un.cost(ALPHA, BETA, grad_bytes, gamma=GAMMA),
        "segments4_s": seg.cost(ALPHA, BETA, grad_bytes, gamma=GAMMA),
    }
    # the row namespaces this bench owns: tools/calibrate.py --gate
    # limits the missing-baseline-row check to these, so rows other
    # benches contribute to the shared baseline (serve.*) don't fail
    # an overlap-only run.
    report["gate_scope"] = ["modes", "hierarchical", "stages",
                           "lowered_stages", "inter", "level_a",
                           "progress", "obs"]
    pathlib.Path(json_path).write_text(json.dumps(report, indent=2))
    rows.append(("gradsync_predict_json", 0.0, json_path))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    if "--obs" in sys.argv[1:]:
        # CI obs-smoke job: run ONLY the observability sentinel (the
        # NullTracer ≤ 2% hard assert) without the jax-heavy legs.
        out = bench_obs(smoke="--smoke" in sys.argv[1:])
        print(f"obs_null,{out['null']['measured_s'] * 1e6:.1f},"
              f"events={out['events_per_drain']}")
        print(f"obs_active,{out['active']['measured_s'] * 1e6:.1f},"
              f"events={out['events_per_drain']}")
        print(f"obs_overhead,{out['overhead_fraction'] * 1e6:.1f},"
              f"fraction-ppm;guard_ns={out['guard_ns']:.1f}")
    else:
        bench(smoke="--smoke" in sys.argv[1:])
