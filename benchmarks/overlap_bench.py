"""Gradient-synchronisation schedule benchmark (Level-B TAMPI adaptation).

Compares the three in-graph communication schedules (core/overlap.py over
core/lowering.py): ``fused`` (fork-join analogue), ``bucketed`` (interop
analogue) and ``sentinel`` (artificial serialisation) on a real LM train
step:

* REAL execution wall time on the local mesh (DP-only — CPU backend
  restriction documented in tests/test_distributed.py);
* structural collective counts from the pre-optimisation StableHLO (the
  program as written — the TPU combiner threshold is the production knob
  that trades these back, see EXPERIMENTS.md §Perf);
* **α-β predicted times** from the schedule IR
  (`repro.core.schedule.Schedule.cost`): per mode, the predicted seconds
  of its collective schedule on a reference 8-way DP mesh — sentinel
  serialises the buckets (sum of costs), bucketed overlaps them (max),
  fused pays one whole-payload node — written to ``BENCH_overlap.json``
  next to the measured wall times so schedule regressions in either level
  are visible in CI (the ``--smoke`` bench job).

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax

from repro import configs, optim
from repro.core import schedule as schedule_ir
from repro.core.overlap import _make_buckets
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh

# Nominal host-interconnect model for the predicted times (per-message
# latency, seconds per byte on the wire, combine seconds per byte).
ALPHA, BETA, GAMMA = 5e-6, 1e-9, 2.5e-10
REF_RANKS = 8               # predicted times quoted for an 8-way DP mesh


def predict(mode: str, leaf_bytes: list, bucket_bytes: int,
            n: int = REF_RANKS) -> dict:
    """α-β predicted seconds for one grad-sync under a given schedule.

    Buckets come from the SAME greedy bucketing the real step uses
    (`repro.core.overlap._make_buckets` over the actual per-leaf wire
    bytes), each bucket's algorithm/segment count from the IR's own
    selection (`repro.core.schedule.best_schedule`); the mode decides how
    bucket costs compose: one fused node, overlapped buckets (max —
    dependencies alone order them), or sentinel-serialised buckets (sum).
    """
    total = sum(leaf_bytes)
    if mode == "fused":
        bucket_sizes = [total]
    else:
        buckets = _make_buckets(leaf_bytes, bucket_bytes)
        bucket_sizes = [sum(leaf_bytes[i] for i in b) for b in buckets]
    costs, algs, segs = [], set(), set()
    for sz in bucket_sizes:
        sched = schedule_ir.best_schedule("allreduce", n, sz,
                                          alpha=ALPHA, beta=BETA,
                                          gamma=GAMMA)
        costs.append(sched.cost(ALPHA, BETA, sz, gamma=GAMMA))
        algs.add(sched.algorithm)
        segs.add(sched.segments)
    cost = sum(costs) if mode == "sentinel" else max(costs)
    return {"predicted_s": cost, "algorithms": sorted(algs),
            "segments": sorted(segs), "n_buckets": len(bucket_sizes),
            "bucket_bytes_max": max(bucket_sizes), "ref_ranks": n}


def bench(print_fn=print, smoke: bool = False,
          json_path: str = "BENCH_overlap.json"):
    rows = []
    n_layers = 2 if smoke else 8
    reps = 2 if smoke else 5
    cfg = configs.smoke("granite_3_2b").scaled(dtype="float32",
                                               n_layers=n_layers)
    opt_cfg = optim.OptimConfig()
    key = jax.random.PRNGKey(0)
    state = steps.init_train_state(cfg, opt_cfg, key)
    batch = inputs.make_batch(cfg, batch=8, seq=64, key=key)
    abatch = jax.eval_shape(lambda: batch)
    mesh = make_mesh((1, 1), ("data", "model"))  # 1-core box: schedule
    # structure is mesh-size independent; wall time measures overheads
    bucket_bytes = 1 << 16
    # fp32 training: grads travel in their own (fp32) dtype, so the wire
    # bytes ARE size × itemsize — the same list sync_grads buckets by.
    leaf_bytes = [int(l.size) * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(state.params)]
    grad_bytes = sum(leaf_bytes)

    report = {"alpha": ALPHA, "beta": BETA, "gamma": GAMMA,
              "grad_bytes": grad_bytes, "bucket_bytes": bucket_bytes,
              "modes": {}}
    for mode in ("fused", "bucketed", "sentinel"):
        policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None,
                                grad_sync=mode)
        with mesh:
            make = steps.build_train_step_manual(
                cfg, mesh, policy, opt_cfg, bucket_bytes=bucket_bytes)
            f = make(jax.eval_shape(lambda: state), abatch)
            lowered = f.lower(state, batch)
            txt = lowered.as_text()
            n_ar = txt.count("all_reduce")
            n_barrier = txt.count("optimization_barrier")
            compiled = lowered.compile()
            s, m = compiled(state, batch)          # warmup
            jax.block_until_ready(m["loss"])
            t0 = time.monotonic()
            for _ in range(reps):
                s, m = compiled(s, batch)
            jax.block_until_ready(m["loss"])
            dt = (time.monotonic() - t0) / reps
        rows.append((f"gradsync_{mode}", dt * 1e6,
                     f"all_reduces={n_ar};barriers={n_barrier}"))
        report["modes"][mode] = dict(
            predict(mode, leaf_bytes, bucket_bytes),
            measured_s=dt, all_reduces=n_ar, barriers=n_barrier)

    # segmented vs unsegmented ring under the same model: the pipelining
    # claim the simulator verifies (tests/test_schedule.py) quoted here
    # for the bench report.
    un = schedule_ir.build("allreduce", "ring", REF_RANKS)
    seg = schedule_ir.build("allreduce", "ring", REF_RANKS, segments=4)
    report["segmented_ring"] = {
        "payload_bytes": grad_bytes,
        "unsegmented_s": un.cost(ALPHA, BETA, grad_bytes, gamma=GAMMA),
        "segments4_s": seg.cost(ALPHA, BETA, grad_bytes, gamma=GAMMA),
    }
    pathlib.Path(json_path).write_text(json.dumps(report, indent=2))
    rows.append(("gradsync_predict_json", 0.0, json_path))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench(smoke="--smoke" in sys.argv[1:])
