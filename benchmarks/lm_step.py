"""LM train/decode step timings on CPU (smoke configs, all 10 archs).

Not a TPU number — a regression harness for the substrate: per-arch train
step and decode step wall time at smoke scale, plus tokens/s derived.

CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.models import model, inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import local_mesh


def bench(print_fn=print, archs=None):
    rows = []
    archs = archs or list(configs.ARCHS)
    opt_cfg = optim.OptimConfig()
    mesh = local_mesh(model=1)
    B, S = 2, 64
    for arch in archs:
        cfg = configs.smoke(arch)
        key = jax.random.PRNGKey(0)
        state = steps.init_train_state(cfg, opt_cfg, key)
        batch = inputs.make_batch(cfg, batch=B, seq=S, key=key)
        abatch = jax.eval_shape(lambda: batch)
        policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None)
        with mesh:
            jitted, _ = steps.build_train_step(
                cfg, mesh, policy, opt_cfg, abstract_batch=abatch,
                donate=False)
            state, m = jitted(state, batch)       # compile + warmup
            jax.block_until_ready(m["loss"])
            t0 = time.monotonic()
            n = 3
            for _ in range(n):
                state, m = jitted(state, batch)
            jax.block_until_ready(m["loss"])
            dt = (time.monotonic() - t0) / n
        rows.append((f"train_step_{arch}", dt * 1e6,
                     f"tok_per_s={B * S / dt:.0f}"))

        if cfg.causal:
            params = state.params
            cache = model.init_cache(cfg, B, S)
            dec_batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
            with mesh:
                dec, a_cache = steps.build_decode_step(
                    cfg, mesh, policy, batch=B, cache_len=S,
                    abstract_batch=jax.eval_shape(lambda: dec_batch),
                    donate=False)
                logits, cache = dec(params, cache, dec_batch,
                                    jnp.int32(0))
                jax.block_until_ready(logits)
                t0 = time.monotonic()
                n = 10
                for i in range(n):
                    logits, cache = dec(params, cache, dec_batch,
                                        jnp.int32(i + 1))
                jax.block_until_ready(logits)
                dt = (time.monotonic() - t0) / n
            rows.append((f"decode_step_{arch}", dt * 1e6,
                         f"tok_per_s={B / dt:.0f}"))
    for r in rows:
        print_fn(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    bench()
