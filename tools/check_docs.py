#!/usr/bin/env python
"""Docs link & symbol checker (run by the CI docs job and tests/test_docs.py).

Checks, over README.md and docs/*.md:

1. every relative markdown link ``[text](path)`` resolves to an existing
   file (external http(s)/mailto links and pure #anchors are skipped);
2. every dotted ``repro.*`` name mentioned anywhere in the text (prose or
   code fences) resolves to a real module/attribute under ``src/`` — so
   renaming an API without updating the docs fails CI.

Usage:  PYTHONPATH=src python tools/check_docs.py [file.md ...]
Exits non-zero listing every broken link / dangling symbol.
"""

from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"\brepro(?:\.\w+)+")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def resolve_symbol(dotted: str) -> bool:
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_file(path: pathlib.Path) -> list:
    errors = []
    text = path.read_text()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {m.group(1)}")
    for dotted in sorted(set(SYMBOL_RE.findall(text))):
        if not resolve_symbol(dotted):
            errors.append(f"{path}: dangling symbol -> {dotted}")
    return errors


def main(argv: list) -> int:
    if argv:
        files = [pathlib.Path(a) for a in argv]
    else:
        files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file missing")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} error(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
