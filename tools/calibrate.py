#!/usr/bin/env python
"""Fit α/β/γ from measured bench runs and gate CI on prediction drift.

``benchmarks/overlap_bench.py`` writes, next to every measured wall time,
the schedule IR's linear cost features — critical-path rounds ``R``,
one-port wire bytes ``W``, one-port combine bytes ``V``.  This tool
least-squares fits the machine model

    measured ≈ α·R + β·W + γ·V + overhead

over every row of the bench report (plus ``--history`` files when
present), with all constants constrained non-negative (active-set
NNLS over ``numpy.linalg.lstsq``).  A row may carry an
``overhead_class`` label (the Level-A executor leg tags its rows
``level_a:compiled`` / ``level_a:interpreted``); each class gets its OWN
fitted per-call ``overhead`` intercept, and classes sharing a *family*
(the label up to the ``:``) share one α/β/γ — ``level_a:*`` rows fit the
host transport's per-transfer/per-byte constants, common to both
executors on the same wire, separately from the unlabelled ``default``
family's XLA-leg constants (one α across both families would be
physically meaningless: host isend/irecv latency and device collective
rounds differ by orders of magnitude, and the executor intercepts would
just absorb the mismatch).  The per-class intercept is then exactly the
per-call executor overhead — the quantity the compiled-program work
exists to kill.  Unlabelled rows form the ``default`` class/family,
whose constants are also reported at top level for back-compatibility.
The fitted constants are the
CALIBRATED α-β(-γ) model: ``repro.core.schedule.load_calibration`` feeds
them to ``best_schedule`` / ``Collectives(comm, calibration=...)`` so
``algorithm="auto"`` selects under measured rather than nominal
constants, and ``--apply`` writes ``predicted_calibrated_s`` back into
the bench report next to the nominal ``predicted_s`` so the two
predictions can be compared like with like.

**Gating** (the bench-smoke CI job): per-row ratios
``measured / predicted_calibrated`` are compared against the committed
``BENCH_baseline.json``.  Because the fit is re-run on the current
machine, uniform speed differences cancel — a ratio drifting beyond
``--tolerance`` (×) of its baseline value means a *structural* change:
a schedule serialising that used to overlap, a collective count
regression, a cost-model break.  When BOTH executor classes are in the
fit, the gate additionally hard-asserts the compiled executor's fitted
per-call overhead at ≤ ``EXECUTOR_OVERHEAD_MAX_RATIO`` × the
interpreted one — the acceptance bar for the compiled-program executor,
enforced every bench-smoke run rather than once at review time.

**History** (rolling-window fits): ``--history`` accepts bench report
FILES and/or DIRECTORIES of per-run artifacts (the bench-smoke CI job
archives each run's ``BENCH_overlap.json`` under a timestamped name);
directories are expanded to their ``*.json`` files sorted by name — with
timestamped names that is chronological — and ``--history-window N``
keeps only each directory's newest ``N`` artifacts (explicitly listed
files are always kept), so the fit (and hence the gate's calibrated
predictions) averages over a rolling window of recent runs instead of
whipsawing on a single noisy one.

Usage:
  python tools/calibrate.py [--bench BENCH_overlap.json]
      [--history FILE_OR_DIR ...] [--history-window N]
      [--out CALIBRATION.json] [--apply]
      [--write-baseline BENCH_baseline.json]
      [--gate --baseline BENCH_baseline.json --tolerance 3.0]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

FEATURE_KEYS = ("rounds", "wire_bytes", "combine_bytes")
CONSTANT_KEYS = ("alpha", "beta", "gamma", "overhead")
DEFAULT_CLASS = "default"
# compiled per-call overhead must stay at or below this fraction of the
# interpreted executor's (the Level-A executor acceptance bar).
EXECUTOR_OVERHEAD_MAX_RATIO = 0.5
_EXECUTOR_CLASSES = ("level_a:compiled", "level_a:interpreted")
_EPS = 1e-12


def row_class(row: dict) -> str:
    return row.get("overhead_class", DEFAULT_CLASS)


def class_family(cls: str) -> str:
    """Classes share machine constants per family: ``level_a:compiled``
    and ``level_a:interpreted`` fit one ``level_a`` α/β/γ between them."""
    return cls.split(":", 1)[0]


def collect_rows(report: dict, prefix: str = "") -> List[Tuple[str, dict]]:
    """Every nested dict carrying both ``features`` and ``measured_s``
    is one calibration/gate row, named by its JSON path."""
    rows = []
    for key, val in report.items():
        if not isinstance(val, dict):
            continue
        path = f"{prefix}{key}"
        if "features" in val and "measured_s" in val:
            rows.append((path, val))
        rows.extend(collect_rows(val, prefix=f"{path}."))
    return rows


def history_files(paths: List[str],
                  window: Optional[int] = None) -> List[pathlib.Path]:
    """Expand ``--history`` arguments into bench-report files.

    Each DIRECTORY contributes its ``*.json`` entries sorted by file
    name (timestamped artifact names sort chronologically), truncated to
    the NEWEST ``window`` of them — the rolling window.  Explicitly
    listed FILES are always kept, in argument order: naming a report on
    the command line is an explicit request to fit over it.  Paths that
    do not exist are skipped with a warning — an empty history (the
    first CI run, an evicted cache) must not break the fit over the
    current bench report.
    """
    files: List[pathlib.Path] = []
    for p in paths:
        pp = pathlib.Path(p)
        if pp.is_dir():
            found = sorted(pp.glob("*.json"), key=lambda f: f.name)
            if window is not None and window > 0:
                found = found[-window:]
            files.extend(found)
        elif pp.is_file():
            files.append(pp)
        else:
            print(f"warning: --history path {pp} does not exist; skipped",
                  file=sys.stderr)
    return files


def nnls(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Non-negative least squares via active-set elimination: drop any
    column fitted negative and refit (few columns, few rows — exactness
    is not worth a scipy dependency)."""
    active = list(range(A.shape[1]))
    while True:
        x = np.zeros(A.shape[1])
        if active:
            sol, *_ = np.linalg.lstsq(A[:, active], b, rcond=None)
            x[np.array(active)] = sol
        neg = [c for c in active if x[c] < 0]
        if not neg:
            return x
        active = [c for c in active if c not in neg]


def fit(rows: List[Tuple[str, dict]]) -> Dict[str, float]:
    """NNLS fit: α/β/γ per class *family*, overhead intercept per class.

    One joint non-negative least squares over a block design — each
    family's rows load only that family's feature columns, each class
    its own intercept column — so an all-``default`` report reproduces
    the original 4-constant fit bit-for-bit.  Returns ``{"alpha",
    "beta", "gamma", "overhead"}`` (the ``default`` family/class, the
    shape older consumers read) plus ``"families"`` (per-family α/β/γ)
    and ``"overheads"`` (per-class intercepts).
    """
    classes = sorted({row_class(r) for _, r in rows})
    families = sorted({class_family(c) for c in classes})
    A = np.array(
        [[r["features"][k] if class_family(row_class(r)) == fam else 0.0
          for fam in families for k in FEATURE_KEYS]
         + [1.0 if row_class(r) == c else 0.0 for c in classes]
         for _, r in rows], dtype=float)
    b = np.array([r["measured_s"] for _, r in rows], dtype=float)
    x = nnls(A, b)
    nf = len(FEATURE_KEYS)
    fam_consts = {
        fam: dict(zip(CONSTANT_KEYS[:nf],
                      (float(v) for v in x[i * nf:(i + 1) * nf])))
        for i, fam in enumerate(families)}
    consts = dict(fam_consts.get(DEFAULT_CLASS,
                                 dict.fromkeys(CONSTANT_KEYS[:nf], 0.0)))
    consts["families"] = fam_consts
    consts["overheads"] = {
        c: float(v) for c, v in zip(classes, x[len(families) * nf:])}
    consts["overhead"] = consts["overheads"].get(DEFAULT_CLASS, 0.0)
    return consts


def predict_calibrated(row: dict, consts: Dict[str, float]) -> float:
    f = row["features"]
    cls = row_class(row)
    # Per-family constants / per-class overhead when the fit carried
    # them; old single-constant calibration files fall back to the
    # legacy flat keys.
    fam = consts.get("families", {}).get(
        class_family(cls),
        {k: consts[k] for k in CONSTANT_KEYS[:3]})
    overhead = consts.get("overheads", {}).get(cls, consts["overhead"])
    return (fam["alpha"] * f["rounds"]
            + fam["beta"] * f["wire_bytes"]
            + fam["gamma"] * f["combine_bytes"]
            + overhead)


def ratios(rows: List[Tuple[str, dict]],
           consts: Dict[str, float]) -> Dict[str, float]:
    return {name: row["measured_s"] / max(predict_calibrated(row, consts),
                                          _EPS)
            for name, row in rows}


def gate(cur: Dict[str, float], base: Dict[str, float],
         tolerance: float,
         scope: Optional[List[str]] = None) -> List[str]:
    """Drift report; non-empty means fail.  NEW rows (in the current run
    but not the baseline) are reported without failing — adding a bench
    leg must not insta-break CI; the baseline refresh picks it up.  A
    baseline row MISSING from the current run fails: a leg (or its
    ``features`` key) silently dropping out is exactly the unmeasured
    regression the gate exists to catch.

    ``scope`` (a report's top-level ``gate_scope`` list) restricts that
    missing-row check to baseline rows under the named prefixes: a
    report that declares which row namespaces it owns (e.g. the serving
    bench's ``["serve"]``) is only accountable for THOSE baseline rows,
    so two benches can gate against one shared baseline without each
    failing over the other's rows.  Drift checks are unaffected — every
    row the report does emit is still compared.  No scope = the report
    answers for the whole baseline (the pre-scope behaviour)."""
    failures = []
    if scope is not None:
        base = {name: v for name, v in base.items()
                if any(name == p or name.startswith(p + ".")
                       for p in scope)}
    for name in sorted(cur):
        if name not in base:
            print(f"  new row (not gated): {name}")
            continue
        drift = cur[name] / max(base[name], _EPS)
        ok = 1.0 / tolerance <= drift <= tolerance
        print(f"  {name}: ratio {cur[name]:.3g} vs baseline "
              f"{base[name]:.3g} (drift ×{drift:.2f}) "
              f"{'ok' if ok else 'DRIFT'}")
        if not ok:
            failures.append(
                f"{name}: measured/predicted ratio drifted ×{drift:.2f} "
                f"from baseline (tolerance ×{tolerance})")
    for name in sorted(set(base) - set(cur)):
        print(f"  {name}: MISSING from current run")
        failures.append(
            f"{name}: baseline row missing from the bench report — a leg "
            f"stopped emitting measured_s/features; refresh the baseline "
            f"deliberately if it was removed on purpose")
    return failures


def executor_overhead_failures(consts: Dict[str, float]) -> List[str]:
    """The Level-A acceptance check: compiled per-call overhead must fit
    at ≤ ``EXECUTOR_OVERHEAD_MAX_RATIO`` × the interpreted executor's.
    Empty (pass) when either executor class is absent from the fit."""
    overheads = consts.get("overheads", {})
    compiled_cls, interp_cls = _EXECUTOR_CLASSES
    if compiled_cls not in overheads or interp_cls not in overheads:
        return []
    compiled, interp = overheads[compiled_cls], overheads[interp_cls]
    ratio = compiled / max(interp, _EPS)
    ok = compiled <= EXECUTOR_OVERHEAD_MAX_RATIO * interp + _EPS
    print(f"  executor overhead: compiled {compiled*1e3:.3f} ms vs "
          f"interpreted {interp*1e3:.3f} ms (×{ratio:.2f}, max "
          f"×{EXECUTOR_OVERHEAD_MAX_RATIO}) {'ok' if ok else 'FAIL'}")
    if ok:
        return []
    return [f"compiled executor per-call overhead {compiled*1e3:.3f} ms "
            f"exceeds {EXECUTOR_OVERHEAD_MAX_RATIO} x interpreted "
            f"({interp*1e3:.3f} ms): the compiled-program fast path "
            f"regressed"]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--bench", default="BENCH_overlap.json")
    p.add_argument("--history", nargs="*", default=[],
                   help="extra bench reports to include in the fit: "
                        "files and/or directories of per-run artifacts")
    p.add_argument("--history-window", type=int, default=10,
                   help="per --history DIRECTORY: keep only its newest N "
                        "artifacts (rolling window; 0 = unlimited; "
                        "explicitly listed files are always kept)")
    p.add_argument("--out", default="CALIBRATION.json")
    p.add_argument("--apply", action="store_true",
                   help="write predicted_calibrated_s into the bench json")
    p.add_argument("--write-baseline", metavar="PATH",
                   help="write the per-row ratios as the new baseline")
    p.add_argument("--gate", action="store_true")
    p.add_argument("--baseline", default="BENCH_baseline.json")
    p.add_argument("--tolerance", type=float, default=None,
                   help="max allowed × drift of a row's measured/predicted"
                        " ratio vs the baseline; when gating, defaults to"
                        " the tolerance recorded IN the baseline file"
                        " (single source of truth), else 3.0")
    args = p.parse_args(argv)

    bench_path = pathlib.Path(args.bench)
    report = json.loads(bench_path.read_text())
    rows = collect_rows(report)
    if not rows:
        print(f"{bench_path}: no rows with features + measured_s",
              file=sys.stderr)
        return 1
    fit_rows = list(rows)
    for h in history_files(args.history, args.history_window):
        n_before = len(fit_rows)
        fit_rows.extend(collect_rows(json.loads(h.read_text())))
        print(f"history: {h} (+{len(fit_rows) - n_before} rows)")

    consts = fit(fit_rows)
    cur = ratios(rows, consts)
    print(f"calibrated over {len(fit_rows)} row(s): " +
          ", ".join(f"{k}={consts[k]:.3e}" for k in CONSTANT_KEYS))
    for fam, fc in sorted(consts["families"].items()):
        if fam != DEFAULT_CLASS:
            print(f"family {fam}: " +
                  ", ".join(f"{k}={v:.3e}" for k, v in fc.items()))
    extra = {c: v for c, v in consts["overheads"].items()
             if c != DEFAULT_CLASS}
    if extra:
        print("per-class overheads: " +
              ", ".join(f"{c}={v:.3e}" for c, v in sorted(extra.items())))

    calibration = dict(consts)
    calibration["n_rows"] = len(fit_rows)
    calibration["rows"] = {
        name: {"measured_s": row["measured_s"],
               "predicted_nominal_s": row.get("predicted_s"),
               "predicted_calibrated_s": predict_calibrated(row, consts),
               "ratio": cur[name]}
        for name, row in rows}
    pathlib.Path(args.out).write_text(json.dumps(calibration, indent=2))

    if args.apply:
        for name, row in rows:
            row["predicted_calibrated_s"] = predict_calibrated(row, consts)
        report["calibration"] = consts
        bench_path.write_text(json.dumps(report, indent=2))

    if args.write_baseline:
        pathlib.Path(args.write_baseline).write_text(json.dumps(
            {"constants": consts, "ratios": cur,
             "tolerance": args.tolerance or 3.0},
            indent=2))
        print(f"baseline written to {args.write_baseline}")

    if args.gate:
        base = json.loads(pathlib.Path(args.baseline).read_text())
        tolerance = args.tolerance or float(base.get("tolerance", 3.0))
        print(f"gating against {args.baseline} "
              f"(tolerance ×{tolerance}):")
        failures = gate(cur, base["ratios"], tolerance,
                        scope=report.get("gate_scope"))
        failures.extend(executor_overhead_failures(consts))
        if failures:
            for f_ in failures:
                print(f"GATE FAIL: {f_}", file=sys.stderr)
            return 1
        print("gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
