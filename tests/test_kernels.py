"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle.

Sweeps shapes and dtypes per the deliverable: every kernel is asserted
allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.moe_gmm import moe_gmm


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Hkv,D,causal,window",
    [
        (1, 256, 4, 2, 64, True, None),     # GQA causal
        (2, 128, 8, 8, 128, False, None),   # MHA bidirectional (encoder)
        (1, 256, 4, 1, 64, True, 64),       # MQA + sliding window
        (2, 512, 2, 2, 32, True, None),     # long-ish causal
        (1, 128, 6, 2, 80, True, None),     # non-128 head dim (zamba2/hubert)
    ])
def test_flash_attention_vs_ref(B, S, H, Hkv, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    exp = ref.attention(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_blocks_sweep():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    exp = ref.attention(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (1, 64, 8, 16, 32, 64),     # chunk == s
])
def test_mamba2_ssd_vs_ref(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n), dtype)
    C = jax.random.normal(ks[4], (b, s, n), dtype)
    y, st = mamba2_ssd(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=1e-3, rtol=1e-3)


def test_mamba2_ssd_init_state_chaining():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n = 1, 128, 2, 16, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_full, st_full = mamba2_ssd(x, dt, A, B, C, chunk=32, interpret=True)
    y1, st1 = mamba2_ssd(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64],
                         chunk=32, interpret=True)
    y2, st2 = mamba2_ssd(x[:, 64:], dt[:, 64:], A, B[:, 64:], C[:, 64:],
                         chunk=32, init_state=st1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_matches_sequential_decode():
    """Chunked train path == step-by-step decode recurrence (cache parity)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, p, n = 2, 64, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_seq, st_seq = ref.ssd_sequential(x, dt, A, B, C)
    y_chk, st_chk = mamba2_ssd(x, dt, A, B, C, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_seq),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,K,N", [(4, 64, 32, 48), (8, 128, 128, 256),
                                     (2, 32, 64, 32)])
def test_moe_gmm_vs_ref(E, C, K, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jax.random.normal(ks[0], (E, C, K), dtype)
    w = jax.random.normal(ks[1], (E, K, N), dtype)
    out = moe_gmm(x, w, block_c=32, block_n=16, block_k=32, interpret=True)
    exp = jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(dtype)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_moe_gmm_ref_matches_ragged_oracle():
    """The fixed-capacity layout must agree with the ragged gmm oracle."""
    E, C, K, N = 3, 8, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    x = jax.random.normal(ks[0], (E, C, K))
    w = jax.random.normal(ks[1], (E, K, N))
    out = ops.moe_gmm(x, w, impl="ref")
    ragged = ref.gmm(x.reshape(E * C, K), w,
                     jnp.full((E,), C, jnp.int32)).reshape(E, C, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ragged),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# mlstm (pure-jnp chunked vs sequential oracle)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,d,chunk", [(2, 64, 4, 8, 16),
                                           (1, 128, 2, 16, 32)])
def test_mlstm_chunked_vs_sequential(b, s, h, d, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, d))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    y1, (C1, n1, m1) = ref.mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
    y2, (C2, n2, m2) = ref.mlstm_sequential(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2),
                               atol=2e-5, rtol=2e-4)


def test_mlstm_decode_parity():
    b, s, h, d = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    q = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, d))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    y_seq, _ = ref.mlstm_sequential(q, k, v, ig, fg)
    state = None
    outs = []
    import jax.numpy as jnp
    C = jnp.zeros((b, h, d, d)); n = jnp.zeros((b, h, d))
    m = jnp.full((b, h), -jnp.inf)
    state = (C, n, m)
    for t in range(s):
        state, yt = ref.mlstm_decode_step(state, q[:, t], k[:, t], v[:, t],
                                          ig[:, t], fg[:, t])
        outs.append(yt)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# mlstm Pallas kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,d,chunk", [(2, 64, 2, 16, 16),
                                           (1, 128, 4, 32, 64)])
def test_mlstm_kernel_vs_sequential(b, s, h, d, chunk, dtype):
    from repro.kernels.mlstm_chunk import mlstm_chunk
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    q = (jax.random.normal(ks[0], (b, s, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, s, h, d)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, d)).astype(dtype)
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    y, (C, n, m) = mlstm_chunk(q, k, v, ig, fg, chunk=chunk,
                               interpret=True)
    y_ref, (C_ref, n_ref, m_ref) = ref.mlstm_sequential(q, k, v, ig, fg)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                               atol=2e-2 if dtype == jnp.bfloat16 else 2e-4,
                               rtol=2e-2)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused collective-stage kernels (Pallas executor tier)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", [64, 257, 1031, 4096])   # incl. odd sizes
@pytest.mark.parametrize("wire", ["fp32", "bf16", "int8"])
def test_fused_combine_stage_parity(m, wire):
    rng = np.random.default_rng(m)
    acc = jnp.asarray(rng.standard_normal(m), jnp.float32)
    got32 = jnp.asarray(rng.standard_normal(m), jnp.float32)
    if wire == "fp32":
        got, scale = got32, None
    elif wire == "bf16":
        got, scale = got32.astype(jnp.bfloat16), None
    else:
        got, scale = ops.quantize_stage(got32, impl="ref")
    want = ref.combine_stage(acc, got, scale)
    fused = ops.combine_stage(acc, got, scale, impl="pallas_interpret")
    assert fused.dtype == want.dtype == jnp.float32
    if wire == "int8":
        # the dequant multiply-add may contract to an FMA inside the
        # kernel but not in the XLA oracle — 1 ULP of fp32 slack
        np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                                   atol=2e-6)
    else:
        # fp32 (and the widening bf16 cast) must be bit-identical
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))
    inst = ops.combine_stage(acc, got, scale, accumulate=False,
                             impl="pallas_interpret")
    want_inst = ref.combine_stage(acc, got, scale, accumulate=False)
    if wire == "int8":
        np.testing.assert_allclose(np.asarray(inst), np.asarray(want_inst),
                                   atol=2e-6)
    else:
        np.testing.assert_array_equal(np.asarray(inst),
                                      np.asarray(want_inst))


@pytest.mark.parametrize("m", [63, 640, 2049])
def test_quantize_dequantize_stage_parity(m):
    rng = np.random.default_rng(m)
    x = jnp.asarray(rng.standard_normal(m) * 11.0, jnp.float32)
    q, scale = ops.quantize_stage(x, impl="pallas_interpret")
    q_ref, scale_ref = ops.quantize_stage(x, impl="ref")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(scale_ref))
    assert q.dtype == jnp.int8
    deq = ops.dequantize_stage(q, scale, impl="pallas_interpret")
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(ref.dequantize_stage(q, scale,
                                                         jnp.float32)))
    # round-trip error bounded by the uniform quantization step
    step = float(scale)
    assert np.max(np.abs(np.asarray(deq) - np.asarray(x))) <= step


@pytest.mark.parametrize("H,W", [(16, 16), (8, 32), (17, 5)])
def test_gs_stencil_kernel_parity(H, W):
    rng = np.random.default_rng(H * W)
    block = jnp.asarray(rng.standard_normal((H, W)), jnp.float32)
    top = jnp.asarray(rng.standard_normal(W), jnp.float32)
    bottom = jnp.asarray(rng.standard_normal(W), jnp.float32)
    left = jnp.asarray(rng.standard_normal(H), jnp.float32)
    right = jnp.asarray(rng.standard_normal(H), jnp.float32)
    new, res, edges = ops.gs_stencil(block, top, left, bottom, right,
                                     impl="pallas_interpret")
    new_r, res_r, edges_r = ref.gs_stencil(block, top, left, bottom, right)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(res), np.asarray(res_r))
    for e, er in zip(edges, edges_r):
        np.testing.assert_array_equal(np.asarray(e), np.asarray(er))
    # the edge tuple is (top, bottom, left, right) rows of the NEW block
    np.testing.assert_array_equal(np.asarray(edges[0]),
                                  np.asarray(new)[0])
    np.testing.assert_array_equal(np.asarray(edges[3]),
                                  np.asarray(new)[:, -1])
