"""Distribution-layer tests on 8 local host devices.

jax locks the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """One train step on a (4,2) mesh must match the unsharded step."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs, optim
from repro.models import model, inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh

cfg = configs.smoke("granite_3_2b").scaled(dtype="float32")
opt_cfg = optim.OptimConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
key = jax.random.PRNGKey(0)
state = steps.init_train_state(cfg, opt_cfg, key)
batch = inputs.make_batch(cfg, batch=8, seq=32, key=key)

# single-device reference
def ref_step(state, batch):
    from repro.runtime.steps import _loss_fn
    (total, loss), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
        state.params, batch, cfg, None, None)
    new_params, new_opt, m = optim.update(opt_cfg, grads, state.opt,
                                          state.params)
    return steps.TrainState(new_params, new_opt), dict(m, loss=loss)

ref_state, ref_m = jax.jit(ref_step)(state, batch)

mesh = make_mesh((4, 2), ("data", "model"))
policy = ShardingPolicy(fsdp=True, tp=True, sp=True, remat=None)
with mesh:
    abatch = jax.eval_shape(lambda: batch)
    jitted, sshard = steps.build_train_step(cfg, mesh, policy, opt_cfg,
                                            abstract_batch=abatch,
                                            donate=False)
    state_sharded = jax.device_put(state, sshard)
    from repro.runtime.sharding import batch_shardings
    bsh = batch_shardings(mesh, abatch)
    batch_sharded = jax.device_put(batch, bsh)
    new_state, m = jitted(state_sharded, batch_sharded)

np.testing.assert_allclose(float(m["loss"]), float(ref_m["loss"]),
                           rtol=2e-5)
np.testing.assert_allclose(float(m["grad_norm"]), float(ref_m["grad_norm"]),
                           rtol=2e-4)
# parameters after one step must match
ra, rb = jax.tree_util.tree_flatten(ref_state.params)[0], \
         jax.tree_util.tree_flatten(jax.device_get(new_state.params))[0]
for a, b in zip(ra, rb):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-4)
print("SHARDED-OK")
""")


def test_manual_grad_sync_modes_match():
    """fused / bucketed / sentinel grad-sync must agree with auto mode."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs, optim
from repro.models import model, inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh

cfg = configs.smoke("granite_3_2b").scaled(dtype="float32")
opt_cfg = optim.OptimConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
key = jax.random.PRNGKey(0)
state = steps.init_train_state(cfg, opt_cfg, key)
batch = inputs.make_batch(cfg, batch=8, seq=32, key=key)
# Manual-DP execution uses a DP-only mesh: the CPU backend's collective
# rendezvous deadlocks when manual data-axis psums interleave with
# auto model-axis collectives (scheduling order differs per group).  The
# 16x16 production analysis of these schedules is compile-only.
mesh = make_mesh((8, 1), ("data", "model"))
abatch = jax.eval_shape(lambda: batch)

losses = {}
for mode in ("fused", "bucketed", "sentinel"):
    policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None,
                            grad_sync=mode)
    with mesh:
        make = steps.build_train_step_manual(cfg, mesh, policy, opt_cfg,
                                             bucket_bytes=1 << 16)
        f = make(jax.eval_shape(lambda: state), abatch)
        new_state, m = f(state, batch)
    losses[mode] = (float(m["loss"]), float(m["grad_norm"]),
                    jax.device_get(new_state.params))

base = losses["fused"]
for mode in ("bucketed", "sentinel"):
    assert abs(losses[mode][0] - base[0]) < 1e-5, mode
    assert abs(losses[mode][1] - base[1]) < 1e-4, mode
    fa = jax.tree_util.tree_leaves(base[2])
    fb = jax.tree_util.tree_leaves(losses[mode][2])
    for a, b in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)
print("MANUAL-OK")
""")


def test_grad_sync_hlo_schedules_differ():
    """Structural check on the program AS WRITTEN (pre-optimization
    StableHLO): fused issues one gradient all-reduce, bucketed issues many
    independent ones, sentinel chains them through optimization_barriers.

    The comparison is deliberately pre-combiner: XLA's AllReduceCombiner
    (threshold-controlled on TPU via --xla_..._combine_threshold_bytes, and
    the CPU backend additionally strips optimization_barriers) re-fuses
    small collectives — which is exactly the production knob the bucketed
    schedule trades against; see EXPERIMENTS.md §Perf.
    """
    _run("""
import jax, jax.numpy as jnp
from repro import configs, optim
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh

cfg = configs.smoke("granite_3_2b").scaled(dtype="float32")
opt_cfg = optim.OptimConfig()
key = jax.random.PRNGKey(0)
state = steps.init_train_state(cfg, opt_cfg, key)
batch = inputs.make_batch(cfg, batch=8, seq=32, key=key)
mesh = make_mesh((8, 1), ("data", "model"))
abatch = jax.eval_shape(lambda: batch)

counts, barriers = {}, {}
for mode in ("fused", "bucketed", "sentinel"):
    policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None,
                            grad_sync=mode)
    with mesh:
        make = steps.build_train_step_manual(cfg, mesh, policy, opt_cfg,
                                             bucket_bytes=1 << 14)
        f = make(jax.eval_shape(lambda: state), abatch)
        txt = f.lower(state, batch).as_text()   # pre-optimization
    counts[mode] = txt.count("all_reduce")
    barriers[mode] = txt.count("optimization_barrier")
assert counts["bucketed"] > counts["fused"], counts
assert counts["sentinel"] == counts["bucketed"], counts
assert barriers["sentinel"] > 0 and barriers["bucketed"] == 0, barriers
print("SCHEDULES-OK", counts, barriers)
""")


def test_elastic_restore_across_meshes():
    """A checkpoint saved on one mesh restores onto a different mesh."""
    _run("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro import configs, optim
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy, param_shardings
from repro.launch.mesh import make_mesh
from repro.checkpoint import save_checkpoint, restore_checkpoint

cfg = configs.smoke("granite_3_2b").scaled(dtype="float32")
opt_cfg = optim.OptimConfig()
state = steps.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))

mesh_a = make_mesh((4, 2), ("data", "model"))
mesh_b = make_mesh((2, 4), ("data", "model"))
pol = ShardingPolicy()
sa = steps.state_shardings(mesh_a, jax.eval_shape(lambda: state), pol)
state_a = jax.device_put(state, sa)

import os
d = tempfile.mkdtemp()
save_checkpoint(d, state_a, step=7)
sb = steps.state_shardings(mesh_b, jax.eval_shape(lambda: state), pol)
restored, step = restore_checkpoint(d, jax.eval_shape(lambda: state), sb)
assert step == 7
for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(state_a)),
                jax.tree_util.tree_leaves(jax.device_get(restored))):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC-OK")
""")
