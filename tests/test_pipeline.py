"""Pipeline-parallelism tests (core/pipeline.py) — subprocess: 4 devices."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_pipeline_matches_sequential():
    """4-stage GPipe over 4 devices == sequential stage application, and
    the schedule really lowers to collective-permutes."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.core.pipeline import pipeline_apply, bubble_fraction

mesh = make_mesh((4,), ("stage",))
S, M, B, D = 4, 6, 2, 16
ks = jax.random.split(jax.random.PRNGKey(0), S + 1)
stage_params = {
    "w": jnp.stack([jax.random.normal(ks[i], (D, D)) / jnp.sqrt(D)
                    for i in range(S)]),
    "b": jnp.stack([jax.random.normal(ks[i], (D,)) * 0.1
                    for i in range(S)]),
}
x = jax.random.normal(ks[S], (M, B, D))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

out = pipeline_apply(stage_fn, stage_params, x, mesh=mesh)

ref = x
for s in range(S):
    ref = stage_fn(jax.tree_util.tree_map(lambda q, s=s: q[s],
                                          stage_params), ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)

lo = jax.jit(lambda p, x: pipeline_apply(stage_fn, p, x, mesh=mesh)) \\
    .lower(stage_params, x)
txt = lo.compile().as_text()
assert "collective-permute" in txt
assert abs(bubble_fraction(4, 6) - 3 / 9) < 1e-9
print("PIPELINE-OK")
""")
