"""Per-architecture smoke tests (reduced configs of the same family).

For each of the 10 assigned architectures:
* one forward/train step on CPU asserting output shapes + no NaNs;
* gradients exist and are finite;
* for decoders: prefill + one-step decode agrees with the full forward at
  the last position (cache-parity — exercises every stateful block's
  decode path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model, inputs
from repro.models.config import applicable_shapes

ARCHS = list(configs.ARCHS)


def _smoke_cfg(name):
    return configs.smoke(name).scaled(dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = _smoke_cfg(arch)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    B, S = 2, 32
    batch = inputs.make_batch(cfg, batch=B, seq=S, key=key)

    def loss_fn(p):
        logits, _, aux = model.apply(p, cfg, batch, mode="train")
        return model.lm_loss(logits, batch["labels"]) + 0.01 * aux, logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{arch}: non-finite grads"
    # loss should be near ln(vocab) at random init (sanity on scale)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    cfg = configs.get(arch)
    smoke_cfg = _smoke_cfg(arch)
    params = jax.eval_shape(lambda k: model.init(smoke_cfg, k),
                            jax.random.PRNGKey(0))
    assert model.param_count(params) > 0
    assert 0 < model.active_param_count(params, smoke_cfg) \
        <= model.param_count(params)
    # full config param count (abstract init only — no allocation)
    full = jax.eval_shape(lambda k: model.init(cfg, k), jax.random.PRNGKey(0))
    n = model.param_count(full)
    assert n > 1e8, f"{arch}: suspicious full param count {n}"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert_xlarge"])
def test_prefill_decode_parity(arch):
    """logits(full forward)[:, -1] == logits(prefill S-1 → decode 1 step).

    MoE capacity is raised so no token is dropped: capacity dropping is
    population-dependent by design (Switch-style), which would legitimately
    break parity between the two passes.
    """
    cfg = _smoke_cfg(arch).scaled(capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = model.init(cfg, key)
    B, S = 2, 17
    batch = inputs.make_batch(cfg, batch=B, seq=S, kind="prefill", key=key)

    logits_full, _, _ = model.apply(params, cfg, batch, mode="train")

    pre_batch = {k: (v[:, :S - 1] if k in ("tokens", "embeds") else v)
                 for k, v in batch.items()}
    _, cache, _ = model.apply(params, cfg, pre_batch, mode="prefill")
    cache = model.pad_cache(cfg, cache, S)
    dec_batch = {"tokens": batch["tokens"][:, S - 1:]}
    logits_dec, new_cache, _ = model.apply(
        params, cfg, dec_batch, mode="decode", cache=cache,
        cache_index=jnp.int32(S - 1))

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        atol=2e-4, rtol=2e-3)
    assert jax.tree_util.tree_structure(new_cache) \
        == jax.tree_util.tree_structure(cache)


def test_applicable_shapes_rules():
    """Assignment skip rules (documented in DESIGN.md)."""
    names = {a: {s.name for s in applicable_shapes(configs.get(a))}
             for a in ARCHS}
    for a in ARCHS:
        assert "train_4k" in names[a] and "prefill_32k" in names[a]
    assert "decode_32k" not in names["hubert_xlarge"]      # encoder-only
    assert "long_500k" in names["zamba2_2p7b"]             # hybrid: runs
    assert "long_500k" in names["xlstm_350m"]              # ssm: runs
    for a in ("phi3_medium_14b", "granite_3_2b", "deepseek_coder_33b",
              "starcoder2_15b", "internvl2_2b", "olmoe_1b_7b",
              "mixtral_8x22b", "hubert_xlarge"):
        assert "long_500k" not in names[a]                 # full attention
    total = sum(len(v) for v in names.values())
    assert total == 31  # 40 assigned cells − 9 rule-based skips


def test_exact_published_dimensions():
    """The full configs must match the assignment block verbatim."""
    spec = {
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = configs.get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, kv, ff, V), arch
    assert configs.get("zamba2_2p7b").ssm_state == 64
    assert configs.get("olmoe_1b_7b").n_experts == 64
    assert configs.get("olmoe_1b_7b").top_k == 8
    assert configs.get("mixtral_8x22b").n_experts == 8
    assert configs.get("mixtral_8x22b").top_k == 2
