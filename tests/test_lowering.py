"""Level-B lowering tests: one schedule IR driving in-graph execution.

jax locks the device count at first init, so these run in subprocesses
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (same pattern as
tests/test_distributed.py).  Structural equivalence claims: the lowered
ppermute counts mirror the schedule's transfer structure, and the
numerics match ``lax.psum`` / the host-side reference.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_lowered_allreduce_matches_psum_and_schedule_structure():
    """Ring (segmented and not) and butterfly lowerings equal psum, and
    each emits exactly the schedule's per-rank transfer count of
    collective-permutes."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import lowering
from repro.core import schedule as schedule_ir

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
want = np.asarray(jnp.sum(x, axis=0))

for alg, seg in (("ring", 1), ("ring", 4), ("doubling", 1)):
    def f(xl):
        return lowering.allreduce(xl.reshape(-1), ("data",),
                                  algorithm=alg, segments=seg)
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                           axis_names={"data"}, check_vma=False))
    got = np.asarray(sf(x.reshape(-1)))
    assert np.max(np.abs(got - want)) < 1e-3, (alg, seg)
    txt = sf.lower(x.reshape(-1)).as_text()
    sched = schedule_ir.build("allreduce", alg, 8, segments=seg)
    n_pp = txt.count("collective_permute")
    assert n_pp == lowering.sends_per_rank(sched), (alg, seg, n_pp)
    assert txt.count("all_reduce") == 0, (alg, seg)

# native = one fused node (the sync_grads default)
def g(xl):
    return lowering.allreduce(xl.reshape(-1), ("data",))
sg = jax.jit(shard_map(g, mesh=mesh, in_specs=P("data"), out_specs=P(),
                       axis_names={"data"}, check_vma=False))
txt = sg.lower(x.reshape(-1)).as_text()
assert txt.count("all_reduce") == 1 and txt.count("collective_permute") == 0
print("LOWERED-ALLREDUCE-OK")
""")


def test_lowered_allreduce_non_divisible_payload():
    """Padding path: payload not divisible by n×segments."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import lowering

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 173))   # 173 % 8 != 0
def f(xl):
    return lowering.allreduce(xl.reshape(-1), ("data",),
                              algorithm="ring", segments=3)
sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                       axis_names={"data"}, check_vma=False))
got = np.asarray(sf(x.reshape(-1)))
want = np.asarray(jnp.sum(x, axis=0))
assert got.shape == want.shape
assert np.max(np.abs(got - want)) < 1e-3
print("PAD-OK")
""")


def test_halo_exchange_rows_executes_neighbor_schedule():
    """halo_exchange_rows = the 1-D neighbourhood schedule lowered: two
    ppermutes, boundary shards get zero halos, interior shards get their
    neighbours' edge rows — and the result matches the host-side
    HaloExchange run of the SAME schedule."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import tac
from repro.core.collectives import HaloExchange
from repro.core.overlap import halo_exchange_rows

mesh = make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(1), (32, 5))    # 8 shards x 4 rows
def halo(xl):
    t, b = halo_exchange_rows(xl, "data", width=1)
    return jnp.concatenate([t, b], axis=0)
sh = jax.jit(shard_map(halo, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data"), axis_names={"data"},
                       check_vma=False))
out = np.asarray(sh(g))
assert sh.lower(g).as_text().count("collective_permute") == 2

# host-side execution of the same 1-D neighbourhood schedule
world = tac.CommWorld(8)
cart = world.cart_create((8,), periodic=False)
hx = HaloExchange(cart)
gnp = np.asarray(g)
blocks = [gnp[r * 4:(r + 1) * 4] for r in range(8)]
sends = [{d: (blocks[r][-1:] if d == (0, 1) else blocks[r][:1])
          for d, _ in hx.neighbors(r)} for r in range(8)]
got = hx.run_group(sends)
for r in range(8):
    top = got[r].get((0, -1), np.zeros((1, 5)))
    bot = got[r].get((0, 1), np.zeros((1, 5)))
    np.testing.assert_allclose(out[2 * r], top[0], atol=1e-6)
    np.testing.assert_allclose(out[2 * r + 1], bot[0], atol=1e-6)
print("HALO-PARITY-OK")
""")


def test_sync_grads_explicit_ring_matches_native():
    """sync_grads(algorithm="ring") — the bucketed schedule lowered to
    explicit rounds — agrees with the default fused-node lowering."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import sync_grads

mesh = make_mesh((8,), ("data",))
n = 3000
xs = jax.random.normal(jax.random.PRNGKey(2), (8, n))

outs = {}
for alg, seg in (("native", 1), ("ring", 1), ("ring", 2)):
    def f(xl):
        out = sync_grads({"w": xl, "b": xl[:7] * 2.0}, axes=("data",),
                         mode="bucketed", bucket_bytes=1 << 12,
                         algorithm=alg, segments=seg)
        return out["w"], out["b"]
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                           out_specs=P(), axis_names={"data"},
                           check_vma=False))
    outs[(alg, seg)] = [np.asarray(o) for o in sf(xs.reshape(-1))]
    txt = sf.lower(xs.reshape(-1)).as_text()
    if alg == "native":
        assert txt.count("all_reduce") > 0
    else:
        assert txt.count("all_reduce") == 0
        assert txt.count("collective_permute") > 0

ref = outs[("native", 1)]
for k, v in outs.items():
    for a, b in zip(ref, v):
        np.testing.assert_allclose(a, b, atol=1e-5), k
print("SYNC-GRADS-RING-OK")
""")


def test_bucketing_uses_wire_dtype_bytes():
    """Satellite: buckets are sized by each leaf's actual bytes AS SENT
    (wire-dtype itemsize), not a hardcoded 4 B/element — under
    ``wire="leaf"`` a bf16 leaf packs twice the elements of an fp32 leaf
    per bucket AND travels in bf16; the default ``wire="fp32"`` keeps the
    pre-IR fp32 accumulation (bf16 is the repo's default model dtype, so
    narrower accumulation must stay opt-in)."""
    _run("""
import jax.numpy as jnp, jax
from repro.core.overlap import _make_buckets

# 6 leaves of 1024 elements; bucket budget 8 KiB.
f32 = [1024 * 4] * 6        # 4 KiB each -> 2 per bucket -> 3 buckets
bf16 = [1024 * 2] * 6       # 2 KiB each -> 4 per bucket -> 2 buckets
i8 = [1024 * 1] * 6         # 1 KiB each -> 6 fit with room -> 1 bucket
assert len(_make_buckets(f32, 8 << 10)) == 3
assert len(_make_buckets(bf16, 8 << 10)) == 2
assert len(_make_buckets(i8, 8 << 10)) == 1

# and sync_grads derives those bytes from the leaves' WIRE dtype
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import sync_grads
mesh = make_mesh((8,), ("data",))
leaves32 = {f"w{i}": jnp.zeros(1024, jnp.float32) for i in range(6)}
leaves16 = {f"w{i}": jnp.zeros(1024, jnp.bfloat16) for i in range(6)}
def lowered(tree, **kw):
    def f(_x):
        return sync_grads(tree, axes=("data",), mode="bucketed",
                          bucket_bytes=8 << 10, **kw)
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                           axis_names={"data"}, check_vma=False))
    return sf.lower(jnp.zeros((8,)))
def n_ar(low):
    return low.as_text().count("stablehlo.all_reduce")
assert n_ar(lowered(leaves32)) == 3
# default: bf16 leaves upcast to fp32 (pre-IR numerics) -> fp32 sizing;
# no reduction region computes in bf16 (scalar tensor<bf16> appears only
# inside a bf16 all-reduce's region — input casts are ranked tensors)
low_def = lowered(leaves16)
assert n_ar(low_def) == 3
assert "tensor<bf16>" not in low_def.as_text()
# wire="leaf": bf16 stays bf16 -> 2 KiB/leaf sizing, bf16 on the wire
low_leaf = lowered(leaves16, wire="leaf")
assert n_ar(low_leaf) == 2
assert "tensor<bf16>" in low_leaf.as_text()
print("BUCKET-DTYPE-OK")
""")


def test_hierarchical_lowering_two_axis_counts_and_numerics():
    """Tentpole: ONE build_hierarchical schedule lowered over two mesh
    axes.  On (inter × intra) grids 2×4 and 4×2 the lowering emits
    exactly the schedule's per-stage structure — (intra-1) reduce-scatter
    ppermutes + log2(inter) butterfly ppermutes + (intra-1) allgather
    ppermutes, zero all-reduces — and agrees numerically with plain psum
    over both axes AND with the Level-A host interpretation of the SAME
    schedule object.  The compiled-HLO collective counts are
    cross-checked through repro.analysis.hlo_cost."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import lowering, tac
from repro.core import schedule as schedule_ir
from repro.core.collectives import Collectives
from repro.analysis.hlo_cost import module_cost

x = jax.random.normal(jax.random.PRNGKey(0), (8 * 500,))
want = np.asarray(x.reshape(8, -1).sum(axis=0))

for inter, intra in ((2, 4), (4, 2)):
    mesh = make_mesh((inter, intra), ("pod", "data"))
    sched = schedule_ir.build_hierarchical(intra, inter)

    def f(xl):
        return lowering.lower_allreduce(sched, xl, ("pod", "data"))
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                           out_specs=P(), axis_names={"pod", "data"},
                           check_vma=False))
    got = np.asarray(sf(x))
    assert np.max(np.abs(got - want)) < 1e-3, (inter, intra)

    # psum parity
    def g(xl):
        return lowering.allreduce(xl, ("pod", "data"))
    sg = jax.jit(shard_map(g, mesh=mesh, in_specs=P(("pod", "data")),
                           out_specs=P(), axis_names={"pod", "data"},
                           check_vma=False))
    np.testing.assert_allclose(got, np.asarray(sg(x)), atol=1e-3)

    # per-stage ppermute counts in the program as written
    txt = sf.lower(x).as_text()
    exp_pp = 2 * (intra - 1) + (inter.bit_length() - 1)
    assert txt.count("collective_permute") == exp_pp, (inter, intra)
    assert txt.count("all_reduce") == 0, (inter, intra)
    assert exp_pp == lowering.sends_per_rank(sched)

    # compiled-HLO cross-check via the loop-aware cost analyzer
    cost = module_cost(sf.lower(x).compile().as_text(), n_devices=8)
    assert cost.coll_counts["collective-permute"] == exp_pp, (inter, intra)
    assert cost.coll_counts["all-reduce"] == 0

    # Level-A host interpretation of the SAME schedule object
    world = tac.CommWorld(8)
    coll = Collectives(world)
    shards = [np.asarray(x).reshape(8, -1)[r] for r in range(8)]
    host = coll.run_group("allreduce", [{"value": v} for v in shards],
                          hierarchical=intra)
    for h in host:
        np.testing.assert_allclose(h, want, atol=1e-3)
print("HIER-TWO-AXIS-OK")
""")


def test_hierarchical_lowering_non_pow2_inter_uses_fused_stage():
    """Non-power-of-two pod counts keep the intra ring rounds explicit
    and lower the inter stage to ONE fused psum of the owned chunk (the
    same trade the flat non-pow2 doubling makes)."""
    r = subprocess.run(
        [sys.executable, "-c", """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import lowering

mesh = make_mesh((3, 2), ("pod", "data"))
x = jax.random.normal(jax.random.PRNGKey(1), (6 * 301,))
want = np.asarray(x.reshape(6, -1).sum(axis=0))
def f(xl):
    return lowering.allreduce(xl, ("pod", "data"),
                              algorithm="hierarchical")
sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                       out_specs=P(), axis_names={"pod", "data"},
                       check_vma=False))
assert np.max(np.abs(np.asarray(sf(x)) - want)) < 1e-3
txt = sf.lower(x).as_text()
assert txt.count("collective_permute") == 2      # intra rounds (intra=2)
assert txt.count("all_reduce") == 1              # fused inter stage
print("HIER-NONPOW2-OK")
"""],
        env=dict(_ENV, XLA_FLAGS="--xla_force_host_platform_device_count=6"),
        capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\\n{r.stdout}\\nstderr:\\n{r.stderr}"


def test_sync_grads_hierarchical_two_axis():
    """sync_grads(hierarchical=True) reduces every bucket with the
    composed two-axis schedule: numerics match the native fused psum over
    both DP axes, buckets keep their count, and the HLO carries ppermutes
    instead of all-reduces."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import sync_grads

mesh = make_mesh((2, 4), ("pod", "data"))
n = 3000
xs = jax.random.normal(jax.random.PRNGKey(2), (8 * n,))

outs = {}
for hier in (False, True):
    def f(xl):
        out = sync_grads({"w": xl, "b": xl[:7] * 2.0},
                         axes=("pod", "data"), mode="bucketed",
                         bucket_bytes=1 << 12, hierarchical=hier)
        return out["w"], out["b"]
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                           out_specs=P(), axis_names={"pod", "data"},
                           check_vma=False))
    outs[hier] = [np.asarray(o) for o in sf(xs)]
    txt = sf.lower(xs).as_text()
    if hier:
        assert txt.count("all_reduce") == 0
        assert txt.count("collective_permute") > 0
    else:
        assert txt.count("all_reduce") > 0

for a, b in zip(outs[False], outs[True]):
    np.testing.assert_allclose(a, b, atol=1e-5)

# axis-count validation
try:
    sync_grads({"w": jnp.zeros(4)}, axes=("data",), hierarchical=True)
except ValueError as e:
    assert "two DP axes" in str(e)
else:
    raise AssertionError("expected ValueError for one axis")
print("SYNC-GRADS-HIER-OK")
""")


def test_stage_impl_fused_ring_bit_parity_and_wire_counts():
    """The fused-stage ring (stage_impl=) is bit-identical to the legacy
    combine path and keeps the ppermute count; a bf16 wire keeps the
    count (pure cast), an int8 wire doubles it (payload + scale)."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import lowering
from repro.core import schedule as schedule_ir

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(7), (8, 1000))
want = np.asarray(jnp.sum(x, axis=0))

def lower(alg, seg, stage_impl=None, wire=None):
    def f(xl):
        return lowering.allreduce(xl.reshape(-1), ("data",), algorithm=alg,
                                  segments=seg, stage_impl=stage_impl,
                                  wire=wire)
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                             out_specs=P(), axis_names={"data"},
                             check_vma=False))

for alg, seg in (("ring", 1), ("ring", 4), ("doubling", 1)):
    base = lower(alg, seg)
    fused = lower(alg, seg, stage_impl="pallas_interpret")
    got_b = np.asarray(base(x.reshape(-1)))
    got_f = np.asarray(fused(x.reshape(-1)))
    assert np.array_equal(got_b, got_f), (alg, seg)   # bit parity, fp32
    sched = schedule_ir.build("allreduce", alg, 8, segments=seg)
    n_base = base.lower(x.reshape(-1)).as_text().count("collective_permute")
    n_fused = fused.lower(x.reshape(-1)).as_text().count(
        "collective_permute")
    assert n_base == n_fused == lowering.sends_per_rank(sched), (alg, seg)

ring = schedule_ir.build("allreduce", "ring", 8)
for wire, factor, tol in (("bf16", 1, 2e-2), ("int8", 2, 5e-2)):
    f = lower("ring", 1, stage_impl="pallas_interpret", wire=wire)
    got = np.asarray(f(x.reshape(-1)))
    rel = np.max(np.abs(got - want)) / np.max(np.abs(want))
    assert rel < tol, (wire, rel)
    n_pp = f.lower(x.reshape(-1)).as_text().count("collective_permute")
    # int8 forwards a scale alongside every payload permute
    rs = 7                       # reduce-scatter rounds (n-1)
    expect = lowering.sends_per_rank(ring) + (rs + 7) * (factor - 1)
    assert n_pp == expect, (wire, n_pp, expect)
print("stage parity OK")
""")


def test_stage_impl_option_validation():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import lowering
for bad in (dict(algorithm="native", stage_impl="ref"),
            dict(algorithm="native", wire="bf16"),
            dict(algorithm="doubling", stage_impl="ref", wire="bf16"),
            dict(algorithm="ring", wire="bf16")):        # wire w/o stage
    try:
        lowering.allreduce(jnp.zeros(8), ("data",), **bad)
    except ValueError:
        pass
    else:
        raise AssertionError(f"accepted {bad}")
try:
    lowering.allreduce(jnp.zeros(8), ("data",), algorithm="ring",
                       stage_impl="nope")
except ValueError:
    pass
else:
    raise AssertionError("accepted bogus stage_impl")
print("validation OK")
""")


def test_sync_grads_stage_tier_passthrough():
    """sync_grads(stage_impl=) is bit-identical to the plain ring path;
    stage_wire="bf16" narrows the wire within bf16 tolerance; combining
    compress="int8" with the stage tier is rejected."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core import overlap

mesh = make_mesh((8,), ("data",))
g = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 64, 17))}

def run(**kw):
    def f(gl):
        return overlap.sync_grads(gl, axes=("data",), algorithm="ring",
                                  mean=False, **kw)
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                           axis_names={"data"}, check_vma=False))
    return np.asarray(sf(g)["w"])

base = run()
assert np.array_equal(base, run(stage_impl="pallas_interpret"))
want = np.asarray(jnp.sum(g["w"], axis=0))
rel = np.max(np.abs(run(stage_impl="pallas_interpret", stage_wire="bf16")
                    - want)) / np.max(np.abs(want))
assert rel < 2e-2, rel
try:
    run(compress="int8", stage_impl="pallas_interpret")
except ValueError:
    pass
else:
    raise AssertionError("compress=int8 + stage tier accepted")
print("sync_grads stage OK")
""")
