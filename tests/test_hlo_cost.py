"""Validation of the loop-aware HLO cost analyzer (analysis/hlo_cost.py).

``compiled.cost_analysis()`` counts while-loop bodies once; our analyzer
must multiply by trip counts — verified against programs of known cost.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import module_cost, ModuleCost


def _scanned(x, ws):
    y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
    return y.sum()


@pytest.mark.parametrize("L", [3, 8])
def test_forward_scan_flops(L):
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
    txt = jax.jit(_scanned).lower(x, ws).compile().as_text()
    c = module_cost(txt)
    dots = 2 * 128 * 256 * 256 * L
    assert dots <= c.flops <= 1.1 * dots  # dots + small elementwise


@pytest.mark.parametrize("L", [3, 8])
def test_grad_scan_flops(L):
    """Backward adds 2x the forward matmul cost (reversed loop: the trip
    count lives in the init tuple, not the condition)."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
    txt = jax.jit(jax.grad(_scanned, argnums=1)).lower(x, ws) \
        .compile().as_text()
    c = module_cost(txt)
    dots = 3 * 2 * 128 * 256 * 256 * L
    assert 0.95 * dots <= c.flops <= 1.15 * dots


def test_unrolled_matches_scanned():
    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x = jnp.tanh(x @ ws[i])
        return x.sum()

    L = 6
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    cs = module_cost(jax.jit(_scanned).lower(x, ws).compile().as_text())
    cu = module_cost(jax.jit(unrolled).lower(x, ws).compile().as_text())
    assert abs(cs.flops - cu.flops) / cu.flops < 0.15


def test_bytes_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b4 = module_cost(jax.jit(_scanned).lower(
        x, jax.ShapeDtypeStruct((4, 256, 256), jnp.float32))
        .compile().as_text()).bytes
    b8 = module_cost(jax.jit(_scanned).lower(
        x, jax.ShapeDtypeStruct((8, 256, 256), jnp.float32))
        .compile().as_text()).bytes
    # XLA may fuse/unroll the two trip counts differently, so the ratio is
    # only approximately 2x — the test guards against counting the loop
    # body once (ratio 1.0) or quadratically (ratio 4.0).
    assert 1.4 < b8 / b4 < 3.5


def test_dot_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = module_cost(jax.jit(f).lower(a, b).compile().as_text())
    expected = 2 * 4 * 32 * 16 * 64
    assert expected <= c.flops <= 1.05 * expected + 1e4


def test_collective_counts_and_wire_bytes():
    """The analyzer's collective accounting on a hand-written module —
    the counts the hierarchical-lowering tests assert on compiled
    programs, pinned here against known shapes: per-kind counts, operand
    bytes, and ring-model wire bytes (all-reduce 2(g-1)/g, permute 1x)."""
    txt = """
HloModule synthetic

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %cp1 = f32[1024]{0} collective-permute(%p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %cp2 = f32[1024]{0} collective-permute(%cp1), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %ar = f32[1024]{0} all-reduce(%cp2), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[1024]{0} add(%ar, %cp1)
}
"""
    c = module_cost(txt, n_devices=4)
    assert c.coll_counts["collective-permute"] == 2
    assert c.coll_counts["all-reduce"] == 1
    assert c.coll_bytes["collective-permute"] == 2 * 4096
    assert c.coll_wire_bytes["collective-permute"] == 2 * 4096  # 1x factor
    # all-reduce over a 4-rank group: 2(g-1)/g of the operand bytes
    assert c.coll_wire_bytes["all-reduce"] == pytest.approx(
        4096 * 2 * 3 / 4)
