"""Validation of the loop-aware HLO cost analyzer (analysis/hlo_cost.py).

``compiled.cost_analysis()`` counts while-loop bodies once; our analyzer
must multiply by trip counts — verified against programs of known cost.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import module_cost, ModuleCost


def _scanned(x, ws):
    y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
    return y.sum()


@pytest.mark.parametrize("L", [3, 8])
def test_forward_scan_flops(L):
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
    txt = jax.jit(_scanned).lower(x, ws).compile().as_text()
    c = module_cost(txt)
    dots = 2 * 128 * 256 * 256 * L
    assert dots <= c.flops <= 1.1 * dots  # dots + small elementwise


@pytest.mark.parametrize("L", [3, 8])
def test_grad_scan_flops(L):
    """Backward adds 2x the forward matmul cost (reversed loop: the trip
    count lives in the init tuple, not the condition)."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
    txt = jax.jit(jax.grad(_scanned, argnums=1)).lower(x, ws) \
        .compile().as_text()
    c = module_cost(txt)
    dots = 3 * 2 * 128 * 256 * 256 * L
    assert 0.95 * dots <= c.flops <= 1.15 * dots


def test_unrolled_matches_scanned():
    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x = jnp.tanh(x @ ws[i])
        return x.sum()

    L = 6
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    cs = module_cost(jax.jit(_scanned).lower(x, ws).compile().as_text())
    cu = module_cost(jax.jit(unrolled).lower(x, ws).compile().as_text())
    assert abs(cs.flops - cu.flops) / cu.flops < 0.15


def test_bytes_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b4 = module_cost(jax.jit(_scanned).lower(
        x, jax.ShapeDtypeStruct((4, 256, 256), jnp.float32))
        .compile().as_text()).bytes
    b8 = module_cost(jax.jit(_scanned).lower(
        x, jax.ShapeDtypeStruct((8, 256, 256), jnp.float32))
        .compile().as_text()).bytes
    # XLA may fuse/unroll the two trip counts differently, so the ratio is
    # only approximately 2x — the test guards against counting the loop
    # body once (ratio 1.0) or quadratically (ratio 4.0).
    assert 1.4 < b8 / b4 < 3.5


def test_dot_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = module_cost(jax.jit(f).lower(a, b).compile().as_text())
    expected = 2 * 4 * 32 * 16 * 64
    assert expected <= c.flops <= 1.05 * expected + 1e4
