"""Unit tests for the task graph / dependency semantics (paper §2.1, §4.6)."""

import threading
import time

import pytest

from repro.core import TaskRuntime, TaskError


def test_in_out_dependency_order():
    order = []
    lock = threading.Lock()

    def log(tag):
        with lock:
            order.append(tag)

    with TaskRuntime(num_workers=4) as rt:
        rt.submit(log, "w1", out=["a"])
        rt.submit(log, "r1", in_=["a"])
        rt.submit(log, "r2", in_=["a"])
        rt.submit(log, "w2", out=["a"])
        rt.taskwait()
        assert order.index("w1") < order.index("r1")
        assert order.index("w1") < order.index("r2")
        assert order.index("r1") < order.index("w2")
        assert order.index("r2") < order.index("w2")


def test_independent_tasks_run_concurrently():
    barrier = threading.Barrier(3, timeout=5.0)

    def rendezvous():
        barrier.wait()

    with TaskRuntime(num_workers=4) as rt:
        for _ in range(3):
            rt.submit(rendezvous)
        rt.taskwait()  # would raise BrokenBarrierError via TaskError if serial


def test_inout_chain_serializes():
    values = []

    def bump():
        values.append(len(values))

    with TaskRuntime(num_workers=8) as rt:
        for _ in range(50):
            rt.submit(bump, inout=["counter"])
        rt.taskwait()
    assert values == list(range(50))


def test_results_and_errors():
    with TaskRuntime(num_workers=2) as rt:
        t = rt.submit(lambda a, b: a + b, 2, 3)
        rt.taskwait()
        assert t.result == 5

    rt = TaskRuntime(num_workers=2)
    rt.start()
    rt.submit(lambda: 1 / 0, name="boom")
    with pytest.raises(TaskError):
        rt.taskwait()
    rt.close()


def test_error_does_not_hang_dependents():
    ran = []
    rt = TaskRuntime(num_workers=2)
    rt.start()
    rt.submit(lambda: 1 / 0, out=["x"], name="boom")
    rt.submit(lambda: ran.append(1), in_=["x"])
    with pytest.raises(TaskError):
        rt.taskwait()
    rt.close()
    assert ran == [1]  # dependency released despite the failure


def test_critical_path():
    rt = TaskRuntime(num_workers=1)
    rt.start()
    rt.submit(lambda: None, out=["a"], cost=2.0)
    rt.submit(lambda: None, in_=["a"], out=["b"], cost=3.0)
    rt.submit(lambda: None, cost=10.0)  # independent
    rt.taskwait()
    assert rt.graph.critical_path() == 10.0
    rt.close()


def test_identity_keyed_regions():
    a, b = object(), object()
    order = []
    with TaskRuntime(num_workers=4) as rt:
        rt.submit(lambda: order.append("wa"), out=[a])
        rt.submit(lambda: order.append("wb"), out=[b])
        rt.submit(lambda: order.append("ra"), in_=[a])
        rt.taskwait()
    assert order.index("wa") < order.index("ra")
