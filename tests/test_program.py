"""Compiled-executor tests: plan cache, static wait plans, and
compiled-vs-interpreted parity (results AND per-rank tag consumption)
across algorithms × modes × world/split/cart communicators — including
the rejected-call path and mixed-executor ranks sharing one wire."""

import numpy as np
import pytest

from repro.core import (Collectives, HaloExchange, HierarchicalCollectives,
                        TaskRuntime, tac)
from repro.core import program as program_ir
from repro.core import schedule as schedule_ir
from repro.core.collectives import (CollectiveHandle, _drive_group,
                                    _Machine)
from repro.core.schedule import Recv

EXECUTORS = ("interpreted", "compiled")
COLLS = ("barrier", "bcast", "reduce", "allreduce", "allgather",
         "reduce_scatter", "alltoall")


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


def _same(a, b):
    """Structural equality over the collectives' result shapes."""
    if type(a) is not type(b) and not (
            isinstance(a, np.ndarray) and isinstance(b, np.ndarray)):
        return False
    if isinstance(a, np.ndarray):
        return a.shape == b.shape and np.array_equal(a, b)
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(map(_same, a, b))
    if isinstance(a, dict):
        return set(a) == set(b) and all(_same(a[k], b[k]) for k in a)
    return a == b


def _seq_state(coll):
    """Observable per-rank tag-sequence positions (count(k) reprs)."""
    return [repr(c) for c in coll._seq]


def _per_rank_kwargs(name, m, vals, root):
    per = []
    for r in range(m):
        if name == "barrier":
            per.append({})
        elif name == "bcast":
            per.append({"value": vals[r] if r == root else None,
                        "root": root})
        elif name == "reduce":
            per.append({"value": vals[r], "root": root})
        elif name == "alltoall":
            per.append({"blocks": [vals[r] + d for d in range(m)]})
        else:
            per.append({"value": vals[r]})
    return per


def _run_both(name, n, algorithm, make_comm, per_rank, **common):
    """The same collective on two fresh communicators, one per executor;
    returns {executor: (results, seq_state)}."""
    out = {}
    for ex in EXECUTORS:
        comm = make_comm()
        coll = Collectives(comm, executor=ex)
        res = coll.run_group(name, per_rank, algorithm=algorithm, **common)
        out[ex] = (res, _seq_state(coll))
    return out


# ---------------------------------------------------------------------------
# exhaustive small-matrix parity (no hypothesis needed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", COLLS)
@pytest.mark.parametrize("algorithm", [None, "ring", "doubling", "auto"])
@pytest.mark.parametrize("kind", ["world", "split", "cart"])
def test_parity_across_collectives_algorithms_comms(name, algorithm, kind):
    n = 6
    if kind == "world":
        m, make_comm = n, lambda: tac.CommWorld(n)
    elif kind == "split":
        m = 3

        def make_comm():
            w = tac.CommWorld(n)
            handles = [w.split(r // 3, key=r, rank=r) for r in range(n)]
            return handles[0].result      # color-0 group, ranks 0..2
    else:
        m = 4

        def make_comm():
            return tac.CommWorld(n).cart_create((2, 2), periodic=True)
    vals = [np.arange(4.0) * (r + 1) for r in range(m)]
    per = _per_rank_kwargs(name, m, vals, root=m - 1)
    out = _run_both(name, m, algorithm, make_comm, per)
    (res_i, seq_i), (res_c, seq_c) = out["interpreted"], out["compiled"]
    assert _same(res_i, res_c)
    assert seq_i == seq_c


@pytest.mark.parametrize("segments", [1, 3])
def test_parity_segmented_and_hierarchical_allreduce(segments):
    n = 8
    vals = [np.arange(16.0) + r for r in range(n)]
    per = [{"value": v} for v in vals]
    out = _run_both("allreduce", n, "ring", lambda: tac.CommWorld(n), per,
                    segments=segments)
    assert _same(out["interpreted"][0], out["compiled"][0])
    assert out["interpreted"][1] == out["compiled"][1]

    out = _run_both("allreduce", n, None, lambda: tac.CommWorld(n), per,
                    hierarchical=4)
    assert _same(out["interpreted"][0], out["compiled"][0])

    res = {}
    for ex in EXECUTORS:
        hier = HierarchicalCollectives(tac.CommWorld(n), 4, executor=ex)
        res[ex] = (hier.run_group(vals),
                   hier.run_group(vals, composed=True))
    assert _same(res["interpreted"], res["compiled"])


def test_parity_halo_and_persistent():
    outs = {}
    for ex in EXECUTORS:
        cart = tac.CommWorld(6).cart_create((2, 3), periodic=(True, False))
        halo = HaloExchange(cart, executor=ex)
        sends = [{d: (r, d) for d in dict(halo.neighbors(r))}
                 for r in range(6)]
        outs[ex] = [halo.run_group(sends) for _ in range(3)]
    assert _same(outs["interpreted"], outs["compiled"])

    outs = {}
    vals = [np.arange(5.0) + r for r in range(4)]
    for ex in EXECUTORS:
        coll = Collectives(tac.CommWorld(4), executor=ex)
        pers = coll.persistent("allreduce", algorithm="doubling", op="max")
        outs[ex] = [pers.run_group(vals) for _ in range(3)]
    assert _same(outs["interpreted"], outs["compiled"])


# ---------------------------------------------------------------------------
# interoperability modes inside a runtime (CI runs this file under both
# REPRO_NOTIFY backends)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["blocking", "event"])
def test_mode_parity_inside_tasks(mode):
    n = 4
    vals = [np.full(6, float(r + 1)) for r in range(n)]
    ref = np.sum(np.stack(vals), axis=0)
    for ex in EXECUTORS:
        coll = Collectives(tac.CommWorld(n), executor=ex)
        got = {}

        def comm(r):
            def body():
                got[r] = coll.allreduce(vals[r], rank=r, op="sum",
                                        mode=mode, key="m")
            return body

        with TaskRuntime(num_workers=2) as rt:
            for r in range(n):
                rt.submit(comm(r), out=[("res", r)])
            rt.taskwait()
        for r in range(n):
            res = got[r].result if mode == "event" else got[r]
            np.testing.assert_allclose(res, ref)


def test_mixed_executor_ranks_share_the_wire():
    """Compiled and interpreted ranks of ONE collective on the SAME
    communicator: byte-identical tags mean they match and agree."""
    n = 8
    w = tac.CommWorld(n)
    colls = {ex: Collectives(w, executor=ex) for ex in EXECUTORS}
    for name, mk in [
            ("allreduce", lambda r: {"value": np.arange(4.0) + r}),
            ("bcast", lambda r: {"value": "x" if r == 0 else None}),
            ("allgather", lambda r: {"value": r}),
            ("alltoall", lambda r: {"blocks": [(r, d) for d in range(n)]}),
    ]:
        machines = []
        for r in range(n):
            ex = "compiled" if r % 2 else "interpreted"
            gen = colls[ex]._make_gen(name, rank=r, key=("mix", name),
                                      **mk(r))
            machines.append(_Machine(gen, CollectiveHandle()))
        _drive_group(machines)
        results = [m.handle.result for m in machines]
        ref_coll = Collectives(tac.CommWorld(n), executor="interpreted")
        ref = ref_coll.run_group(name, [mk(r) for r in range(n)])
        assert _same(results, ref)


# ---------------------------------------------------------------------------
# the hypothesis property: random collectives, communicators, payloads and
# rejected-call prefixes — results and tag consumption always agree
# ---------------------------------------------------------------------------
def test_parity_property_randomized():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, HealthCheck
    import hypothesis.strategies as st

    @settings(deadline=None, max_examples=40,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def prop(data):
        n = data.draw(st.integers(2, 8), label="world size")
        kind = data.draw(st.sampled_from(["world", "split", "cart"]),
                         label="communicator kind")
        if kind == "world":
            m, make_comm = n, lambda: tac.CommWorld(n)
        elif kind == "split":
            k = data.draw(st.integers(1, n), label="split group size")
            m = min(k, n)

            def make_comm():
                w = tac.CommWorld(n)
                hs = [w.split(r // k, key=r, rank=r) for r in range(n)]
                return hs[0].result
        else:
            dims = data.draw(st.sampled_from(
                [(a, b) for a in (1, 2, 3) for b in (1, 2, 3)
                 if 2 <= a * b <= n]), label="cart dims")
            m = dims[0] * dims[1]

            def make_comm():
                return tac.CommWorld(n).cart_create(dims, periodic=True)
        name = data.draw(st.sampled_from(COLLS), label="collective")
        algorithm = data.draw(
            st.sampled_from([None, "ring", "doubling", "auto"]),
            label="algorithm")
        op = data.draw(st.sampled_from(["sum", "max", "min"]), label="op")
        length = data.draw(st.integers(1, 5), label="payload length")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        reject_first = data.draw(st.booleans(), label="rejected prefix")
        rng = np.random.default_rng(seed)
        vals = [rng.integers(-9, 9, size=length).astype(float)
                for _ in range(m)]
        root = data.draw(st.integers(0, m - 1), label="root")
        per = _per_rank_kwargs(name, m, vals, root)
        common = ({"op": op} if name in ("reduce", "allreduce",
                                         "reduce_scatter") else {})
        out = {}
        for ex in EXECUTORS:
            comm = make_comm()
            coll = Collectives(comm, executor=ex)
            if reject_first:
                # a rejected call on every rank must consume nothing
                for r in range(m):
                    with pytest.raises(ValueError):
                        coll.allreduce(vals[0], rank=r, mode="bogus")
            res = coll.run_group(name, per, algorithm=algorithm, **common)
            out[ex] = (res, _seq_state(coll))
        assert _same(out["interpreted"][0], out["compiled"][0])
        assert out["interpreted"][1] == out["compiled"][1]

    prop()


# ---------------------------------------------------------------------------
# rejected calls must not desynchronize tag sequences
# ---------------------------------------------------------------------------
def test_rejected_calls_never_consume_tag_sequence():
    n = 4
    vals = [np.arange(3.0) + r for r in range(n)]
    states = {}
    for ex in EXECUTORS:
        coll = Collectives(tac.CommWorld(n), executor=ex)
        coll.run_group("allreduce", [{"value": v} for v in vals])
        bad_calls = [
            lambda: coll.allreduce(vals[0], rank=0, mode="bogus"),
            lambda: coll.allreduce(vals[1], rank=1, algorithm="bogus"),
            lambda: coll.allreduce(vals[2], rank=2, op="bogus"),
            lambda: coll.allreduce(vals[0], rank=99),
            lambda: coll.run_group("allreduce",
                                   [{"value": v} for v in vals],
                                   segments=2, algorithm="doubling"),
            lambda: coll.run_group("allreduce",
                                   [{"value": v} for v in vals],
                                   hierarchical=3),
            lambda: coll.alltoall([1, 2], rank=0),
            lambda: coll.run_group("nope", [{}] * n),
        ]
        for bad in bad_calls:
            with pytest.raises(ValueError):
                bad()
        # every rank still in lockstep: the next keyless collective works
        res = coll.run_group("allreduce", [{"value": v} for v in vals])
        states[ex] = (_seq_state(coll), res)
    assert states["interpreted"][0] == states["compiled"][0]
    assert _same(states["interpreted"][1], states["compiled"][1])


def test_late_binding_errors_match_interpreter():
    """Binding failures surface on first advance (generator semantics) in
    both executors, not at gen-construction time."""
    for ex in EXECUTORS:
        coll = Collectives(tac.CommWorld(4), executor=ex)
        # too-few blocks reach binding only on first advance
        bad = coll._schedule("alltoall", None, 1, "k2", blocks=[1])
        with pytest.raises(IndexError):
            next(bad)


# ---------------------------------------------------------------------------
# the plan cache + static wait plans
# ---------------------------------------------------------------------------
def test_plan_cache_reuses_programs():
    program_ir.clear_cache()
    w = tac.CommWorld(4)
    coll = Collectives(w, executor="compiled")
    vals = [np.arange(3.0) + r for r in range(4)]
    coll.run_group("allreduce", [{"value": v} for v in vals])
    after_first = program_ir.cache_stats()
    assert after_first["misses"] >= 1
    for _ in range(5):
        coll.run_group("allreduce", [{"value": v} for v in vals])
    after = program_ir.cache_stats()
    assert after["misses"] == after_first["misses"]   # no recompiles
    assert after["hits"] > after_first["hits"]
    assert after["size"] == after_first["size"]

    # distinct op => distinct plan; same op string => shared entry
    coll.run_group("allreduce", [{"value": v} for v in vals], op="max")
    assert program_ir.cache_stats()["misses"] == after["misses"] + 1
    coll.run_group("allreduce", [{"value": v} for v in vals], op="max")
    assert program_ir.cache_stats()["misses"] == after["misses"] + 1


def test_plan_cache_eviction_bound(monkeypatch):
    program_ir.clear_cache()
    monkeypatch.setattr(program_ir, "CACHE_MAX", 2)
    w = tac.CommWorld(2)
    sched = schedule_ir.build("allreduce", "ring", 2)
    for i in range(5):
        program_ir.compile_schedule(sched, w, op=np.add, head=("t", i))
    stats = program_ir.cache_stats()
    assert stats["size"] <= 2
    assert stats["evictions"] == 3
    program_ir.clear_cache()
    assert program_ir.cache_stats()["size"] == 0


def test_compile_rejects_size_mismatch_and_missing_op():
    w = tac.CommWorld(4)
    sched = schedule_ir.build("allreduce", "ring", 3)
    with pytest.raises(ValueError, match="size"):
        program_ir.CompiledProgram(sched, w, op=np.add, head=("x",))
    prog = program_ir.CompiledProgram(
        schedule_ir.build("allreduce", "ring", 4), w, op=None, head=("x",))
    with pytest.raises(ValueError, match="no op"):
        prog.gen(0, 0, value=np.arange(3.0))
    with pytest.raises(ValueError, match="out of range"):
        prog.gen(7, 0, value=np.arange(3.0))


@pytest.mark.parametrize("name,algorithm", [
    ("allreduce", "ring"), ("allreduce", "doubling"),
    ("alltoall", "doubling"), ("allgather", "doubling"),
    ("reduce", "ring"), ("bcast", "doubling"), ("barrier", "doubling")])
def test_wait_plan_matches_dynamic_interpretation(name, algorithm):
    """The static wait plan equals what the interpreter's pending-dict
    probing computes dynamically, for every rank."""
    sched = schedule_ir.build(name, algorithm, 6)
    for rank in range(sched.n):
        steps, tail = sched.wait_plan(rank)
        assert len(steps) == len(sched.programs[rank])
        pending = {}
        for (op, waits), op2 in zip(steps, sched.programs[rank]):
            assert op is op2
            expect = tuple(b for b in op.reads if b in pending)
            assert waits == expect
            for b in waits:
                del pending[b]
            if isinstance(op, Recv):
                pending[op.buf] = None
        assert tail == tuple(pending)
        # every posted recv is consumed exactly once (waits ∪ tail)
        recvs = [op.buf for op in sched.programs[rank]
                 if isinstance(op, Recv)]
        consumed = [b for _, ws in steps for b in ws] + list(tail)
        assert sorted(map(repr, recvs)) == sorted(map(repr, consumed))


# ---------------------------------------------------------------------------
# persistent-plan buffer arenas: combine outputs write into pre-allocated
# per-rank buffers (ufunc out=), reused across postings of the same plan
# ---------------------------------------------------------------------------
def test_persistent_arena_reuses_combine_buffers():
    vals = [np.arange(16.0) + r for r in range(4)]
    want = np.sum(vals, axis=0)
    coll = Collectives(tac.CommWorld(4), executor="compiled")
    pers = coll.persistent("allreduce", algorithm="ring")
    for r in pers.run_group(vals):
        np.testing.assert_array_equal(r, want)
    assert any(pers._arenas), \
        "compiled ring allreduce should populate combine arenas"
    snap = [{k: id(v) for k, v in a.items()} for a in pers._arenas]
    for _ in range(10):
        for r in pers.run_group(vals):
            np.testing.assert_array_equal(r, want)
    # steady state: the very same buffer objects, and no growth (a new
    # entry or a reallocated id per iteration would be the leak).
    assert [{k: id(v) for k, v in a.items()} for a in pers._arenas] == snap


def test_persistent_arena_results_do_not_alias_buffers():
    n = 4
    vals1 = [np.full(12, float(r + 1)) for r in range(n)]
    vals2 = [np.full(12, float(10 * (r + 1))) for r in range(n)]
    for name in ("allreduce", "reduce"):
        coll = Collectives(tac.CommWorld(n), executor="compiled")
        pers = coll.persistent(name)
        out1 = pers.run_group(vals1)
        pers.run_group(vals2)
        # iteration 2 rewrote the arena buffers in place; iteration-1
        # results must be unaffected and share no memory with them.
        want1 = np.sum(vals1, axis=0)
        for res in out1:
            if res is None:        # reduce non-root
                continue
            np.testing.assert_array_equal(res, want1)
            for a in pers._arenas:
                for buf in a.values():
                    assert not np.shares_memory(res, buf)


def test_persistent_arena_survives_dtype_switch():
    coll = Collectives(tac.CommWorld(4), executor="compiled")
    pers = coll.persistent("allreduce", algorithm="ring")
    ints = [np.arange(8) + r for r in range(4)]
    flts = [np.arange(8.0) + r for r in range(4)]
    for vals in (ints, flts, ints):
        for r in pers.run_group(vals):
            np.testing.assert_array_equal(r, np.sum(vals, axis=0))
