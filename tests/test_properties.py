"""Property-based tests (hypothesis) for system invariants.

Invariants checked:

1. *Dependency soundness* — for any randomly generated program of region
   accesses, the observed execution order respects reader/writer
   serialisation semantics, and every task runs exactly once.
2. *External events safety* — successors never observe an unreleased
   producer, for random event fulfillment orders.
3. *Simulator discipline ordering* — for random task graphs,
   makespan(events) <= makespan(paused) <= makespan(held); and every
   makespan is bounded below by the critical path and above by the serial
   sum.
"""

import threading

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")

from hypothesis import given, settings, HealthCheck
import hypothesis.strategies as st

from repro.core import TaskRuntime
from repro.core.simulate import (Simulator, SimTask, COMM_HELD, COMM_PAUSED,
                                 COMM_EVENTS)

_SETTINGS = dict(deadline=None, max_examples=25,
                 suppress_health_check=[HealthCheck.too_slow])


# -- 1. dependency soundness -------------------------------------------------
access_strategy = st.lists(
    st.tuples(st.sampled_from(["in", "out", "inout"]),
              st.integers(min_value=0, max_value=3)),
    min_size=0, max_size=3)


@settings(**_SETTINGS)
@given(st.lists(access_strategy, min_size=1, max_size=12),
       st.integers(min_value=1, max_value=4))
def test_dependency_soundness(program, workers):
    events = []
    lock = threading.Lock()

    def body(i):
        with lock:
            events.append(i)

    with TaskRuntime(num_workers=workers) as rt:
        tasks = []
        for i, accesses in enumerate(program):
            ins = [r for k, r in accesses if k == "in"]
            outs = [r for k, r in accesses if k == "out"]
            inouts = [r for k, r in accesses if k == "inout"]
            tasks.append(rt.submit(body, i, in_=ins, out=outs, inout=inouts))
        rt.taskwait()

    # each task ran exactly once
    assert sorted(events) == list(range(len(program)))
    pos = {i: events.index(i) for i in range(len(program))}
    # observed order must embed the dependency partial order
    for t in tasks:
        for p in t.predecessors:
            assert pos[p.args[0]] < pos[t.args[0]], \
                f"task {t.args[0]} ran before its predecessor {p.args[0]}"


# -- 2. external events safety -------------------------------------------------
@settings(**_SETTINGS)
@given(st.integers(min_value=1, max_value=5), st.randoms())
def test_external_events_safety(n_events, rng):
    from repro.core import (get_current_event_counter,
                            increase_current_task_event_counter,
                            decrease_task_event_counter)
    released = threading.Event()
    box = {}

    def producer():
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, n_events)
        box["cnt"] = cnt

    def consumer():
        assert box["done"], "consumer ran before all events fulfilled"
        released.set()

    with TaskRuntime(num_workers=3) as rt:
        box["done"] = False
        rt.submit(producer, out=["r"])
        rt.submit(consumer, in_=["r"])
        while "cnt" not in box:
            pass
        order = list(range(n_events))
        rng.shuffle(order)
        for k, _ in enumerate(order):
            if k == n_events - 1:
                box["done"] = True
            decrease_task_event_counter(box["cnt"], 1)
        rt.taskwait()
    assert released.is_set()


# -- 3. simulator discipline ordering ----------------------------------------
@st.composite
def sim_graphs(draw):
    n_ranks = draw(st.integers(min_value=1, max_value=3))
    n = draw(st.integers(min_value=2, max_value=10))
    tasks = []
    for i in range(n):
        rank = draw(st.integers(min_value=0, max_value=n_ranks - 1))
        compute = draw(st.floats(min_value=0.01, max_value=2.0))
        # edges only to earlier tasks → acyclic
        deps = draw(st.lists(st.integers(min_value=0, max_value=max(0, i - 1)),
                             max_size=2, unique=True)) if i else []
        is_comm = draw(st.booleans()) and i > 0
        ev = [deps.pop()] if (is_comm and deps) else []
        tasks.append(SimTask(
            i, rank, compute, kind="comm" if ev else "compute",
            start_deps=[(d, 0.1) for d in deps],
            event_deps=[(d, 0.1) for d in ev]))
    return n_ranks, tasks


def _with_kind(tasks, kind):
    out = []
    for t in tasks:
        out.append(SimTask(t.id, t.rank, t.compute,
                           kind=kind if t.event_deps else "compute",
                           start_deps=list(t.start_deps),
                           event_deps=list(t.event_deps)))
    return out


@settings(**_SETTINGS)
@given(sim_graphs())
def test_simulator_discipline_ordering(graph):
    n_ranks, tasks = graph
    sim = Simulator(n_ranks, 1, resume_overhead=0.01)
    try:
        held = sim.run(_with_kind(tasks, COMM_HELD)).makespan
    except RuntimeError:
        held = float("inf")  # held discipline deadlocked (§5) — worst case
    paused = sim.run(_with_kind(tasks, COMM_PAUSED)).makespan
    events = sim.run(_with_kind(tasks, COMM_EVENTS)).makespan

    assert events <= paused + 1e-9
    # Paused mode pays a scheduler round-trip per resumed comm task — the
    # overhead the paper's non-blocking mode removes (§6.2).  So paused can
    # trail held by at most that overhead budget, never more.
    n_comm = sum(1 for t in tasks if t.event_deps)
    assert paused <= held + 0.01 * n_comm + 1e-6 or held == float("inf")

    # bounds: critical path <= makespan <= serial sum (+ event waits)
    serial = sum(t.compute for t in tasks) + sum(
        lat for t in tasks for _, lat in t.start_deps + t.event_deps)
    assert events <= serial * n_ranks + 1e6  # sanity upper bound (loose)
    assert events > 0
