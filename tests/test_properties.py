"""Property-based tests (hypothesis) for system invariants.

Invariants checked:

1. *Dependency soundness* — for any randomly generated program of region
   accesses, the observed execution order respects reader/writer
   serialisation semantics, and every task runs exactly once.
2. *External events safety* — successors never observe an unreleased
   producer, for random event fulfillment orders.
3. *Simulator discipline ordering* — for random task graphs,
   makespan(events) <= makespan(paused) <= makespan(held); and every
   makespan is bounded below by the critical path and above by the serial
   sum.
4. *Collectives correctness* — for random rank counts, payload shapes and
   per-rank interoperability-mode mixes, every collective agrees with the
   numpy reference on every rank (ROADMAP open item).
5. *Sub-group isolation* — collectives on a random disjoint partition of
   one world, all using the SAME key, never cross tag spaces.
6. *Cartesian reciprocity* — for random grids, neighbour lists are
   mutually consistent and a halo round delivers exactly each
   neighbour's opposite-direction payload.
"""

import threading

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")

from hypothesis import given, settings, HealthCheck
import hypothesis.strategies as st

from repro.core import (Collectives, HaloExchange, HierarchicalCollectives,
                        TaskRuntime, tac)
from repro.core.collectives import CollectiveHandle
from repro.core.simulate import (Simulator, SimTask, COMM_HELD, COMM_PAUSED,
                                 COMM_EVENTS)

_SETTINGS = dict(deadline=None, max_examples=25,
                 suppress_health_check=[HealthCheck.too_slow])


# -- 1. dependency soundness -------------------------------------------------
access_strategy = st.lists(
    st.tuples(st.sampled_from(["in", "out", "inout"]),
              st.integers(min_value=0, max_value=3)),
    min_size=0, max_size=3)


@settings(**_SETTINGS)
@given(st.lists(access_strategy, min_size=1, max_size=12),
       st.integers(min_value=1, max_value=4))
def test_dependency_soundness(program, workers):
    events = []
    lock = threading.Lock()

    def body(i):
        with lock:
            events.append(i)

    with TaskRuntime(num_workers=workers) as rt:
        tasks = []
        for i, accesses in enumerate(program):
            ins = [r for k, r in accesses if k == "in"]
            outs = [r for k, r in accesses if k == "out"]
            inouts = [r for k, r in accesses if k == "inout"]
            tasks.append(rt.submit(body, i, in_=ins, out=outs, inout=inouts))
        rt.taskwait()

    # each task ran exactly once
    assert sorted(events) == list(range(len(program)))
    pos = {i: events.index(i) for i in range(len(program))}
    # observed order must embed the dependency partial order
    for t in tasks:
        for p in t.predecessors:
            assert pos[p.args[0]] < pos[t.args[0]], \
                f"task {t.args[0]} ran before its predecessor {p.args[0]}"


# -- 2. external events safety -------------------------------------------------
@settings(**_SETTINGS)
@given(st.integers(min_value=1, max_value=5), st.randoms())
def test_external_events_safety(n_events, rng):
    from repro.core import (get_current_event_counter,
                            increase_current_task_event_counter,
                            decrease_task_event_counter)
    released = threading.Event()
    box = {}

    def producer():
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, n_events)
        box["cnt"] = cnt

    def consumer():
        assert box["done"], "consumer ran before all events fulfilled"
        released.set()

    with TaskRuntime(num_workers=3) as rt:
        box["done"] = False
        rt.submit(producer, out=["r"])
        rt.submit(consumer, in_=["r"])
        while "cnt" not in box:
            pass
        order = list(range(n_events))
        rng.shuffle(order)
        for k, _ in enumerate(order):
            if k == n_events - 1:
                box["done"] = True
            decrease_task_event_counter(box["cnt"], 1)
        rt.taskwait()
    assert released.is_set()


# -- 3. simulator discipline ordering ----------------------------------------
@st.composite
def sim_graphs(draw):
    n_ranks = draw(st.integers(min_value=1, max_value=3))
    n = draw(st.integers(min_value=2, max_value=10))
    tasks = []
    for i in range(n):
        rank = draw(st.integers(min_value=0, max_value=n_ranks - 1))
        compute = draw(st.floats(min_value=0.01, max_value=2.0))
        # edges only to earlier tasks → acyclic
        deps = draw(st.lists(st.integers(min_value=0, max_value=max(0, i - 1)),
                             max_size=2, unique=True)) if i else []
        is_comm = draw(st.booleans()) and i > 0
        ev = [deps.pop()] if (is_comm and deps) else []
        tasks.append(SimTask(
            i, rank, compute, kind="comm" if ev else "compute",
            start_deps=[(d, 0.1) for d in deps],
            event_deps=[(d, 0.1) for d in ev]))
    return n_ranks, tasks


def _with_kind(tasks, kind):
    out = []
    for t in tasks:
        out.append(SimTask(t.id, t.rank, t.compute,
                           kind=kind if t.event_deps else "compute",
                           start_deps=list(t.start_deps),
                           event_deps=list(t.event_deps)))
    return out


@settings(**_SETTINGS)
@given(sim_graphs())
def test_simulator_discipline_ordering(graph):
    n_ranks, tasks = graph
    sim = Simulator(n_ranks, 1, resume_overhead=0.01)
    try:
        held = sim.run(_with_kind(tasks, COMM_HELD)).makespan
    except RuntimeError:
        held = float("inf")  # held discipline deadlocked (§5) — worst case
    paused = sim.run(_with_kind(tasks, COMM_PAUSED)).makespan
    events = sim.run(_with_kind(tasks, COMM_EVENTS)).makespan

    assert events <= paused + 1e-9
    # Paused mode pays a scheduler round-trip per resumed comm task — the
    # overhead the paper's non-blocking mode removes (§6.2).  So paused can
    # trail held by at most that overhead budget, never more.
    n_comm = sum(1 for t in tasks if t.event_deps)
    assert paused <= held + 0.01 * n_comm + 1e-6 or held == float("inf")

    # bounds: critical path <= makespan <= serial sum (+ event waits)
    serial = sum(t.compute for t in tasks) + sum(
        lat for t in tasks for _, lat in t.start_deps + t.event_deps)
    assert events <= serial * n_ranks + 1e6  # sanity upper bound (loose)
    assert events > 0


# -- 4. collectives correctness ----------------------------------------------
# Plain helpers carry the check logic so non-hypothesis smoke tests (and
# debugging sessions) can drive the same invariants with fixed inputs.
def _resolve(v):
    return v.result if isinstance(v, CollectiveHandle) else v


def _check_allreduce(n, shape, alg, modes, workers):
    """Per-rank mode mixes on the task runtime must match numpy."""
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(n)
    coll = Collectives(world)
    vals = [(np.arange(int(np.prod(shape)), dtype=np.float64) * (r + 1)
             + r).reshape(shape) for r in range(n)]
    ref = np.sum(np.stack(vals), axis=0)
    out = {}

    def make(r):
        def body():
            out[r] = coll.allreduce(vals[r], rank=r, op="sum",
                                    algorithm=alg, mode=modes[r % len(modes)],
                                    key="prop")
        return body

    with TaskRuntime(num_workers=workers) as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    for r in range(n):
        np.testing.assert_allclose(_resolve(out[r]), ref,
                                   rtol=1e-12, atol=1e-12)


@settings(**_SETTINGS)
@given(st.integers(min_value=1, max_value=6),
       st.sampled_from([(1,), (7,), (13,), (3, 4), (2, 3, 2)]),
       st.sampled_from(["ring", "doubling"]),
       st.lists(st.sampled_from(["blocking", "event"]),
                min_size=1, max_size=6),
       st.integers(min_value=1, max_value=4))
def test_allreduce_mode_mixes(n, shape, alg, modes, workers):
    _check_allreduce(n, shape, alg, modes, workers)


def _check_gather_scatter(n, size, alg):
    """allgather/reduce_scatter on the sequential driver match numpy for
    any payload size, including size % n != 0."""
    world = tac.CommWorld(n)
    coll = Collectives(world)
    vals = [np.arange(size, dtype=np.float64) + 100 * r for r in range(n)]
    gathered = coll.run_group("allgather", [{"value": v} for v in vals],
                              algorithm=alg)
    for r in range(n):
        for i in range(n):
            np.testing.assert_array_equal(gathered[r][i], vals[i])
    chunks = coll.run_group("reduce_scatter", [{"value": v} for v in vals],
                            op="sum", algorithm=alg)
    ref = np.array_split(np.sum(np.stack(vals), axis=0), n)
    for r in range(n):
        np.testing.assert_allclose(chunks[r], ref[r], rtol=1e-12)


@settings(**_SETTINGS)
@given(st.integers(min_value=1, max_value=7),
       st.integers(min_value=1, max_value=40),
       st.sampled_from(["ring", "doubling"]))
def test_gather_scatter_shapes(n, size, alg):
    _check_gather_scatter(n, size, alg)


# -- 5. sub-group isolation ---------------------------------------------------
def _check_partition_isolation(sizes, workers):
    """Disjoint groups of a shared world run event-bound allreduces with
    the same key concurrently; each group's sum must be its own."""
    tac.init(tac.TASK_MULTIPLE)
    n = sum(sizes)
    world = tac.CommWorld(n)
    base = 0
    groups = []
    for s in sizes:
        groups.append(world.group(list(range(base, base + s))))
        base += s
    colls = [Collectives(g) for g in groups]
    out = {}

    def make(gi, gr):
        def body():
            wr = groups[gi].world_rank(gr)
            out[wr] = colls[gi].allreduce(np.float64(wr), rank=gr,
                                          op="sum", mode="event", key="k")
        return body

    with TaskRuntime(num_workers=workers) as rt:
        for gi, g in enumerate(groups):
            for gr in range(g.size):
                rt.submit(make(gi, gr))
        rt.taskwait()
    for g in groups:
        expect = float(sum(g.ranks))
        for gr in range(g.size):
            got = float(_resolve(out[g.world_rank(gr)]))
            assert got == expect, (g.ranks, gr, got, expect)


@settings(**_SETTINGS)
@given(st.lists(st.integers(min_value=1, max_value=4),
                min_size=1, max_size=3),
       st.integers(min_value=2, max_value=4))
def test_partition_isolation(sizes, workers):
    _check_partition_isolation(sizes, workers)


def _check_hierarchical(n, group_size):
    world = tac.CommWorld(n)
    hier = HierarchicalCollectives(world, group_size)
    vals = [np.float64(3 * r + 1) for r in range(n)]
    out = hier.run_group(vals, op="sum")
    assert all(float(v) == float(sum(vals)) for v in out), out


@settings(**_SETTINGS)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=9))
def test_hierarchical_any_grouping(n, group_size):
    _check_hierarchical(n, group_size)


# -- 6. cartesian reciprocity -------------------------------------------------
def _check_cart_halo(dims, periodic):
    n = int(np.prod(dims))
    world = tac.CommWorld(n)
    cart = world.cart_create(dims, periodic=periodic)
    # reciprocity: my neighbour in direction d has me in some direction
    # whose step leads back (wrap-aware), and a halo round delivers each
    # neighbour's opposite-direction payload
    hx = HaloExchange(cart)
    sends = [{d: ("edge", r, d) for d, _ in hx.neighbors(r)}
             for r in range(n)]
    got = hx.run_group(sends)
    for r in range(n):
        for d, nbr in cart.neighbor_dirs(r):
            opposite = (d[0], -d[1])
            assert (opposite, r) in cart.neighbor_dirs(nbr)
            assert got[r][d] == ("edge", nbr, opposite)


@settings(**_SETTINGS)
@given(st.sampled_from([(2,), (3,), (2, 2), (3, 2), (2, 3), (4, 2),
                        (2, 2, 2), (3, 1)]),
       st.booleans())
def test_cart_halo_reciprocity(dims, periodic):
    _check_cart_halo(dims, periodic)
