"""Sub-communicators and neighbourhood collectives (tac.CommGroup /
CartGroup, collectives.HaloExchange / HierarchicalCollectives /
neighbor_alltoall): rank translation, split semantics, tag-space
isolation of concurrent collectives on disjoint groups, Cartesian
topology, halo exchange in both interoperability modes, hierarchical
allreduce, and simulator neighbourhood nodes."""

import threading

import numpy as np
import pytest

from repro.core import (Collectives, HaloExchange, HierarchicalCollectives,
                        TaskRuntime, tac)
from repro.core.collectives import CollectiveHandle, n_rounds
from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_HELD,
                                 COMM_PAUSED, COMM_EVENTS)


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


# ---------------------------------------------------------------------------
# CommGroup: construction, rank translation, p2p namespacing
# ---------------------------------------------------------------------------
def test_group_rank_translation():
    w = tac.CommWorld(6)
    g = w.group([4, 1, 5])
    assert g.size == 3 and g.ranks == (4, 1, 5)
    assert [g.world_rank(i) for i in range(3)] == [4, 1, 5]
    assert g.group_rank(5) == 2 and g.group_rank(0) is None
    h = w.group([5, 0])
    assert g.translate(2, h) == 0       # world rank 5 is h's rank 0
    assert g.translate(0, h) is None    # world rank 4 not in h


def test_group_construction_validation():
    w = tac.CommWorld(4)
    with pytest.raises(ValueError, match="duplicate"):
        w.group([0, 1, 1])
    with pytest.raises(ValueError, match="out of range"):
        w.group([0, 4])
    with pytest.raises(ValueError, match="at least one"):
        w.group([])
    g = w.group([2, 3])
    with pytest.raises(ValueError, match="group rank"):
        g.isend("x", src=0, dst=2)
    with pytest.raises(ValueError, match="group rank"):
        g.world_rank(-1)


def test_group_p2p_is_isolated_from_world():
    """The same (src, dst, tag) on the world and on a group are distinct
    channels — the group's context id namespaces its traffic."""
    w = tac.CommWorld(3)
    g = w.group([2, 0])   # group rank 0 = world rank 2, 1 = world 0
    w.isend("world", src=2, dst=0, tag=7)
    g.isend("group", src=0, dst=1, tag=7)   # same world ranks, same tag
    assert g.irecv(src=0, dst=1, tag=7).result == "group"
    assert w.irecv(src=2, dst=0, tag=7).result == "world"


def test_two_groups_same_ranks_are_isolated():
    w = tac.CommWorld(2)
    g1, g2 = w.group([0, 1]), w.group([0, 1])
    g1.isend("one", src=0, dst=1)
    g2.isend("two", src=0, dst=1)
    assert g2.irecv(src=0, dst=1).result == "two"
    assert g1.irecv(src=0, dst=1).result == "one"


# ---------------------------------------------------------------------------
# CommWorld.split
# ---------------------------------------------------------------------------
def test_split_orders_by_key_then_world_rank():
    w = tac.CommWorld(5)
    # even ranks keyed descending, odd ranks all key 0 (tie -> world rank)
    handles = [w.split(r % 2, key=-r if r % 2 == 0 else 0, rank=r)
               for r in range(5)]
    groups = [h.result for h in handles]
    assert groups[0].ranks == (4, 2, 0)
    assert groups[1].ranks == (1, 3)
    assert groups[0] is groups[2] is groups[4]   # one object per color


def test_split_undefined_color_and_completion():
    w = tac.CommWorld(3)
    h0 = w.split("a", rank=0)
    h1 = w.split(None, rank=1)
    assert not h0.test()                 # collective: waits for rank 2
    h2 = w.split("a", rank=2)
    assert h0.test() and h1.test() and h2.test()
    assert h1.result is None             # MPI_UNDEFINED
    assert h0.result.ranks == (0, 2) and h0.result is h2.result


def test_split_generations_are_independent():
    """A rank's n-th split call joins the n-th split, MPI's same-order
    rule — interleaved calls from different ranks must not cross."""
    w = tac.CommWorld(2)
    a0 = w.split("first", rank=0)
    b0 = w.split("second", rank=0)       # rank 0 is already one split ahead
    a1 = w.split("first", rank=1)
    assert a0.result.ranks == (0, 1) and not b0.test()
    b1 = w.split("second", rank=1)
    assert b0.result.ranks == (0, 1) and b1.result is b0.result


def test_split_is_task_aware():
    """tac.wait on a split handle pauses the task until peers arrive."""
    w = tac.CommWorld(3)
    out = {}

    def make(r):
        def body():
            out[r] = tac.wait(w.split(0, rank=r))
        return body

    with TaskRuntime(num_workers=2) as rt:   # fewer workers than ranks
        for r in range(3):
            rt.submit(make(r))
        rt.taskwait()
    assert all(out[r].ranks == (0, 1, 2) for r in range(3))


# ---------------------------------------------------------------------------
# concurrent collectives on disjoint sub-groups (acceptance)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", (3, 5, 7))
def test_disjoint_group_collectives_share_one_world(n):
    """Disjoint sub-groups run allreduces CONCURRENTLY over one world,
    with identical keys — only the group context ids keep their tag
    spaces apart.  World sizes include non-powers-of-two."""
    w = tac.CommWorld(n)
    lo, hi = w.group(list(range(n // 2))), w.group(list(range(n // 2, n)))
    colls = {id(lo): Collectives(lo), id(hi): Collectives(hi)}
    results = {}

    def body(g, gr, wr):
        results[wr] = colls[id(g)].allreduce(
            np.float64(wr), rank=gr, op="sum", mode="blocking", key="same")

    threads = [threading.Thread(target=body, args=(g, gr, g.world_rank(gr)))
               for g in (lo, hi) for gr in range(g.size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    lo_sum = sum(range(n // 2))
    hi_sum = sum(range(n // 2, n))
    for wr in range(n):
        expect = lo_sum if wr < n // 2 else hi_sum
        assert float(results[wr]) == expect, (wr, results[wr])


@pytest.mark.parametrize("n", (3, 5, 7))
def test_group_and_world_collectives_coexist(n):
    """An event-bound allreduce on a sub-group overlaps a blocking one on
    the parent world inside one runtime, same key on both."""
    w = tac.CommWorld(n)
    sub = w.group(list(range(n - 1)))
    wc, sc = Collectives(w), Collectives(sub)
    world_out, sub_out = {}, {}

    def world_task(r):
        def body():
            world_out[r] = wc.allreduce(np.float64(1), rank=r, op="sum",
                                        mode="blocking", key="k")
        return body

    def sub_task(r):
        def body():
            sub_out[r] = sc.allreduce(np.float64(10), rank=r, op="sum",
                                      mode="event", key="k")
        return body

    with TaskRuntime(num_workers=3) as rt:
        for r in range(n):
            rt.submit(world_task(r))
        for r in range(n - 1):
            rt.submit(sub_task(r))
        rt.taskwait()
    assert all(float(v) == n for v in world_out.values())
    assert all(float(h.result) == 10 * (n - 1) for h in sub_out.values())


def test_collectives_over_group_all_ops():
    """The seven collectives run unchanged over a sub-group."""
    w = tac.CommWorld(6)
    g = w.group([5, 1, 3])
    coll = Collectives(g)
    out = coll.run_group("allgather", [{"value": r} for r in range(3)])
    assert out[0] == [0, 1, 2]
    red = coll.run_group("reduce", [{"value": np.float64(r + 1)}
                                    for r in range(3)], op="sum")
    assert float(red[0]) == 6.0 and red[1] is None


# ---------------------------------------------------------------------------
# Cartesian topology
# ---------------------------------------------------------------------------
def test_cart_coords_roundtrip_and_shift():
    w = tac.CommWorld(6)
    cart = w.cart_create((2, 3))
    assert [cart.coords(r) for r in range(6)] == [
        (0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]
    for r in range(6):
        assert cart.rank_at(cart.coords(r)) == r
    assert cart.shift(0, 0, 1) == (None, 3)    # off the top, down to 3
    assert cart.shift(4, 1, 1) == (3, 5)
    assert cart.rank_at((0, 3)) is None        # non-periodic: off grid


def test_cart_periodic_wraps():
    w = tac.CommWorld(6)
    cart = w.cart_create((2, 3), periodic=(False, True))
    assert cart.shift(0, 1, -1) == (1, 2)      # wraps in x
    assert cart.shift(0, 0, -1) == (3, None)   # no wrap in y
    assert cart.rank_at((0, -1)) == 2


def test_cart_neighbor_dirs_deterministic_order():
    w = tac.CommWorld(4)
    cart = w.cart_create((2, 2))
    assert cart.neighbor_dirs(0) == [((0, 1), 2), ((1, 1), 1)]
    assert cart.neighbor_dirs(3) == [((0, -1), 1), ((1, -1), 2)]
    assert cart.neighbors(0) == [2, 1]


def test_cart_validation():
    w = tac.CommWorld(4)
    with pytest.raises(ValueError, match="needs"):
        w.cart_create((3, 2))
    with pytest.raises(ValueError, match="dims"):
        w.cart_create(())
    with pytest.raises(ValueError, match="periodic"):
        w.cart_create((2, 2), periodic=(True,))
    cart = w.cart_create((2, 2))
    with pytest.raises(ValueError, match="dim"):
        cart.shift(0, 2)
    with pytest.raises(ValueError, match="coordinates"):
        cart.rank_at((0,))


# ---------------------------------------------------------------------------
# neighbourhood collectives
# ---------------------------------------------------------------------------
def test_neighbor_alltoall_needs_topology():
    w = tac.CommWorld(4)
    coll = Collectives(w)
    with pytest.raises(TypeError, match="Cartesian"):
        coll.neighbor_alltoall({}, rank=0)


def test_neighbor_alltoall_payload_validation():
    w = tac.CommWorld(4)
    coll = Collectives(w.cart_create((2, 2)))
    with pytest.raises(ValueError, match="directions"):
        coll.neighbor_alltoall({(0, 1): "x"}, rank=0)   # (1,1) missing


def test_neighbor_alltoall_matches_neighbour_structure():
    """Every rank receives from direction d exactly what the neighbour in
    direction d sent towards it (direction -d on their side)."""
    n, dims = 6, (2, 3)
    w = tac.CommWorld(n)
    cart = w.cart_create(dims, periodic=True)
    coll = Collectives(cart)
    results = {}

    def body(r):
        sends = {d: ("from", r, d) for d, _ in cart.neighbor_dirs(r)}
        results[r] = coll.neighbor_alltoall(sends, rank=r,
                                            mode="blocking", key="na")

    threads = [threading.Thread(target=body, args=(r,)) for r in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    for r in range(n):
        for d, nbr in cart.neighbor_dirs(r):
            assert results[r][d] == ("from", nbr, (d[0], -d[1]))


def test_halo_exchange_group_driver_2d():
    w = tac.CommWorld(4)
    cart = w.cart_create((2, 2))
    hx = HaloExchange(cart)
    sends = [{d: np.full(3, 10 * r + d[0]) for d, _ in hx.neighbors(r)}
             for r in range(4)]
    got = hx.run_group(sends)
    # rank 0 receives from below-right neighbours: from 2 in dir (0,1)
    # (2 sent dir (0,-1), dim 0) and from 1 in dir (1,1) (dim 1)
    np.testing.assert_array_equal(got[0][(0, 1)], np.full(3, 20))
    np.testing.assert_array_equal(got[0][(1, 1)], np.full(3, 11))
    np.testing.assert_array_equal(got[3][(0, -1)], np.full(3, 10))


def test_halo_exchange_iterations_do_not_cross():
    """Implicit per-rank sequence numbers isolate successive rounds even
    when one rank runs ahead (posts round 2 before peers post round 1)."""
    w = tac.CommWorld(2)
    cart = w.cart_create((2, 1))
    hx = HaloExchange(cart)
    out = {}

    def fast():
        out["fast1"] = hx.exchange({(0, 1): "f1"}, rank=0)
        out["fast2"] = hx.exchange({(0, 1): "f2"}, rank=0)

    def slow():
        out["slow1"] = hx.exchange({(0, -1): "s1"}, rank=1)
        out["slow2"] = hx.exchange({(0, -1): "s2"}, rank=1)

    t1, t2 = threading.Thread(target=fast), threading.Thread(target=slow)
    t1.start(), t2.start()
    t1.join(timeout=20), t2.join(timeout=20)
    assert out["fast1"] == {(0, 1): "s1"} and out["fast2"] == {(0, 1): "s2"}
    assert out["slow1"] == {(0, -1): "f1"}
    assert out["slow2"] == {(0, -1): "f2"}


def test_halo_exchange_event_mode_overlaps_interior_compute():
    """The paper's overlap pattern: halo tasks bind the exchange to their
    event counter and finish (zero pauses); interior compute proceeds;
    boundary compute declares a dependency and reads handle.result."""
    w = tac.CommWorld(4)
    cart = w.cart_create((2, 2))
    hx = HaloExchange(cart)
    handles, interior_done, boundary = {}, [], {}

    def comm(r):
        def body():
            sends = {d: np.float64(r) for d, _ in hx.neighbors(r)}
            handles[r] = hx.start(sends, rank=r, mode="event", key="it0")
            assert isinstance(handles[r], CollectiveHandle)
        return body

    def interior(r):
        def body():
            interior_done.append(r)
        return body

    def consume(r):
        def body():
            boundary[r] = {d: float(v)
                           for d, v in handles[r].result.items()}
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(4):
            rt.submit(comm(r), out=[("halo", r)])
            rt.submit(interior(r))             # no halo dependency
            rt.submit(consume(r), in_=[("halo", r)])
        rt.taskwait()
    assert rt.stats.get("task_blocks", 0) == 0
    assert sorted(interior_done) == [0, 1, 2, 3]
    for r in range(4):
        for d, nbr in cart.neighbor_dirs(r):
            assert boundary[r][d] == float(nbr)


def test_halo_exchange_blocking_mode_pauses():
    """Blocking halo rounds on a starved pool pause instead of deadlock."""
    w = tac.CommWorld(4)
    cart = w.cart_create((2, 2))
    hx = HaloExchange(cart)
    got = {}

    def make(r):
        def body():
            sends = {d: r for d, _ in hx.neighbors(r)}
            got[r] = hx.start(sends, rank=r, mode="blocking", key="b")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(4):
            rt.submit(make(r))
        rt.taskwait()
    assert rt.stats.get("task_blocks", 0) > 0
    for r in range(4):
        assert got[r] == {d: nbr for d, nbr in cart.neighbor_dirs(r)}


def test_halo_exchange_run_group_validation():
    w = tac.CommWorld(2)
    hx = HaloExchange(w.cart_create((2, 1)))
    with pytest.raises(ValueError, match="all 2 ranks"):
        hx.run_group([{}])
    with pytest.raises(TypeError, match="Cartesian"):
        HaloExchange(w)


# ---------------------------------------------------------------------------
# distributed-graph topologies (MPI_Dist_graph_create_adjacent analogue)
# ---------------------------------------------------------------------------
# an unstructured 5-rank mesh: a triangle (0-1-2) with a tail (2-3-4)
MESH = [(1, 2), (0, 2), (0, 1, 3), (2, 4), (3,)]


def test_dist_graph_structure_and_reciprocity():
    w = tac.CommWorld(5)
    g = w.dist_graph_create(MESH)
    assert g.size == 5
    assert g.neighbors(2) == [0, 1, 3]
    # reciprocity: r's direction d toward q matches q's (d[0], -d[1])
    for r in range(g.size):
        for d, q in g.neighbor_dirs(r):
            assert ((d[0], -d[1]), r) in g.neighbor_dirs(q)
    # topology() feeds build_neighbor: one validated schedule, cached by
    # value (an isomorphic graph shares the object)
    from repro.core import schedule as schedule_ir
    sched = schedule_ir.build_neighbor(g.topology())
    assert sched.n == 5
    g2 = w.dist_graph_create(MESH)
    assert schedule_ir.build_neighbor(g2.topology()) is sched


def test_dist_graph_validation():
    w = tac.CommWorld(4)
    with pytest.raises(ValueError, match="asymmetric"):
        w.dist_graph_create([(1,), (), (), ()])
    with pytest.raises(ValueError, match="self-loop"):
        w.dist_graph_create([(0, 1), (0,), (), ()])
    with pytest.raises(ValueError, match="out of range"):
        w.dist_graph_create([(3,), ()])
    with pytest.raises(ValueError, match="exceeds world size"):
        w.dist_graph_create([()] * 5)


def test_dist_graph_halo_exchange_unstructured_mesh():
    """HaloExchange over an unstructured mesh: every rank receives
    exactly its graph neighbours' payloads (ROADMAP next-direction)."""
    w = tac.CommWorld(5)
    g = w.dist_graph_create(MESH)
    hx = HaloExchange(g)
    sends = [{d: np.array([10.0 * r + i])
              for i, (d, _) in enumerate(hx.neighbors(r))}
             for r in range(5)]
    out = hx.run_group(sends)
    for r in range(5):
        assert set(out[r]) == {d for d, _ in hx.neighbors(r)}
        for d, q in hx.neighbors(r):
            # q sent toward its opposite direction (d[0], -d[1])
            expect = sends[q][(d[0], -d[1])]
            np.testing.assert_array_equal(out[r][d], expect)


def test_dist_graph_neighbor_alltoall_event_mode_on_runtime():
    w = tac.CommWorld(5)
    g = w.dist_graph_create(MESH)
    coll = Collectives(g)
    got = {}

    def comm(r):
        def body():
            sends = {d: np.float64(100 * r + q)
                     for d, q in g.neighbor_dirs(r)}
            got[r] = coll.neighbor_alltoall(sends, rank=r, mode="event",
                                            key="g")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(5):
            rt.submit(comm(r))
        rt.taskwait()
    for r in range(5):
        res = got[r].result
        for d, q in g.neighbor_dirs(r):
            assert float(res[d]) == 100 * q + r


# ---------------------------------------------------------------------------
# hierarchical allreduce (the first consumer of split)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,gs", [(4, 2), (6, 3), (7, 3), (5, 2), (3, 5)])
def test_hierarchical_allreduce_matches_flat(n, gs):
    w = tac.CommWorld(n)
    hier = HierarchicalCollectives(w, gs)
    rng = np.random.default_rng(n)
    vals = [rng.standard_normal(5) for _ in range(n)]
    out = hier.run_group(list(vals), op="sum")
    ref = np.sum(np.stack(vals), axis=0)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref, rtol=1e-12, atol=1e-12)
    for r in range(1, n):   # bitwise agreement: same combine order
        np.testing.assert_array_equal(out[0], out[r])


def test_hierarchical_group_structure():
    w = tac.CommWorld(7)
    hier = HierarchicalCollectives(w, 3)
    assert hier.intra[0].ranks == (0, 1, 2)
    assert hier.intra[0] is hier.intra[2]
    assert hier.intra[3].ranks == (3, 4, 5)
    assert hier.intra[6].ranks == (6,)          # smaller tail group
    assert hier.leaders.ranks == (0, 3, 6)
    # critical path: 2 intra chain hops each way + leader doubling
    assert hier.n_rounds() == 2 * 2 + n_rounds("allreduce", "doubling", 3)
    with pytest.raises(ValueError, match="positive"):
        HierarchicalCollectives(w, 0)
    with pytest.raises(ValueError, match="rank"):
        hier.allreduce(1.0, rank=7)


def test_hierarchical_modes_on_runtime():
    n = 6
    w = tac.CommWorld(n)
    hier = HierarchicalCollectives(w, 2)
    out = {}

    def make(r):
        def body():
            mode = "event" if r % 2 else "blocking"
            out[r] = hier.allreduce(np.float64(r), rank=r, op="sum",
                                    mode=mode, key="h")
        return body

    with TaskRuntime(num_workers=3) as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    vals = [out[r].result if isinstance(out[r], CollectiveHandle)
            else out[r] for r in range(n)]
    assert all(float(v) == 15.0 for v in vals)


# ---------------------------------------------------------------------------
# simulator neighbourhood nodes
# ---------------------------------------------------------------------------
def _halo_pair(kind, lat=0.5, t0=1.0, t1=3.0, other=False):
    a = SimTask(0, 0, t0, name="w0")
    b = SimTask(1, 1, t1, name="w1")
    ha = SimTask(2, 0, 0.1, kind=kind, start_deps=[(0, 0.0)],
                 neighbors=[(3, lat)], name="h0")
    hb = SimTask(3, 1, 0.1, kind=kind, start_deps=[(1, 0.0)],
                 neighbors=[(2, lat)], name="h1")
    tasks = [a, b, ha, hb]
    if other:
        # independent work queued behind h0 on rank 0's single worker:
        # what a held worker delays and a paused/event one does not
        tasks.append(SimTask(4, 0, 1.0, start_deps=[(0, 0.0)],
                             name="other"))
    return tasks


def test_sim_neighbor_completion_is_peer_arrival_plus_latency():
    res = Simulator(2, 1).run(_halo_pair(COMM_EVENTS))
    # h0 enters at 1.1, h1 at 3.1; h0 completes at 3.1+0.5, h1 at max(3.1,
    # 1.1+0.5) = 3.1 — no all-ranks barrier, just the declared edge.
    assert res.done_times[2] == pytest.approx(3.6)
    assert res.done_times[3] == pytest.approx(3.1)


def test_sim_neighbor_disciplines_order():
    """With independent work queued behind the halo node on a single
    worker, the held worker delays it; paused pays resumes; events pay
    nothing."""
    held = Simulator(2, 1).run(_halo_pair(COMM_HELD, other=True))
    paused = Simulator(2, 1, resume_overhead=0.01).run(
        _halo_pair(COMM_PAUSED, other=True))
    events = Simulator(2, 1).run(_halo_pair(COMM_EVENTS, other=True))
    assert events.makespan < paused.makespan < held.makespan
    assert sum(held.held_wait_time.values()) > 0
    assert events.resumes == 0


def test_sim_neighbor_validation():
    with pytest.raises(ValueError, match="comm "):
        Simulator(1, 1).run([SimTask(0, 0, 1.0, kind=COMPUTE,
                                     neighbors=[(0, 0.0)])])
    with pytest.raises(ValueError, match="unknown task"):
        Simulator(1, 1).run([SimTask(0, 0, 1.0, kind=COMM_EVENTS,
                                     neighbors=[(9, 0.0)])])
    comp = SimTask(0, 0, 1.0)
    halo = SimTask(1, 0, 1.0, kind=COMM_EVENTS, neighbors=[(0, 0.0)])
    with pytest.raises(ValueError, match="comm-kind"):
        Simulator(1, 1).run([comp, halo])


def test_sim_neighbor_graph_reusable_across_runs():
    tasks = _halo_pair(COMM_EVENTS)
    a = Simulator(2, 1).run(tasks).makespan
    b = Simulator(2, 1).run(tasks).makespan
    assert a == b
    assert all(not t.event_deps for t in tasks)


def test_sim_neighbors_compose_with_groups():
    """A graph may mix neighbourhood halo nodes and group collective
    nodes — the Gauss–Seidel shape."""
    tasks = _halo_pair(COMM_EVENTS)
    tasks.append(SimTask(4, 0, 0.1, kind=COMM_EVENTS, start_deps=[(2, 0.0)],
                         group="res", group_latency=0.2, name="r0"))
    tasks.append(SimTask(5, 1, 0.1, kind=COMM_EVENTS, start_deps=[(3, 0.0)],
                         group="res", group_latency=0.2, name="r1"))
    res = Simulator(2, 1).run(tasks)
    assert res.done_times[4] == res.done_times[5]


def test_gauss_seidel_halo_event_beats_sentinel():
    """Acceptance (PR 2): with the halo exchange expressed as
    neighbourhood nodes, event mode still strictly beats the
    blocking-sentinel baseline, including non-power-of-two rank counts."""
    from benchmarks.gauss_seidel import simulate_version
    for n in (3, 4, 5):
        kw = dict(n_ranks=n, nby=2, nbx=2, iters=4)
        ev = simulate_version("interop-nonblk", **kw)
        sn = simulate_version("sentinel", **kw)
        assert ev < sn, (n, ev, sn)


# ---------------------------------------------------------------------------
# directed (asymmetric) dist-graph topologies: one-way edges
# ---------------------------------------------------------------------------
# a 4-rank directed graph with one-way edges: 0->1, 0->2, 1->3, 2->3, 3->0
# (a diamond with a back edge; rank 0 has out-degree 2 and in-degree 1)
DIRECTED = [(1, 2), (3,), (3,), (0,)]


def test_directed_dist_graph_structure():
    w = tac.CommWorld(4)
    g = w.dist_graph_create(DIRECTED, directed=True)
    assert g.directed
    assert g.neighbors(0) == [1, 2] and g.in_neighbors(0) == [3]
    assert g.neighbors(3) == [0] and g.in_neighbors(3) == [1, 2]
    # edge u->v is send-dir ((u, v), +1) at u, recv-dir ((u, v), -1) at v
    assert g.neighbor_dirs(0) == [(((0, 1), 1), 1), (((0, 2), 1), 2)]
    assert g.in_neighbor_dirs(3) == [(((1, 3), -1), 1), (((2, 3), -1), 2)]
    # the symmetric ctor still rejects the same adjacency
    with pytest.raises(ValueError, match="directed=True"):
        w.dist_graph_create(DIRECTED)


def test_directed_dist_graph_double_edges_are_independent():
    """u->v and v->u declared together are two one-way edges with
    distinct direction labels, not one undirected edge."""
    w = tac.CommWorld(2)
    g = w.dist_graph_create([(1,), (0,)], directed=True)
    assert g.neighbor_dirs(0) == [(((0, 1), 1), 1)]
    assert g.neighbor_dirs(1) == [(((1, 0), 1), 0)]
    assert g.in_neighbor_dirs(0) == [(((1, 0), -1), 1)]
    assert g.in_neighbor_dirs(1) == [(((0, 1), -1), 0)]


def test_directed_group_translation_one_way_edges():
    """Group-local adjacency over non-contiguous world ranks: the edge
    endpoints name *group* ranks; payloads travel between the right
    world ranks (translation) and only along declared edges."""
    w = tac.CommWorld(6)
    grp = w.group([5, 1, 4, 2])        # group rank i -> world rank
    g = grp.graph(DIRECTED, directed=True)
    assert g.ranks == (5, 1, 4, 2)
    # group rank 0 (world 5) sends one-way to group rank 1 (world 1)
    h = g.isend(np.float64(7.0), src=0, dst=1, tag="edge")
    assert g.irecv(src=0, dst=1, tag="edge").result == 7.0
    assert h.test()
    # translation across sibling groups still works on the graph group
    other = w.group([4, 5])
    assert g.translate(2, other) == 0   # world 4
    assert g.translate(3, other) is None


def test_directed_build_neighbor_validates_in_topology():
    from repro.core import schedule as schedule_ir
    w = tac.CommWorld(4)
    g = w.dist_graph_create(DIRECTED, directed=True)
    sched = schedule_ir.build_neighbor(g.topology(), g.in_topology())
    assert sched.n == 4
    assert sched.in_dirs[3] == (((1, 3), -1), ((2, 3), -1))
    assert sched.out_dirs[3] == (((3, 0), 1),)
    # a wrong declaration is rejected against the derived arrivals
    bad = list(g.in_topology())
    bad[0] = ()
    with pytest.raises(ValueError, match="declared in-directions"):
        schedule_ir.build_neighbor(g.topology(), tuple(bad))


def test_directed_halo_exchange_run_group():
    """One-way exchange end to end: every rank receives exactly its
    in-edges' payloads, keyed by the receive direction."""
    w = tac.CommWorld(4)
    g = w.dist_graph_create(DIRECTED, directed=True)
    hx = HaloExchange(g)
    sends = [{d: np.float64(100 * r + q) for d, q in g.neighbor_dirs(r)}
             for r in range(4)]
    out = hx.run_group(sends)
    for r in range(4):
        assert set(out[r]) == {d for d, _ in g.in_neighbor_dirs(r)}
        for d, q in g.in_neighbor_dirs(r):
            # in-dir ((q, r), -1) was fed by q's send-dir ((q, r), +1)
            np.testing.assert_array_equal(out[r][d], sends[q][(d[0], 1)])


def test_directed_neighbor_alltoall_event_mode_on_runtime():
    w = tac.CommWorld(4)
    g = w.dist_graph_create(DIRECTED, directed=True)
    coll = Collectives(g)
    got = {}

    def comm(r):
        def body():
            sends = {d: np.float64(10 * r + q)
                     for d, q in g.neighbor_dirs(r)}
            got[r] = coll.neighbor_alltoall(sends, rank=r, mode="event",
                                            key="d")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(4):
            rt.submit(comm(r))
        rt.taskwait()
    for r in range(4):
        res = got[r].result
        assert set(res) == {d for d, _ in g.in_neighbor_dirs(r)}
        for d, q in g.in_neighbor_dirs(r):
            assert float(res[d]) == 10 * q + r
