"""Checkpoint subsystem: mesh-agnostic format, async saves, elastic
restore after an injected failure.

Complements test_system.py's training-loop checkpoint tests with direct
unit coverage of repro.checkpoint: the manifest round-trip across
meshes (4×2 → 2×4, the elastic-worlds prerequisite), ``latest_step``
selection, the AsyncCheckpointer's dependency-release semantics (saves
serialise through the inout region; ``wait_all`` is a taskwait), and
restore-after-injected-rank-death driving the benchmarks' recovery
loop.  Device-count-dependent tests run in subprocesses like
test_distributed.py (jax locks the device count at first init).
"""

import os
import subprocess
import sys
import tempfile
import threading

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import tac

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_save_restore_round_trip_host_arrays(tmp_path):
    state = {"w": np.arange(12.0).reshape(3, 4),
             "opt": {"m": np.ones(5), "step": np.int64(3)}}
    d = str(tmp_path / "ck")
    path = ckpt.save_checkpoint(d, state, step=4)
    assert os.path.isdir(path)
    restored, step = ckpt.restore_checkpoint(
        d, {"w": np.empty((3, 4)), "opt": {"m": np.empty(5),
                                           "step": np.int64(0)}})
    assert step == 4
    np.testing.assert_array_equal(restored["w"], state["w"])
    np.testing.assert_array_equal(restored["opt"]["m"], state["opt"]["m"])
    assert int(restored["opt"]["step"]) == 3


def test_latest_step_and_explicit_step(tmp_path):
    d = str(tmp_path / "ck")
    assert ckpt.latest_step(d) is None
    for s in (1, 5, 3):
        ckpt.save_checkpoint(d, {"x": np.full(2, float(s))}, step=s)
    assert ckpt.latest_step(d) == 5
    r5, s5 = ckpt.restore_checkpoint(d, {"x": np.empty(2)})
    assert s5 == 5 and r5["x"][0] == 5.0
    r3, s3 = ckpt.restore_checkpoint(d, {"x": np.empty(2)}, step=3)
    assert s3 == 3 and r3["x"][0] == 3.0
    with pytest.raises(FileNotFoundError):
        ckpt.restore_checkpoint(str(tmp_path / "nope"), {"x": np.empty(2)})


def test_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, {"x": np.zeros((2, 2))}, step=0)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_checkpoint(d, {"x": np.empty(3)})


@pytest.mark.slow
def test_mesh_agnostic_round_trip_4x2_to_2x4():
    """A sharded train state saved on a (4,2) mesh restores bitwise onto
    a (2,4) mesh — the gather-full/reshard-on-read format."""
    _run("""
import jax, numpy as np, tempfile
from repro import configs, optim
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step

cfg = configs.smoke("granite_3_2b").scaled(dtype="float32")
state = steps.init_train_state(cfg, optim.OptimConfig(),
                               jax.random.PRNGKey(1))
pol = ShardingPolicy()
mesh_a, mesh_b = make_mesh((4, 2), ("data", "model")), \
                 make_mesh((2, 4), ("data", "model"))
sa = steps.state_shardings(mesh_a, jax.eval_shape(lambda: state), pol)
state_a = jax.device_put(state, sa)
d = tempfile.mkdtemp()
save_checkpoint(d, state_a, step=11)
assert latest_step(d) == 11
sb = steps.state_shardings(mesh_b, jax.eval_shape(lambda: state), pol)
restored, step = restore_checkpoint(d, jax.eval_shape(lambda: state), sb)
assert step == 11
for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(state_a)),
                jax.tree_util.tree_leaves(jax.device_get(restored))):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("MESH-AGNOSTIC-OK")
""")


def test_async_save_dependency_release(tmp_path):
    """AsyncCheckpointer.save returns immediately; the EventHandle
    completes when the writer task releases the checkpoint-dir region;
    consecutive saves serialise through it (steps publish in order)."""
    d = str(tmp_path / "ck")
    cp = ckpt.AsyncCheckpointer(d, keep=2)
    gate = threading.Event()
    orig_write = ckpt._write
    published = []

    def slow_write(base, host_state, step):
        gate.wait(timeout=30)           # hold the first save open
        path = orig_write(base, host_state, step)
        published.append(step)
        return path

    ckpt._write = slow_write
    try:
        h1 = cp.save({"x": np.zeros(4)}, step=1)
        h2 = cp.save({"x": np.ones(4)}, step=2)
        assert not h1.test() and not h2.test()   # save() did not block
        gate.set()
        assert h1.wait().endswith("step_0000000001")
        assert h2.wait().endswith("step_0000000002")
        assert published == [1, 2]      # inout region serialised them
    finally:
        ckpt._write = orig_write
        cp.close()
    assert cp.runtime.polling.num_services == 0


def test_async_save_gc_keeps_latest(tmp_path):
    d = str(tmp_path / "ck")
    cp = ckpt.AsyncCheckpointer(d, keep=2)
    for s in range(1, 5):
        cp.save({"x": np.full(3, float(s))}, step=s)
    cp.close()
    assert ckpt.latest_step(d) == 4
    kept = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                  if n.startswith("step_"))
    assert kept == [3, 4]


@pytest.mark.faults
def test_restore_after_injected_failure(tmp_path):
    """The benchmark recovery loop in miniature: checkpoint per step, a
    FaultInjector kills a rank mid-collective, survivors shrink and the
    resumed state comes from the LAST COMPLETED step, not the torn one."""
    from repro.core import Collectives
    from repro.core.resilience import FaultInjector, recover

    d = str(tmp_path / "ck")
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(4)
    coll = Collectives(world)
    inj = FaultInjector(world)
    state = np.arange(8.0)
    ckpt.save_checkpoint(d, {"state": state}, step=0)

    def step_all(coll, state, n, key):
        out = coll.run_group(
            "allreduce", [{"value": state / n} for _ in range(n)],
            key=key)
        return np.asarray(out[0])

    state = step_all(coll, state, 4, "s1")
    ckpt.save_checkpoint(d, {"state": state}, step=1)
    inj.arm(2, after_ops=1)
    with pytest.raises(tac.RankFailedError):
        step_all(coll, state, 4, "s2")        # torn step: never published
    assert ckpt.latest_step(d) == 1           # no partial checkpoint
    g = recover(world)
    restored, step = ckpt.restore_checkpoint(d, {"state": np.empty(8)})
    assert step == 1
    np.testing.assert_array_equal(restored["state"], state)
    # survivors continue from the restored state on the shrunken group
    final = step_all(Collectives(g), restored["state"], 3, "s2r")
    np.testing.assert_allclose(final, restored["state"], rtol=1e-12)
