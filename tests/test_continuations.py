"""Continuation-based completion notification (core/continuations.py).

Covers the engine itself (attach, all-of sets, chaining, bounded queues,
poll fallback, error capture), the ProgressEngine ``notify="continuation"``
backend (O(completions) dispatches vs the polling backend's
O(in-flight × ticks) tests — the acceptance-criterion counters), the
runtime wiring (TaskRuntime.continuations, wait/iwait routing,
scheduling-point drains, deterministic service teardown), the §5
single-worker deadlock regression under nested blocking + continuation
notification, and the simulator's callback-dispatch cost model.
"""

import threading

import numpy as np
import pytest

from repro.core import Collectives, TaskRuntime, tac
from repro.core.collectives import (CollectiveHandle, HaloExchange,
                                    ProgressEngine, _Machine)
from repro.core.continuations import Continuation, ContinuationEngine
from repro.core.simulate import (COMM_EVENTS, COMPUTE, SimTask, Simulator,
                                 progress_cost)


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


def _world(n, **kw):
    w = tac.CommWorld(n)
    return w, Collectives(w, **kw)


# ---------------------------------------------------------------------------
# the engine standalone
# ---------------------------------------------------------------------------
def test_attach_single_handle_dispatches_once():
    eng = ContinuationEngine()
    h = tac.EventHandle()
    ran = []
    cont = eng.attach(h, lambda: ran.append(1))
    assert not cont.test() and eng.queued == 0
    h.complete("payload")
    assert eng.queued == 1 and not ran       # queued, not run inline
    assert eng.dispatch() == 1
    assert ran == [1] and cont.test() and cont.result == "payload"
    assert eng.stats["completions"] == 1 and eng.stats["dispatches"] == 1
    assert eng.stats["tests"] == 0           # push handle: never tested


def test_attach_already_complete_handle():
    eng = ContinuationEngine()
    h = tac.EventHandle()
    h.complete(7)
    cont = eng.attach(h, lambda: None)
    assert eng.queued == 1                   # ready at attach time
    eng.dispatch()
    assert cont.result == 7


def test_attach_set_fires_after_all():
    eng = ContinuationEngine()
    hs = [tac.EventHandle() for _ in range(3)]
    ran = []
    cont = eng.attach(hs, lambda: ran.append("all"))
    for i, h in enumerate(hs):
        assert eng.queued == 0 and not ran
        h.complete(i)
    assert eng.queued == 1
    eng.dispatch()
    assert ran == ["all"] and cont.result == [0, 1, 2]
    assert eng.stats["completions"] == 1     # the SET completed once


def test_continuations_chain():
    """attach() returns a testable/waitable handle, so continuations
    chain — the Continuations-paper property."""
    eng = ContinuationEngine()
    h = tac.EventHandle()
    order = []
    c1 = eng.attach(h, lambda: order.append("first"))
    c2 = eng.attach(c1, lambda: order.append("second"))
    c3 = eng.attach(c2, lambda: order.append("third"))
    h.complete("x")
    # one dispatch() drains the whole cascade, in dependency order
    eng.dispatch()
    assert order == ["first", "second", "third"]
    assert c3.test() and c2.test()
    assert eng.stats["dispatches"] == 3


def test_poll_fallback_for_pushless_handles():
    """Handles without on_complete (e.g. jax ArrayHandle) are polled from
    the engine's fallback list — the only tests it ever performs."""
    class Plain:
        def __init__(self):
            self.done = False
            self.result = None

        def test(self):
            return self.done

    eng = ContinuationEngine()
    h = Plain()
    ran = []
    eng.attach(h, lambda: ran.append(1))
    assert eng.polled == 1
    eng.service(None)
    assert not ran and eng.stats["tests"] == 1
    h.done = True
    h.result = 5
    eng.service(None)                        # test + arrival + dispatch
    assert ran == [1] and eng.polled == 0
    assert eng.stats["tests"] == 2


def test_bounded_queue_overflow_dispatches_inline():
    eng = ContinuationEngine(queue_capacity=2)
    hs = [tac.EventHandle() for _ in range(5)]
    ran = []
    for i, h in enumerate(hs):
        eng.attach(h, lambda i=i: ran.append(i))
    for h in hs:
        h.complete(None)
    # capacity 2 queued; 3 overflowed and ran on the completing thread
    assert eng.stats["inline_dispatches"] == 3 and len(ran) == 3
    eng.dispatch()
    assert sorted(ran) == [0, 1, 2, 3, 4]
    assert eng.stats["dispatches"] == 5


def test_callback_error_captured_not_raised():
    eng = ContinuationEngine()
    h = tac.EventHandle()
    cont = eng.attach(h, lambda: 1 / 0)
    h.complete(None)
    eng.dispatch()                           # must not raise here
    assert eng.stats["callback_errors"] == 1
    assert cont.test() and cont.error is not None
    with pytest.raises(ZeroDivisionError):
        _ = cont.result
    # the engine survives: later attachments still dispatch
    h2 = tac.EventHandle()
    ok = eng.attach(h2, lambda: None)
    h2.complete(3)
    eng.dispatch()
    assert ok.result == 3


def test_failed_handle_result_does_not_kill_dispatcher():
    """A handle whose `result` re-raises (a failed CollectiveHandle) must
    not escape dispatch: the error lands on the continuation and the
    dispatching thread survives."""
    eng = ContinuationEngine()
    h = CollectiveHandle()
    ran = []
    cont = eng.attach(h, lambda: ran.append(1))
    h.fail(ValueError("boom"))
    eng.dispatch()                           # must not raise
    assert ran == [1]                        # callback itself still ran
    assert eng.stats["callback_errors"] == 1
    with pytest.raises(ValueError, match="boom"):
        _ = cont.result


def test_attach_validates():
    eng = ContinuationEngine()
    with pytest.raises(ValueError):
        eng.attach([], lambda: None)
    with pytest.raises(ValueError):
        ContinuationEngine(queue_capacity=0)


def test_continuation_is_a_waitable_handle():
    """tac.wait accepts a Continuation anywhere it accepts an operation
    handle (the PMPI path here: no task)."""
    eng = ContinuationEngine()
    h = tac.EventHandle()
    cont = eng.attach(h, lambda: None)
    t = threading.Thread(target=lambda: (h.complete(42), eng.dispatch()))
    t.start()
    assert tac.wait(cont) == 42
    t.join()


# ---------------------------------------------------------------------------
# the acceptance-criterion counters: O(completions) vs O(in-flight × ticks)
# ---------------------------------------------------------------------------
def _event_machines(engine, n):
    """n in-flight machines, each waiting on one EventHandle."""
    handles = [tac.EventHandle() for _ in range(n)]

    def gen(h):
        res = yield h
        return res

    for h in handles:
        engine.submit(_Machine(gen(h), CollectiveHandle()))
    return handles


def test_progress_counters_flat_vs_linear():
    """64 in-flight event-bound operations, one completion per tick: the
    polling backend performs O(in-flight × ticks) tests, the continuation
    backend O(completions) dispatches and ZERO tests."""
    n = 64

    poll_eng = ProgressEngine()
    handles = _event_machines(poll_eng, n)
    for i, h in enumerate(handles):
        h.complete(i)
        poll_eng.poll(None)
    assert poll_eng.pending == 0
    assert poll_eng.stats["tests"] == n * (n + 1) // 2   # 2080: Σ in-flight

    engine = ContinuationEngine()
    cont_eng = ProgressEngine(notify="continuation", continuations=engine)
    handles = _event_machines(cont_eng, n)
    for i, h in enumerate(handles):
        h.complete(i)
        engine.service(None)
    assert cont_eng.pending == 0
    assert engine.stats["dispatches"] == n               # one per completion
    assert engine.stats["tests"] == 0                    # pure push
    assert cont_eng.stats["rearms"] == n
    assert cont_eng.stats["tests"] == 0                  # never re-polled


def test_continuation_machine_rearms_across_rounds():
    """A multi-wait machine re-arms a continuation per awaited handle —
    dispatches stay O(completions), not O(machines × ticks)."""
    engine = ContinuationEngine()
    eng = ProgressEngine(notify="continuation", continuations=engine)
    h1, h2, h3 = (tac.EventHandle() for _ in range(3))

    def gen():
        a = yield h1
        b = yield [h2, h3]
        return a + sum(b)

    done = CollectiveHandle()
    eng.submit(_Machine(gen(), done))
    h1.complete(1)
    engine.service(None)
    assert not done.test() and eng.pending == 1
    h2.complete(2)
    engine.service(None)                      # set incomplete: no fire
    assert not done.test()
    h3.complete(3)
    engine.service(None)
    assert done.result == 6 and eng.pending == 0
    assert eng.stats["rearms"] == 2           # one per parked wait


def test_progress_engine_validates_backend():
    with pytest.raises(ValueError):
        ProgressEngine(notify="wat")
    with pytest.raises(ValueError):
        ProgressEngine(notify="continuation")   # engine required


# ---------------------------------------------------------------------------
# runtime wiring: both backends drive the full collective stack
# ---------------------------------------------------------------------------
BACKENDS = ("polling", "continuation")


@pytest.mark.parametrize("notify", BACKENDS)
def test_many_in_flight_event_collectives_stress(notify):
    """≥64 concurrent event-bound collectives (8 ranks × 8 keyed
    allreduces) under each notification backend."""
    n, per_rank = 8, 8
    _, coll = _world(n)
    vals = {(r, k): np.full(4, float(r + 1) * (k + 1))
            for r in range(n) for k in range(per_rank)}
    refs = {k: np.sum(np.stack([vals[(r, k)] for r in range(n)]), axis=0)
            for k in range(per_rank)}
    handles = {}

    def comm(r):
        def body():
            for k in range(per_rank):
                handles[(r, k)] = coll.allreduce(
                    vals[(r, k)], rank=r, op="sum", algorithm="ring",
                    mode="event", key=("stress", k))
        return body

    with TaskRuntime(num_workers=4, notify=notify) as rt:
        for r in range(n):
            rt.submit(comm(r))
        rt.taskwait()
    assert len(handles) == n * per_rank      # 64 in-flight operations
    for (r, k), h in handles.items():
        np.testing.assert_allclose(h.result, refs[k])
    assert rt.stats.get("task_blocks", 0) == 0
    if notify == "continuation":
        # machines rode the continuation engine, not a polled list
        assert rt._coll_engine.notify == "continuation"
        assert rt.continuations.stats["dispatches"] > 0
    rt.close()
    assert rt.polling.num_services == 0      # deterministic teardown


@pytest.mark.parametrize("notify", BACKENDS)
def test_blocking_collectives_both_backends(notify):
    n = 5
    _, coll = _world(n)
    vals = [np.arange(6.0) * (r + 1) for r in range(n)]
    ref = np.sum(np.stack(vals), axis=0)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(vals[r], rank=r, op="sum",
                                        mode="blocking", key="b")
        return body

    with TaskRuntime(num_workers=2, notify=notify) as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    for r in range(n):
        np.testing.assert_allclose(results[r], ref)
    assert rt.stats["task_blocks"] == rt.stats["task_resumes"] > 0


def test_nested_single_worker_deadlock_regression_continuation():
    """§5 with block_mode="nested", ONE worker and continuation
    notification: the blocked task's stack serves the engine's service
    (dispatching ready callbacks) while it helps, so the multi-round
    blocking collective completes without spare threads."""
    n = 3
    _, coll = _world(n)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                        algorithm="ring", mode="blocking",
                                        key="nc")
        return body

    with TaskRuntime(num_workers=1, block_mode="nested",
                     notify="continuation") as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    assert all(float(results[r]) == 3.0 for r in range(n))
    assert rt.stats["threads_spawned"] == 1   # no spares in nested mode


@pytest.mark.parametrize("notify", BACKENDS)
def test_wait_iwait_routing(notify):
    """tac.wait pauses/resumes and tac.iwait(all) defers release through
    whichever backend the runtime selected."""
    done = {}

    def producer(handles):
        def body():
            for i, h in enumerate(handles):
                h.complete(i)
        return body

    h_wait = tac.EventHandle()
    h_i1, h_i2, h_i3 = (tac.EventHandle() for _ in range(3))

    def waiter():
        done["wait"] = tac.wait(h_wait)

    def binder():
        tac.iwait(h_i1)
        tac.iwaitall([h_i2, h_i3])

    def consumer():
        done["iwait"] = (h_i1.result, h_i2.result, h_i3.result)

    with TaskRuntime(num_workers=2, notify=notify) as rt:
        rt.submit(binder, out=["b"])
        rt.submit(waiter, out=["w"])
        rt.submit(producer([h_wait, h_i1, h_i2, h_i3]))
        rt.submit(consumer, in_=["b"])
        rt.taskwait()
    assert done["wait"] == 0
    assert done["iwait"] == (1, 2, 3)


def test_failing_collective_releases_and_teardown_clean():
    """A raising schedule must not leave services registered after close
    (the leak-fix satellite): stress with failing machines, then assert
    zero registered services.  n=2 so every rank's combine raises and
    releases (a failed peer stalling the others is separate, documented
    MPI-like behaviour)."""
    n = 2
    _, coll = _world(n)
    handles = {}

    def capture(r):
        def body():
            handles[r] = coll.allreduce(
                np.zeros(3 if r == 0 else 4), rank=r, op="sum",
                algorithm="doubling", mode="event", key="bad")
        return body

    for notify in BACKENDS:
        handles.clear()
        rt = TaskRuntime(num_workers=2, notify=notify)
        with rt:
            for r in range(n):
                rt.submit(capture(r))
            rt.taskwait()                     # must not hang
        failed = [r for r in range(n) if handles[r].error is not None]
        assert failed
        with pytest.raises(ValueError):
            _ = handles[failed[0]].result
        assert rt.polling.num_services == 0, \
            f"{notify}: services leaked past close()"


def test_close_unregisters_every_runtime_service():
    rt = TaskRuntime(num_workers=1, speculative_timeout=60.0)
    rt.start()
    _ = rt.continuations                      # engine + its one service
    h = tac.EventHandle()

    def body():
        tac.iwait(h)

    rt.submit(body)
    h.complete(None)
    rt.taskwait()
    # ticket pool / continuation engine / straggler watch all registered
    assert rt.polling.num_services >= 2
    rt.close()
    assert rt.polling.num_services == 0


def test_one_service_total_not_one_per_operation():
    """100 attached operations: still exactly ONE registered service."""
    with TaskRuntime(num_workers=2, notify="continuation") as rt:
        before = rt.polling.num_services
        hs = [tac.EventHandle() for _ in range(100)]

        def body():
            tac.iwaitall(hs)

        rt.submit(body)
        mid = rt.polling.num_services
        for h in hs:
            h.complete(None)
        rt.taskwait()
        assert mid == before  # attaching 100 ops registered nothing new


# ---------------------------------------------------------------------------
# neighbourhood + chained waits end-to-end under continuation notify
# ---------------------------------------------------------------------------
def test_halo_exchange_event_mode_continuation_backend():
    w = tac.CommWorld(4)
    cart = w.cart_create((2, 2), periodic=False)
    hx = HaloExchange(cart)
    got = {}

    def comm(r):
        def body():
            sends = {d: np.full(2, float(10 * r + i))
                     for i, (d, _) in enumerate(hx.neighbors(r))}
            got[r] = hx.start(sends, rank=r, mode="event", key="h")
        return body

    with TaskRuntime(num_workers=2, notify="continuation") as rt:
        for r in range(4):
            rt.submit(comm(r))
        rt.taskwait()
    for r in range(4):
        res = got[r].result
        assert set(res) == {d for d, _ in hx.neighbors(r)}


def test_task_waits_on_chained_continuation():
    """A task blocks on a continuation-of-a-continuation — chaining
    composes with the task-aware wait."""
    out = {}

    def body():
        rt = tac.current_task()._runtime
        h = tac.EventHandle()
        c1 = rt.continuations.attach(h, lambda: out.setdefault("first", 1))
        c2 = rt.continuations.attach(c1, lambda: out.setdefault("second", 2))
        threading.Thread(target=lambda: h.complete("done")).start()
        tac.wait(c2)
        out["result"] = c1.result

    with TaskRuntime(num_workers=1, notify="continuation") as rt:
        rt.submit(body)
        rt.taskwait()
    assert out == {"first": 1, "second": 2, "result": "done"}


# ---------------------------------------------------------------------------
# simulator: callback-dispatch cost + the analytic progress model
# ---------------------------------------------------------------------------
def _two_task_event_graph():
    return [
        SimTask(0, 0, 1.0, kind=COMPUTE),
        SimTask(1, 0, 0.5, kind=COMM_EVENTS, event_deps=[(0, 2.0)]),
    ]


def test_simulator_dispatch_overhead_shifts_release():
    base = Simulator(1, 1).run(_two_task_event_graph()).makespan
    lag = Simulator(1, 1, dispatch_overhead=0.25).run(
        _two_task_event_graph()).makespan
    assert base == pytest.approx(3.0)         # body 1.0 + edge 2.0
    assert lag == pytest.approx(3.25)         # + one dispatch


def test_simulator_dispatch_overhead_zero_is_identity():
    tasks = [SimTask(i, 0, 0.1, kind=COMM_EVENTS if i else COMPUTE,
                     event_deps=[(0, 1.0)] if i else [])
             for i in range(3)]
    a = Simulator(1, 2).run([SimTask(t.id, t.rank, t.compute, kind=t.kind,
                                     event_deps=list(t.event_deps))
                             for t in tasks]).makespan
    b = Simulator(1, 2, dispatch_overhead=0.0).run(tasks).makespan
    assert a == b


def test_progress_cost_model():
    # polling: linear in in-flight × ticks; continuation: completions only
    p = progress_cost("polling", in_flight=64, ticks=100, completions=10,
                      test_s=1e-6, dispatch_s=2e-6)
    c = progress_cost("continuation", in_flight=64, ticks=100,
                      completions=10, test_s=1e-6, dispatch_s=2e-6)
    assert p == pytest.approx(64 * 100 * 1e-6 + 10 * 2e-6)
    assert c == pytest.approx(10 * 2e-6)
    # doubling the in-flight count doubles polling, leaves continuation flat
    p2 = progress_cost("polling", in_flight=128, ticks=100, completions=10,
                       test_s=1e-6, dispatch_s=2e-6)
    c2 = progress_cost("continuation", in_flight=128, ticks=100,
                       completions=10, test_s=1e-6, dispatch_s=2e-6)
    assert p2 > 1.9 * p and c2 == c
    with pytest.raises(ValueError):
        progress_cost("wat", in_flight=1, ticks=1, completions=1,
                      test_s=1, dispatch_s=1)


def test_runtime_rejects_unknown_notify():
    with pytest.raises(ValueError):
        TaskRuntime(notify="wat")


# ---------------------------------------------------------------------------
# striped stats cells (repro.obs.registry.Counter): exact reconciliation
# ---------------------------------------------------------------------------
def test_stats_reconcile_exactly_under_concurrency():
    """The lock-per-increment ``stats`` dict became striped registry
    counters — increments are lock-free, yet the totals must stay EXACT:
    after a full drain every attach has a completion and a dispatch, no
    callback error, no lost count."""
    import collections

    eng = ContinuationEngine(queue_capacity=64)
    n_threads, per = 6, 250
    fired = collections.deque()          # deque.append is atomic

    def churn():
        for _ in range(per):
            h = tac.EventHandle()
            eng.attach(h, lambda: fired.append(1))
            h.complete(None)
            eng.dispatch()

    threads = [threading.Thread(target=churn) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    while eng.dispatch():                # drain any queued residue
        pass

    total = n_threads * per
    s = eng.stats
    assert s["attached"] == total
    assert s["completions"] == total
    # every completion is dispatched exactly once (queued or inline when
    # the bounded queue overflows under the 6-thread churn)
    assert s["dispatches"] == total
    assert s["inline_dispatches"] + (total - s["inline_dispatches"]) == total
    assert s["callback_errors"] == 0
    assert len(fired) == total           # callbacks all ran, exactly once
    # reads are snapshots: a fresh, equal dict each time — not a shared
    # mutable mapping callers could race on
    again = eng.stats
    assert again == s and again is not s
