"""End-to-end benchmark parity: notification backends and elastic resume.

Two families, both over the REAL benchmark executions (not simulations):

* **Notify parity** — the Gauss–Seidel and IFSKer interop versions must
  produce bit-identical results under the polling engine and the
  continuation engine (the ROADMAP e2e leg: the backend changes how
  completions are observed, never what is computed).

* **Elastic resume equality** — a run that loses a rank mid-iteration,
  shrinks, and resumes from its last checkpoint must equal the clean
  reference: for IFSKer (decomposition-independent numerics) the
  full-size clean run, bitwise; for Gauss–Seidel (decomposition-coupled
  numerics) a clean run at the SHRUNKEN size seeded from the same
  checkpoint step.
"""

import shutil

import numpy as np
import pytest

from benchmarks import gauss_seidel as gs
from benchmarks import ifsker
from repro import checkpoint as ckpt

NOTIFY = ("polling", "continuation")
_GS = dict(n_ranks=4, nby=2, nbx=2, bs=8, iters=2, seed=3)
_IF = dict(n_ranks=2, n_fields=4, n_grid=16, steps=2, seed=3)


@pytest.mark.parametrize("version", ["interop-blk", "interop-nonblk"])
def test_gauss_seidel_notify_backend_parity(version):
    ref, ref_stats = gs.run_real("pure", **_GS)
    outs = {}
    for nb in NOTIFY:
        out, stats = gs.run_real(version, notify=nb, **_GS)
        np.testing.assert_array_equal(out, ref)
        for it, v in ref_stats["residuals"].items():
            assert abs(stats["residuals"][it] - v) < 1e-9, (nb, it)
        outs[nb] = out
    np.testing.assert_array_equal(outs["polling"], outs["continuation"])


@pytest.mark.parametrize("version", ["interop-blk", "interop-nonblk"])
def test_ifsker_notify_backend_parity(version):
    ref, _ = ifsker.run_real("pure", **_IF)
    for nb in NOTIFY:
        out, _ = ifsker.run_real(version, notify=nb, **_IF)
        np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# elastic resume equality
# ---------------------------------------------------------------------------
@pytest.mark.faults
def test_ifsker_elastic_resume_equals_clean_run(tmp_path):
    """IFSKer numerics are decomposition-independent, so the killed +
    shrunken + resumed run must equal the undisturbed full-size run
    BITWISE — the strongest form of the resume property."""
    clean, ic = ifsker.run_elastic(str(tmp_path / "a"), steps=3, seed=11)
    assert not ic["recoveries"]
    healed, ih = ifsker.run_elastic(str(tmp_path / "b"), steps=3, seed=11,
                                    kill_step=2, kill_rank=3)
    assert ih["recoveries"] and ih["size"] == 3
    assert ih["recoveries"][0]["resumed_step"] == 1
    np.testing.assert_array_equal(clean, healed)


@pytest.mark.faults
@pytest.mark.parametrize("notify", NOTIFY)
def test_gauss_seidel_elastic_resume_matches_shrunken_reference(tmp_path,
                                                                notify):
    """Gauss–Seidel numerics depend on the decomposition, so the resume
    property is: the killed run's tail equals a CLEAN run at the
    shrunken size seeded from the same checkpoint step."""
    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    kw = dict(bs=4, iters=4, seed=7, notify=notify)
    healed, info = gs.run_elastic(da, n_ranks=4, nby=3, nbx=3,
                                  kill_iter=3, kill_rank=1, **kw)
    assert info["recoveries"], info
    rec = info["recoveries"][0]
    assert rec["survivors"] == 3 and rec["resumed_step"] == 2
    assert info["decomposition"] == (3, 2, 6)   # re-shaped (3,1) grid

    # reference: seed a fresh dir with the SAME checkpoint the killed
    # run resumed from, then run clean at 3 ranks over the same global
    # geometry (3*2 x 1*6 blocks = the killed run's 6x6)
    state, step = ckpt.restore_checkpoint(
        da, {"grid": np.empty((6 * 4, 6 * 4))}, step=rec["resumed_step"])
    ckpt.save_checkpoint(db, state, step=step)
    clean, ic = gs.run_elastic(db, n_ranks=3, nby=2, nbx=6, **kw)
    assert not ic["recoveries"]
    np.testing.assert_array_equal(healed, clean)


@pytest.mark.faults
def test_gauss_seidel_elastic_backend_parity(tmp_path):
    """The killed + resumed trajectory itself is backend-invariant."""
    outs = {}
    for nb in NOTIFY:
        out, info = gs.run_elastic(str(tmp_path / nb), n_ranks=4, nby=3,
                                   nbx=3, bs=4, iters=3, kill_iter=2,
                                   kill_rank=2, seed=5, notify=nb)
        assert info["recoveries"]
        outs[nb] = out
    np.testing.assert_array_equal(outs["polling"], outs["continuation"])
