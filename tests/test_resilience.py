"""ULFM-style fault tolerance: injection, propagation, shrink, rebuild.

Layers under test (PR tentpole):

1. **Transport failure model** — ``CommWorld.fail_rank`` fails every
   pending handle naming the dead rank with ``RankFailedError`` (pushed
   through the completion callbacks — no new polling), and new posts
   toward it fail at post time.  ``revoke`` / ``revoke_group`` propagate
   a failure to handles that touch only live ranks.
2. **Shrink agreement** — ``CommWorld.shrink`` completes once every
   survivor voted (generation-counted like ``split``), yields one shared
   group, clears the revocation, and tolerates voters dying mid-vote.
3. **Epoch-keyed rebuild** — compiled plans go stale when the epoch
   bumps (``StaleProgramError``); persistent collectives and halo
   exchanges recompile themselves on first post after recovery.
4. **FaultInjector + harness** — deterministic mid-operation death; the
   hypothesis sweep drives failure point × algorithm × mode × notify
   backend through tests/fault_harness.py and asserts hang-free
   surfacing, leak-free teardown, and survivor convergence.
5. **Simulator rank death** — ``Simulator.run(fail=...)`` reports the
   failure cone instead of deadlocking.

The whole module carries the ``faults`` marker: the CI soak job runs
``-m faults`` under both notification backends with
``REPRO_FAULTS_SOAK`` scaling the hypothesis example count.
"""

import os

import numpy as np
import pytest

from repro.core import (Collectives, FaultInjector, HaloExchange,
                        RankFailedError, CommRevokedError, TaskRuntime, tac)
from repro.core import program as program_ir
from repro.core import schedule as schedule_ir
from repro.core.executor import TaskError
from repro.core.resilience import recover, shrink_world
from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_EVENTS)

from fault_harness import ALGORITHMS, run_with_failure

pytestmark = pytest.mark.faults


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


# ---------------------------------------------------------------------------
# 1. transport failure model
# ---------------------------------------------------------------------------
def test_fail_rank_fails_pending_handles_and_new_posts():
    w = tac.CommWorld(4)
    pending = w.irecv(src=3, dst=0, tag="t")
    unrelated = w.irecv(src=1, dst=2, tag="u")
    assert w.epoch == 0 and w.alive == (0, 1, 2, 3)
    w.fail_rank(3)
    assert w.failed == frozenset({3}) and w.alive == (0, 1, 2)
    assert w.epoch == 1
    assert pending.test()              # completed erroneously, not hung
    with pytest.raises(RankFailedError) as ei:
        pending.result
    assert ei.value.rank == 3
    assert not unrelated.test()        # live-pair traffic untouched
    with pytest.raises(RankFailedError):
        w.isend(1.0, src=0, dst=3).result
    with pytest.raises(RankFailedError):
        w.irecv(src=3, dst=1).result
    w.fail_rank(3)                     # idempotent
    assert w.epoch == 1


def test_failure_pushes_through_callbacks_not_polls():
    """The error arrives via the same push path as a success: a callback
    registered before the failure fires exactly once, with the handle in
    its failed state."""
    w = tac.CommWorld(2)
    h = w.irecv(src=1, dst=0, tag=0)
    seen = []
    h.on_complete(lambda hh: seen.append(hh.error))
    w.fail_rank(1)
    assert len(seen) == 1 and isinstance(seen[0], RankFailedError)
    late = []
    h.on_complete(lambda hh: late.append(hh.error))   # post-failure reg
    assert len(late) == 1


def test_revoke_fails_live_pair_traffic():
    w = tac.CommWorld(4)
    h = w.irecv(src=1, dst=2, tag=9)   # both endpoints alive
    w.revoke()
    assert w.revoked
    with pytest.raises(CommRevokedError):
        h.result
    with pytest.raises(CommRevokedError):
        w.isend(1, src=0, dst=1).result
    # CommRevokedError is a RankFailedError: one except clause catches both
    assert issubclass(CommRevokedError, RankFailedError)


def test_revoke_group_is_scoped_to_the_group():
    w = tac.CommWorld(4)
    g = w.group([0, 1, 2])
    sibling = w.group([1, 2, 3])
    hg = g.irecv(src=0, dst=1, tag="a")
    hs = sibling.irecv(src=0, dst=1, tag="a")
    hw = w.irecv(src=0, dst=1, tag="a")
    g.revoke()
    with pytest.raises(CommRevokedError):
        hg.result
    assert not hs.test() and not hw.test()   # sibling + world untouched
    assert not w.revoked


# ---------------------------------------------------------------------------
# 2. shrink agreement
# ---------------------------------------------------------------------------
def test_shrink_agreement_completes_when_all_survivors_vote():
    w = tac.CommWorld(4)
    w.fail_rank(1)
    handles = {r: w.shrink(rank=r) for r in (0, 2)}
    assert not handles[0].test()       # rank 3 has not voted yet
    h3 = w.shrink(rank=3)
    groups = [handles[0].result, handles[2].result, h3.result]
    assert all(g is groups[0] for g in groups)   # ONE shared group
    assert groups[0].ranks == (0, 2, 3)
    # the shrunken group works: group-local p2p round trip
    groups[0].isend(7.5, src=0, dst=2, tag="x")
    assert groups[0].irecv(src=0, dst=2, tag="x").result == 7.5


def test_shrink_clears_revocation():
    w = tac.CommWorld(3)
    w.fail_rank(0)
    w.revoke()
    assert w.revoked
    g = shrink_world(w)
    assert not w.revoked
    assert g.ranks == (1, 2)
    # world traffic between survivors flows again
    w.isend(1, src=1, dst=2, tag="post")
    assert w.irecv(src=1, dst=2, tag="post").result == 1


def test_shrink_dead_caller_and_mid_vote_death():
    w = tac.CommWorld(4)
    w.fail_rank(0)
    with pytest.raises(RankFailedError):
        w.shrink(rank=0).result        # the dead cannot vote
    h1 = w.shrink(rank=1)
    h2 = w.shrink(rank=2)
    assert not h1.test()
    w.fail_rank(3)                     # a yet-to-vote survivor dies...
    g = h1.result                      # ...which completes the agreement
    assert g.ranks == (1, 2) and h2.result is g


def test_shrink_generations_are_independent():
    w = tac.CommWorld(3)
    w.fail_rank(2)
    first = [w.shrink(rank=r) for r in (0, 1)]
    second = [w.shrink(rank=r) for r in (0, 1)]
    ga = first[0].result
    gb = second[0].result
    assert ga is not gb and ga.ranks == gb.ranks == (0, 1)


def test_recover_helper_end_to_end():
    w = tac.CommWorld(5)
    w.fail_rank(2)
    parked = w.irecv(src=0, dst=4, tag="parked")   # live pair, pending
    g = recover(w)
    assert g.ranks == (0, 1, 3, 4)
    with pytest.raises(CommRevokedError):
        parked.result                  # revoke unstuck it
    out = Collectives(g).run_group(
        "allreduce", [{"value": np.float64(r)} for r in range(4)])
    assert all(float(v) == 6.0 for v in out)


# ---------------------------------------------------------------------------
# 3. epoch-keyed rebuild
# ---------------------------------------------------------------------------
def test_stale_program_raises_and_recompiles():
    w = tac.CommWorld(4)
    sched = schedule_ir.build("allreduce", "ring", 4)
    prog = program_ir.compile_schedule(sched, w, head=("t",))
    assert prog.epoch == 0
    w.fail_rank(3)
    with pytest.raises(program_ir.StaleProgramError):
        next(prog.gen(0, "k", value=np.float64(1)))
    fresh = program_ir.compile_schedule(sched, w, head=("t",))
    assert fresh is not prog and fresh.epoch == w.epoch


def test_persistent_collective_rebuilds_after_epoch_bump():
    w = tac.CommWorld(4)
    coll = Collectives(w)
    pers = coll.persistent("allreduce", algorithm="ring")
    vals = [np.float64(r) for r in range(4)]
    out = pers.run_group(vals, key="a")
    assert all(float(v) == 6.0 for v in out)
    before = pers._plan()
    w.epoch += 1                       # any failure/revoke does this
    out = pers.run_group(vals, key="b")    # no StaleProgramError: rebuilt
    assert all(float(v) == 6.0 for v in out)
    assert pers._plan() is not before


def test_halo_exchange_rebuilds_on_shrunken_cart():
    """The full rebuild path: kill, recover, re-shape the survivors as a
    fresh Cartesian grid, run a persistent halo exchange on it."""
    w = tac.CommWorld(5)
    w.fail_rank(4)
    g = recover(w)
    cart = g.cart((2, 2))
    hx = HaloExchange(cart)
    sends = [{d: np.array([float(r)]) for d, _ in hx.neighbors(r)}
             for r in range(4)]
    out = hx.run_group(sends)
    for r in range(4):
        for d, q in hx.neighbors(r):
            np.testing.assert_array_equal(out[r][d], [float(q)])
    with pytest.raises(ValueError, match="needs"):
        g.cart((2, 3))                 # wrong survivor count


# ---------------------------------------------------------------------------
# 4. FaultInjector + harness
# ---------------------------------------------------------------------------
def test_fault_injector_immediate_and_armed():
    w = tac.CommWorld(4)
    inj = FaultInjector(w)
    inj.kill(1)
    assert w.failed == frozenset({1}) and inj.killed == [1]
    inj.arm(2, after_ops=2)
    assert inj.armed
    w.isend(1, src=2, dst=0, tag=0)    # 1st post: still alive
    assert 2 not in w.failed
    w.irecv(src=0, dst=2, tag=1)       # 2nd post: trap fires
    assert 2 in w.failed and not inj.armed
    with pytest.raises(ValueError, match="out of range"):
        inj.arm(9)
    with pytest.raises(ValueError, match="after_ops"):
        inj.arm(0, after_ops=0)


def test_armed_injection_counts_only_the_victim():
    w = tac.CommWorld(3)
    inj = FaultInjector(w)
    inj.arm(1, after_ops=1)
    w.isend(1, src=0, dst=2, tag=0)    # other ranks' posts don't count
    w.irecv(src=0, dst=2, tag=0)
    assert not w.failed
    inj.disarm()
    assert not inj.armed
    w.isend(1, src=1, dst=2, tag=1)    # disarmed: victim survives
    assert not w.failed


@pytest.mark.parametrize("mode", ["blocking", "event"])
@pytest.mark.parametrize("notify", ["polling", "continuation"])
def test_injected_death_surfaces_and_survivors_recover(mode, notify):
    out = run_with_failure(n_ranks=4, victim=2, after_ops=1, mode=mode,
                           notify=notify)
    assert out.survivors.ranks == (0, 1, 3)
    assert 2 not in out.ok_ranks


def test_late_injection_lets_finished_ranks_through():
    """Doubling allreduce, death at the victim's round-2 post: the pair
    that no longer needs the victim completes; the victim's round-2
    partner fails.  The failure cone is minimal, not all-or-nothing."""
    out = run_with_failure(n_ranks=4, victim=0, after_ops=3,
                           algorithm="doubling", mode="event")
    assert 0 not in out.ok_ranks
    assert out.ok_ranks or out.failed_ranks   # shape asserted in harness


def test_runtime_close_leak_free_after_failure():
    """Ten injected failures back to back: every runtime closes with
    zero registered polling services (asserted inside the harness)."""
    for seed in range(5):
        run_with_failure(n_ranks=4, victim=seed % 4, after_ops=1 + seed,
                         mode=("event", "blocking")[seed % 2],
                         recover_after=False, seed=seed)


def test_taskwait_raises_instead_of_hanging_blocking_mode():
    """A blocking-mode collective whose peer dies must surface out of
    taskwait as TaskError (the machine revokes; paused tasks resume with
    the error), never hang."""
    tac.init(tac.TASK_MULTIPLE)
    w = tac.CommWorld(3)
    coll = Collectives(w)
    inj = FaultInjector(w)
    inj.arm(1, after_ops=1)
    with TaskRuntime(num_workers=2) as rt:
        for r in range(3):
            def body(r=r):
                coll.allreduce(np.float64(r), rank=r, mode="blocking",
                               key="tw")
            rt.submit(body, name=f"b[{r}]")
        with pytest.raises(TaskError):
            rt.taskwait()


# The hypothesis sweep over failure point × algorithm × mode × backend
# lives in tests/test_resilience_properties.py (module-level importorskip
# must not take these unit tests down with it when hypothesis is absent).


# -- deterministic mini-sweep: runs even without hypothesis ------------------
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("after_ops", [1, 2, 4])
def test_failure_point_sweep_deterministic(algorithm, after_ops):
    """A fixed grid over the same space the hypothesis suite samples:
    any death point in any algorithm recovers (harness asserts the
    hang-free / leak-free / convergence contract)."""
    out = run_with_failure(n_ranks=4, victim=(after_ops + 1) % 4,
                           after_ops=after_ops, algorithm=algorithm,
                           mode=("event", "blocking")[after_ops % 2],
                           seed=after_ops)
    assert out.survivors.size == 3


# ---------------------------------------------------------------------------
# 5. simulator rank death
# ---------------------------------------------------------------------------
def _chain(rank, ids, dep_lat=0.0):
    return [SimTask(i, rank, 1.0,
                    start_deps=[(i - 1, dep_lat)] if j else [])
            for j, i in enumerate(ids)]


def test_sim_rank_death_reports_failure_cone():
    # rank 0: 0 -> 1 -> 2 (chain); rank 1's task 3 event-depends on 1
    tasks = _chain(0, [0, 1, 2])
    tasks.append(SimTask(3, 1, 1.0, kind=COMM_EVENTS,
                         event_deps=[(1, 0.1)]))
    clean = Simulator(2, 1).run(tasks)
    assert not clean.failed
    res = Simulator(2, 1).run(tasks, fail=(0, 1.5))
    # task 0 finished before the death; 1, 2 die with the rank; 3 never
    # sees task 1's event -> the cone is {1, 2, 3}
    assert res.failed == {1, 2, 3}
    assert 0 in res.done_times and 1 not in res.done_times
    assert res.makespan <= clean.makespan


def test_sim_death_after_delivery_spares_consumers():
    tasks = _chain(0, [0])
    tasks.append(SimTask(1, 1, 1.0, kind=COMM_EVENTS,
                         event_deps=[(0, 0.5)]))
    res = Simulator(2, 1).run(tasks, fail=(0, 1.2))
    # rank 0 died AFTER its body completed at t=1: the in-flight message
    # still arrives and rank 1 finishes
    assert res.failed == set()
    assert 1 in res.done_times


def test_sim_fail_validation_and_determinism():
    tasks = _chain(0, [0, 1])
    with pytest.raises(ValueError):
        Simulator(1, 1).run(tasks, fail=(5, 1.0))
    a = Simulator(1, 1).run(tasks, fail=(0, 1.5)).failed
    b = Simulator(1, 1).run(tasks, fail=(0, 1.5)).failed
    assert a == b == {1}
