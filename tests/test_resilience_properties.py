"""Hypothesis property sweep for failure recovery (tentpole suite).

Samples the full cross-product the deterministic tests grid-spot-check:
death point (operation count) × collective algorithm (ring / doubling /
bruck / tree / hierarchical) × interoperability mode (blocking / event)
× notification backend (polling / continuation), asserting through
tests/fault_harness.py that every combination

* surfaces the injected death as ``RankFailedError`` without hanging the
  taskwait,
* tears the runtime down leak-free (zero registered polling services),
* completes the shrink agreement on exactly the survivors, and
* converges the survivors' post-recovery allreduce to the numpy
  reference at the shrunken size.

``REPRO_FAULTS_SOAK=<n>`` raises the example count (the CI fault-soak
job sets it); the default stays small so tier-1 wall time is bounded.
"""

import os

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property sweep needs hypothesis (pip install -r "
           "requirements-dev.txt)")

from hypothesis import given, settings, HealthCheck
import hypothesis.strategies as st

from fault_harness import ALGORITHMS, run_with_failure

pytestmark = pytest.mark.faults

_SOAK = int(os.environ.get("REPRO_FAULTS_SOAK", "0"))
_SETTINGS = dict(deadline=None, max_examples=_SOAK or 10,
                 suppress_health_check=[HealthCheck.too_slow])


@settings(**_SETTINGS)
@given(n_ranks=st.integers(min_value=2, max_value=6),
       victim=st.integers(min_value=0, max_value=5),
       after_ops=st.integers(min_value=1, max_value=6),
       algorithm=st.sampled_from(ALGORITHMS + ("hierarchical",)),
       mode=st.sampled_from(["blocking", "event"]),
       notify=st.sampled_from(["polling", "continuation"]),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_any_failure_point_recovers(n_ranks, victim, after_ops,
                                             algorithm, mode, notify, seed):
    """For ANY death point in ANY algorithm under EITHER interop mode and
    EITHER notification backend: the taskwait returns, teardown is
    leak-free, the shrink agreement produces the survivor set, and the
    survivors' post-recovery allreduce matches the numpy reference —
    all asserted inside the harness."""
    victim %= n_ranks
    hierarchical = None
    if algorithm == "hierarchical":
        hierarchical = 2 if n_ranks % 2 == 0 else 1
        algorithm = "ring"
    out = run_with_failure(n_ranks=n_ranks, victim=victim,
                           after_ops=after_ops, algorithm=algorithm,
                           hierarchical=hierarchical, mode=mode,
                           notify=notify, seed=seed)
    assert out.survivors.size == n_ranks - 1
    assert victim not in out.survivors.ranks
