"""End-to-end system behaviour tests.

* training run: loss decreases on the learnable synthetic stream;
* checkpoint/restart determinism: resuming reproduces the uninterrupted
  run bit-exactly (fault-tolerance contract);
* async checkpointing: training is not blocked by the save; the external
  events gate `wait_all`;
* prefetcher: deterministic batches, restart-safe cursor;
* straggler mitigation: a stuck idempotent task is speculatively re-run.
"""

import os
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro import configs, optim, checkpoint as ckpt
from repro.data import SyntheticLMData, Prefetcher
from repro.models import inputs
from repro.runtime import steps
from repro.runtime.sharding import ShardingPolicy
from repro.launch.mesh import local_mesh
from repro.core import TaskRuntime

pytestmark = pytest.mark.system


def _tiny_cfg():
    return configs.smoke("granite_3_2b").scaled(
        dtype="float32", n_layers=2, d_model=64, d_ff=128, vocab=128)


def _run_steps(state, step_fn, data, start, n):
    losses = []
    for s in range(start, start + n):
        batch = data.batch_at(s)
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


@pytest.fixture(scope="module")
def trained():
    cfg = _tiny_cfg()
    opt_cfg = optim.OptimConfig(peak_lr=3e-3, warmup_steps=10,
                                total_steps=60)
    mesh = local_mesh(model=1)
    data = SyntheticLMData(cfg, batch=8, seq=32, seed=1)
    state = steps.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None)
    with mesh:
        step_fn, _ = steps.build_train_step(
            cfg, mesh, policy, opt_cfg,
            abstract_batch=jax.eval_shape(lambda: data.batch_at(0)),
            donate=False)
        state, losses = _run_steps(state, step_fn, data, 0, 60)
    return cfg, opt_cfg, mesh, data, policy, step_fn, state, losses


def test_training_reduces_loss(trained):
    *_, losses = trained
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_is_deterministic(trained):
    cfg, opt_cfg, mesh, data, policy, step_fn, _, _ = trained
    state = steps.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    with mesh:
        # uninterrupted: 12 steps
        s_ref, _ = _run_steps(state, step_fn, data, 0, 12)
        # interrupted at 6 + restart from checkpoint
        s_a, _ = _run_steps(state, step_fn, data, 0, 6)
        d = tempfile.mkdtemp()
        ckpt.save_checkpoint(d, s_a, step=6)
        restored, step = ckpt.restore_checkpoint(
            d, jax.eval_shape(lambda: s_a))
        assert step == 6
        s_b, _ = _run_steps(restored, step_fn, data, 6, 6)
    for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(s_ref)),
                    jax.tree_util.tree_leaves(jax.device_get(s_b))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_does_not_block(trained):
    cfg, opt_cfg, *_ , state, _ = trained
    d = tempfile.mkdtemp()
    saver = ckpt.AsyncCheckpointer(d, keep=2)
    t0 = time.monotonic()
    handles = [saver.save(state, s) for s in (1, 2, 3)]
    submit_time = time.monotonic() - t0
    saver.wait_all()
    assert all(h.test() for h in handles)
    assert ckpt.latest_step(d) == 3
    # keep=2: oldest checkpoint garbage-collected
    assert sorted(int(n.split("_")[1]) for n in os.listdir(d)
                  if n.startswith("step_")) == [2, 3]
    saver.close()
    assert submit_time < 5.0   # snapshots only; writes ran async


def test_prefetcher_deterministic_and_restartable():
    cfg = _tiny_cfg()
    data = SyntheticLMData(cfg, batch=4, seq=16, seed=7)
    pf = Prefetcher(data, start_step=0)
    got = [pf.get(s)["tokens"] for s in range(5)]
    pf.close()
    # restart mid-stream: same batches
    pf2 = Prefetcher(data, start_step=3)
    np.testing.assert_array_equal(pf2.get(3)["tokens"], got[3])
    np.testing.assert_array_equal(pf2.get(4)["tokens"], got[4])
    pf2.close()


def test_straggler_speculative_reexecution():
    rt = TaskRuntime(num_workers=2, speculative_timeout=0.15)
    rt.start()
    release = threading.Event()
    runs = []

    def sometimes_stuck():
        runs.append(threading.get_ident())
        if len(runs) == 1:
            release.wait(timeout=10.0)   # first copy straggles
        return 42

    t = rt.submit(sometimes_stuck, idempotent=True)
    deadline = time.time() + 5.0
    while rt.stats.get("speculative_reruns", 0) == 0 \
            and time.time() < deadline:
        time.sleep(0.01)
    assert rt.stats.get("speculative_reruns", 0) >= 1
    rt.taskwait()                         # completes via the speculative copy
    assert t.result == 42
    release.set()
    rt.close()
