"""Observability layer (repro.obs): tracing, metrics, overlap accounting.

Covers the tracer itself (bounded per-thread rings, thread safety, the
NullTracer disabled path), the exported Chrome-trace document (schema
validation, file round-trip), the metrics registry (striped counters with
exact totals, gauges, histograms), the overlap-fraction oracle — the
discrete-event simulator replays a known scenario whose overlap is exact
and the host tracer reproduces the same number within tolerance on the
same structure — and the deprecation shims left behind by the
``serving.metrics`` → ``repro.obs.metrics`` move.
"""

import json
import threading
import time
import warnings

import pytest

from repro import obs
from repro.core import TaskRuntime, tac
from repro.core import simulate
from repro.core.simulate import (COMM_EVENTS, COMM_PAUSED, COMPUTE,
                                 SimTask, Simulator)
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NullTracer, Tracer, overlap_fraction,
                       per_rank_overlap, straggler_scores, summarize)
from repro.obs import trace as trace_mod


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing disabled (the default)."""
    prev = trace_mod.set_tracer(None)
    yield
    trace_mod.set_tracer(prev if not isinstance(prev, NullTracer) else None)


# ---------------------------------------------------------------------------
# the tracer
# ---------------------------------------------------------------------------
def test_tracing_disabled_by_default():
    assert trace_mod.TRACING is False
    assert isinstance(trace_mod.get_tracer(), NullTracer)
    # NullTracer methods are no-ops with an empty event list
    nt = NullTracer()
    nt.span("task", "run", 0.0, 1.0, rank=0)
    nt.instant("task", "submit")
    nt.counter("x", 1.0)
    assert nt.events() == []


def test_tracing_context_installs_and_restores():
    assert not trace_mod.TRACING
    with obs.tracing() as tr:
        assert trace_mod.TRACING
        assert trace_mod.get_tracer() is tr
        tr.instant("task", "submit", task="t")
        assert len(tr.events()) == 1
    assert not trace_mod.TRACING


def test_ring_buffer_is_bounded_and_keeps_newest():
    tr = Tracer(capacity=8)
    for i in range(100):
        tr.instant("task", "submit", t=float(i), seq=i)
    evs = tr.events()
    assert len(evs) == 8
    assert [e["args"]["seq"] for e in evs] == list(range(92, 100))


def test_tracer_rejects_zero_capacity():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_tracer_thread_safety_distinct_tids():
    tr = Tracer()
    n_threads, per = 8, 200

    def emit(i):
        for k in range(per):
            tr.instant("task", "submit", t=float(i * per + k), worker=i)

    threads = [threading.Thread(target=emit, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == n_threads * per
    # one ring (hence one tid) per emitting thread; events are merged
    # sorted by timestamp
    assert len({e["tid"] for e in evs}) == n_threads
    assert [e["ts"] for e in evs] == sorted(e["ts"] for e in evs)
    tr.clear()
    assert tr.events() == []


def test_span_event_rank_attribution():
    ev = trace_mod.span_event("task", "run", 10.0, 5.0, rank=3, task="t")
    assert ev["pid"] == 3 and ev["args"]["rank"] == 3
    assert ev["ts"] == 10.0 and ev["dur"] == 5.0
    un = trace_mod.span_event("task", "run", 0.0, 1.0)
    assert un["pid"] == 0 and "rank" not in un["args"]


# ---------------------------------------------------------------------------
# export + validation
# ---------------------------------------------------------------------------
def test_export_roundtrip_and_validation(tmp_path):
    tr = Tracer()
    t0 = time.monotonic()
    tr.span("task", "run", t0, t0 + 0.01, rank=1, task="a",
            label="compute")
    tr.span("handle", "inflight", t0, t0 + 0.02, rank=1, kind="Event")
    tr.instant("continuation", "dispatch")
    tr.counter("queue", 3.0)
    path = tmp_path / "out.json"
    doc = obs.export_trace(str(path), tracer=tr, extra={"leg": "test"})
    loaded = json.loads(path.read_text())
    assert loaded["otherData"]["leg"] == "test"
    assert obs.validate_trace(loaded) == []
    assert obs.validate_trace(doc) == []
    obs.assert_valid_trace(loaded)


def test_validation_catches_schema_violations():
    bad = [
        {"ph": "X", "cat": "task", "name": "nope", "ts": 0.0, "dur": 1.0,
         "pid": 0, "tid": 0, "args": {}},                 # unknown span name
        {"ph": "i", "s": "t", "cat": "bogus", "name": "submit", "ts": 0.0,
         "pid": 0, "tid": 0, "args": {}},                 # unknown category
        {"ph": "X", "cat": "task", "name": "run", "ts": 0.0, "dur": -1.0,
         "pid": 0, "tid": 0, "args": {}},                 # negative duration
        {"ph": "Z", "name": "x", "ts": 0.0, "pid": 0, "tid": 0},  # bad ph
    ]
    problems = obs.validate_trace(bad)
    assert len(problems) == 4
    with pytest.raises(ValueError):
        obs.assert_valid_trace(bad)
    assert obs.validate_trace({"nope": 1}) \
        == ["document has no 'traceEvents' list"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_exact_totals_across_threads():
    c = Counter("c")
    n_threads, per = 8, 5000

    def worker():
        for _ in range(per):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # striped cells: no lock on inc, yet the total is EXACT
    assert c.value == n_threads * per
    c.reset()
    assert c.value == 0


def test_gauge_levels_and_high_water():
    g = Gauge("g")
    g.inc(); g.inc(); g.inc()
    assert g.value == 3.0 and g.high_water == 3.0
    g.dec(2.0)
    assert g.value == 1.0 and g.high_water == 3.0
    g.set(10.0)
    assert g.high_water == 10.0
    g.reset()
    assert g.value == 0.0 and g.high_water == 0.0


def test_histogram_summary():
    h = Histogram("h")
    for x in (1e-6, 2e-6, 1e-3, 0.5):
        h.observe(x)
    s = h.summary()
    assert s["count"] == 4.0
    assert s["min"] == 1e-6 and s["max"] == 0.5
    assert abs(h.mean - (1e-6 + 2e-6 + 1e-3 + 0.5) / 4) < 1e-12


def test_registry_shares_by_name_and_type_checks():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    with pytest.raises(TypeError):
        reg.gauge("a")
    reg.gauge("b").set(2.0)
    reg.histogram("c").observe(0.1)
    snap = reg.as_dict()
    assert snap["a"]["value"] == 0.0
    assert snap["b"] == {"value": 2.0, "high_water": 2.0}
    assert snap["c"]["count"] == 1.0
    reg.reset()
    assert reg.as_dict()["b"]["value"] == 0.0


# ---------------------------------------------------------------------------
# the overlap-fraction oracle: simulator exact, host within tolerance
# ---------------------------------------------------------------------------
def _oracle_sim_tasks():
    """Rank 0: a 0.2 s compute task beside a comm window closing at 0.5 s.

    With two workers both start at t=0: inflight = [0, 0.5], compute =
    [0, 0.2] — overlap fraction exactly 0.4.
    """
    a = SimTask(0, 0, 0.2, kind=COMPUTE, name="compute")
    b = SimTask(1, 0, 0.0, kind=COMM_EVENTS, event_deps=[(0, 0.3)],
                name="comm")
    return [a, b]


def test_sim_overlap_oracle_exact():
    tasks = _oracle_sim_tasks()
    Simulator(1, 2).run(tasks)
    evs = simulate.trace_events(tasks)
    assert obs.validate_trace(evs) == []
    assert overlap_fraction(evs) == pytest.approx(0.4)
    assert per_rank_overlap(evs) == {0: pytest.approx(0.4)}


def test_host_tracer_overlap_matches_sim_within_tolerance():
    """The same structure on the real runtime: one 0.2 s compute task
    beside an EventHandle posted at ~0 and completed at ~0.5 s.  The
    host number must land near the simulator's exact 0.4."""
    tac.init(tac.TASK_MULTIPLE)
    with obs.tracing() as tr:
        with TaskRuntime(num_workers=2) as rt:
            t0 = time.monotonic()
            box = {}

            def comm():
                box["h"] = tac.EventHandle()

            def compute():
                time.sleep(0.2)

            rt.submit(comm, name="comm", label="comm", rank=0)
            rt.submit(compute, name="compute", label="compute", rank=0)
            rt.taskwait()
            time.sleep(max(0.0, t0 + 0.5 - time.monotonic()))
            box["h"].complete(None)   # closes the inflight span at ~0.5 s
        evs = tr.events()
    assert obs.validate_trace(evs) == []
    host = overlap_fraction(evs, rank=0)
    assert host == pytest.approx(0.4, abs=0.15)


def test_sim_segmented_ring_overlaps_more_than_unsegmented():
    """The segmented schedule's pipelining claim, read off the replayed
    timeline: with combines costing γ > 0, transport of later segments
    hides under combines of earlier ones."""
    from repro.core.schedule import build

    def run(segments):
        sched = build("allreduce", "ring", 4, segments=segments)
        tasks = simulate.schedule_tasks(sched, size=1.0, alpha=1e-3,
                                        beta=1e-2, gamma=1e-2)
        Simulator(4, 1).run(tasks)
        evs = simulate.trace_events(tasks)
        assert obs.validate_trace(evs) == []
        return overlap_fraction(evs)

    assert run(4) > run(1)


def test_sim_paused_task_emits_pause_span():
    a = SimTask(0, 0, 0.1, kind=COMPUTE, name="src")
    b = SimTask(1, 0, 0.05, kind=COMM_PAUSED, event_deps=[(0, 0.2)],
                name="wait")
    Simulator(1, 2, resume_overhead=0.01).run([a, b])
    evs = simulate.trace_events([a, b])
    assert obs.validate_trace(evs) == []
    pauses = [e for e in evs if e["ph"] == "X" and e["cat"] == "task"
              and e["name"] == "pause"]
    assert len(pauses) == 1
    assert pauses[0]["args"]["source"] == "sim"


# ---------------------------------------------------------------------------
# straggler accounting: deterministic injected straggler
# ---------------------------------------------------------------------------
def _straggler_tasks(slow_rank=0, factor=3.0, n_ranks=4, per_rank=2):
    tasks = []
    for r in range(n_ranks):
        for k in range(per_rank):
            dur = 0.5 * (factor if r == slow_rank else 1.0)
            tasks.append(SimTask(len(tasks), r, dur, kind=COMPUTE,
                                 name=f"w[{r},{k}]"))
    return tasks


def test_straggler_scores_flag_injected_straggler():
    tasks = _straggler_tasks()
    Simulator(4, 1).run(tasks)
    evs = simulate.trace_events(tasks)
    scores = straggler_scores(evs)
    assert set(scores) == {0, 1, 2, 3}
    assert scores[0]["score"] == pytest.approx(3.0)
    for r in (1, 2, 3):
        assert scores[r]["score"] == pytest.approx(1.0)
        assert scores[r]["tasks"] == 2.0
    s = summarize(evs)
    assert s["ranks"] == [0, 1, 2, 3]
    assert s["straggler_scores"][0]["score"] == pytest.approx(3.0)


def test_straggler_table_renders_injected_straggler():
    from benchmarks.report import straggler_table

    tasks = _straggler_tasks()
    Simulator(4, 1).run(tasks)
    table = straggler_table(simulate.trace_events(tasks))
    lines = table.splitlines()
    assert lines[0].startswith("| rank ")
    assert len(lines) == 2 + 4            # header + divider + 4 ranks
    assert "| 0 |" in lines[2] and "3.00" in lines[2]


# ---------------------------------------------------------------------------
# host instrumentation end to end: pause spans + deferred release
# ---------------------------------------------------------------------------
def test_host_blocking_wait_emits_pause_span():
    tac.init(tac.TASK_MULTIPLE)
    with obs.tracing() as tr:
        with TaskRuntime(num_workers=2) as rt:
            h = tac.EventHandle()

            def waiter():
                tac.wait(h)       # §4.1: pauses the task, not the core

            rt.submit(waiter, name="waiter", label="comm", rank=0)
            time.sleep(0.05)
            h.complete(42)
            rt.taskwait()
        evs = tr.events()
    assert obs.validate_trace(evs) == []
    counts = summarize(evs)["counts"]
    assert counts.get("task/pause[X]", 0) >= 1
    assert counts.get("handle/inflight[X]", 0) >= 1
    assert counts.get("task/run[X]", 0) >= 1


def test_host_iwait_emits_bind_and_dep_release():
    tac.init(tac.TASK_MULTIPLE)
    with obs.tracing() as tr:
        with TaskRuntime(num_workers=2) as rt:
            h = tac.EventHandle()

            def binder():
                tac.iwait(h)      # §4.3: release deferred to completion

            rt.submit(binder, name="binder", label="comm", rank=1)
            time.sleep(0.05)
            h.complete(7)
            rt.taskwait()
        evs = tr.events()
    counts = summarize(evs)["counts"]
    assert counts.get("handle/bind[i]", 0) == 1
    assert counts.get("handle/dep-release[i]", 0) == 1
    assert counts.get("continuation/dispatch[i]", 0) >= 1


# ---------------------------------------------------------------------------
# deprecation shims: serving.metrics -> repro.obs.metrics
# ---------------------------------------------------------------------------
def test_serving_metrics_shim_warns():
    import repro.serving.metrics as sm

    for name in ("percentile", "TokenRecord", "MetricSink"):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            moved = getattr(sm, name)
        assert moved is getattr(obs, name)
        assert len(caught) == 1
        assert issubclass(caught[0].category, DeprecationWarning)
        assert "repro.obs" in str(caught[0].message)
    with pytest.raises(AttributeError):
        sm.does_not_exist


def test_serving_package_reexport_is_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.serving import MetricSink, TokenRecord, percentile
    assert percentile is obs.percentile
    assert TokenRecord is obs.TokenRecord
    assert MetricSink is obs.MetricSink


def test_percentile_and_sink_semantics_preserved():
    assert obs.percentile([5.0, 1.0, 3.0], 50) == 3.0
    with pytest.raises(ValueError):
        obs.percentile([], 99)
    sink = obs.MetricSink()
    rec = obs.TokenRecord(rid=1, step=0, t_submit=1.0, t_emit=1.5)
    sink.emit(rec)
    assert sink.records == [rec]
    assert rec.latency_s == pytest.approx(0.5)
