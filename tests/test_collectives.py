"""Task-aware collectives (core/collectives.py): correctness across sizes,
dtypes, rank counts, algorithms and modes; CommWorld message semantics;
executor block modes under collective load; simulator collective nodes."""

import os
import sys
import threading

import numpy as np
import pytest

from repro.core import Collectives, TaskRuntime, tac
from repro.core.collectives import (CollectiveHandle, n_rounds,
                                    ALGORITHMS, MODES)
from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_HELD,
                                 COMM_PAUSED, COMM_EVENTS)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


def _world(n):
    w = tac.CommWorld(n)
    return w, Collectives(w)


# ---------------------------------------------------------------------------
# correctness vs numpy references (group driver: deterministic, no runtime)
# ---------------------------------------------------------------------------
RANKS = (1, 2, 3, 4, 5, 7, 8)   # includes non-powers-of-two


@pytest.mark.parametrize("alg", ALGORITHMS)
@pytest.mark.parametrize("n", RANKS)
def test_allreduce_matches_reference(n, alg):
    _, coll = _world(n)
    rng = np.random.default_rng(n)
    vals = [rng.standard_normal(17) for _ in range(n)]
    out = coll.run_group("allreduce", [{"value": v} for v in vals],
                         op="sum", algorithm=alg)
    ref = np.sum(np.stack(vals), axis=0)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref, rtol=1e-12, atol=1e-12)
    # all ranks must agree bitwise (deterministic combine order)
    for r in range(1, n):
        np.testing.assert_array_equal(out[0], out[r])


@pytest.mark.parametrize("dtype,op,ref_fn", [
    (np.float64, "sum", lambda a: np.sum(a, axis=0)),
    (np.float32, "max", lambda a: np.max(a, axis=0)),
    (np.int32, "sum", lambda a: np.sum(a, axis=0, dtype=np.int32)),
    (np.int64, "min", lambda a: np.min(a, axis=0)),
])
@pytest.mark.parametrize("alg", ALGORITHMS)
def test_allreduce_dtypes_and_ops(alg, dtype, op, ref_fn):
    n = 5
    _, coll = _world(n)
    rng = np.random.default_rng(0)
    vals = [(rng.standard_normal(9) * 10).astype(dtype) for _ in range(n)]
    out = coll.run_group("allreduce", [{"value": v} for v in vals],
                         op=op, algorithm=alg)
    ref = ref_fn(np.stack(vals))
    for r in range(n):
        assert out[r].dtype == dtype
        np.testing.assert_allclose(out[r], ref, rtol=1e-6)


@pytest.mark.parametrize("size", (1, 2, 13, 64, 100))
@pytest.mark.parametrize("alg", ALGORITHMS)
def test_reduce_scatter_chunks(alg, size):
    """Chunking matches np.array_split even when size % n != 0."""
    n = 3
    _, coll = _world(n)
    rng = np.random.default_rng(size)
    vals = [rng.standard_normal(size) for _ in range(n)]
    out = coll.run_group("reduce_scatter", [{"value": v} for v in vals],
                         op="sum", algorithm=alg)
    ref_chunks = np.array_split(np.sum(np.stack(vals), axis=0), n)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref_chunks[r], rtol=1e-12)


@pytest.mark.parametrize("alg", ALGORITHMS)
@pytest.mark.parametrize("n", RANKS)
def test_allgather_any_payload(n, alg):
    _, coll = _world(n)
    vals = [{"rank": r, "data": np.full(3, r)} for r in range(n)]
    out = coll.run_group("allgather", [{"value": v} for v in vals],
                         algorithm=alg)
    for r in range(n):
        assert [d["rank"] for d in out[r]] == list(range(n))
        for i in range(n):
            np.testing.assert_array_equal(out[r][i]["data"], np.full(3, i))


@pytest.mark.parametrize("alg", ALGORITHMS)
@pytest.mark.parametrize("n", RANKS)
def test_alltoall(n, alg):
    _, coll = _world(n)
    blocks = [[np.array([100 * s + d]) for d in range(n)] for s in range(n)]
    out = coll.run_group("alltoall", [{"blocks": blocks[s]}
                                      for s in range(n)], algorithm=alg)
    for d in range(n):
        for s in range(n):
            assert out[d][s][0] == 100 * s + d


@pytest.mark.parametrize("alg", ALGORITHMS)
@pytest.mark.parametrize("root", (0, 2, 4))
def test_bcast_and_reduce_roots(alg, root):
    n = 5
    _, coll = _world(n)
    payload = np.arange(6.0)
    out = coll.run_group(
        "bcast", [{"value": payload if r == root else None}
                  for r in range(n)], root=root, algorithm=alg)
    for r in range(n):
        np.testing.assert_array_equal(out[r], payload)

    vals = [np.full(4, float(r + 1)) for r in range(n)]
    red = coll.run_group("reduce", [{"value": v} for v in vals],
                         op="prod", root=root, algorithm=alg)
    np.testing.assert_allclose(red[root], np.full(4, 120.0))
    assert all(red[r] is None for r in range(n) if r != root)


@pytest.mark.parametrize("alg", ALGORITHMS)
def test_barrier_all_enter_before_any_exit(alg):
    """Threaded barrier: no rank may exit before the last has entered."""
    n = 4
    _, coll = _world(n)
    entered = []
    exited = []
    lock = threading.Lock()

    def body(r):
        with lock:
            entered.append(r)
        coll.barrier(rank=r, algorithm=alg, key="b")
        with lock:
            assert len(entered) == n, "rank exited before all entered"
            exited.append(r)

    threads = [threading.Thread(target=body, args=(r,)) for r in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert sorted(exited) == list(range(n))


def test_argument_validation():
    _, coll = _world(3)
    with pytest.raises(ValueError, match="rank"):
        coll.barrier(rank=3)
    with pytest.raises(ValueError, match="blocks"):
        coll.alltoall([1, 2], rank=0)
    with pytest.raises(ValueError, match="algorithm"):
        coll.allreduce(np.zeros(2), rank=0, algorithm="telepathy")
    with pytest.raises(ValueError, match="mode"):
        coll.allreduce(np.zeros(2), rank=0, mode="psychic")
    with pytest.raises(ValueError, match="op"):
        coll.allreduce(np.zeros(2), rank=0, op="xor!")
    with pytest.raises(ValueError, match="unknown collective"):
        coll.run_group("gossip", [{} for _ in range(3)])
    # run_group must reject unknown kwargs (mode is not applicable there)
    with pytest.raises(ValueError, match="mode"):
        coll.run_group("allreduce", [{"value": 1} for _ in range(3)],
                       mode="event")
    with pytest.raises(ValueError, match="missing"):
        coll.run_group("allreduce", [{} for _ in range(3)])


def test_rejected_call_does_not_desync_tag_sequence():
    """A call that fails validation must not consume the rank's implicit
    tag sequence — peers would otherwise mismatch forever."""
    _, coll = _world(2)
    with pytest.raises(ValueError):
        coll.allreduce(np.zeros(2), rank=0, mode="psychic")
    # keyless collective still matches across ranks after the failure
    out = coll.run_group("allreduce", [{"value": np.float64(r)}
                                       for r in range(2)], op="sum")
    assert all(float(v) == 1.0 for v in out)


def test_schedule_error_surfaces_and_releases():
    """A raising schedule (mismatched payload shapes) must neither kill
    the polling service nor hang taskwait: the failing rank's handle
    carries the error, its dependency is released, peers' results are
    unaffected where their rounds completed."""
    n = 2
    _, coll = _world(n)
    handles = {}

    def capture(r):
        def body():
            # mismatched shapes: op(acc, other) raises inside the schedule
            h = coll.allreduce(np.zeros(3 if r == 0 else 4), rank=r,
                               op="sum", algorithm="doubling", mode="event",
                               key="bad")
            handles[r] = h
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(n):
            rt.submit(capture(r))
        rt.taskwait()                          # must not hang
    failed = [r for r in range(n) if handles[r].error is not None]
    assert failed, "at least one rank's schedule must have failed"
    with pytest.raises(ValueError):
        _ = handles[failed[0]].result


def test_group_driver_error_propagates():
    _, coll = _world(2)
    with pytest.raises(ValueError):
        coll.run_group("allreduce",
                       [{"value": np.zeros(3)}, {"value": np.zeros(4)}],
                       op="sum", algorithm="doubling")


def test_n_rounds_model():
    assert n_rounds("allreduce", "ring", 8) == 14          # 2*(n-1)
    assert n_rounds("allreduce", "doubling", 8) == 3       # log2
    # non-pow2 reductions: fold + butterfly over 2^floor(log2 n) + unfold
    assert n_rounds("allreduce", "doubling", 6) == 4       # 1 + 2 + 1
    assert n_rounds("allreduce", "doubling", 3) == 3       # 1 + 1 + 1
    assert n_rounds("barrier", "doubling", 5) == 3
    assert n_rounds("allgather", "ring", 5) == 4
    assert n_rounds("bcast", "doubling", 1) == 0


# ---------------------------------------------------------------------------
# the two modes on the task runtime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("alg", ALGORITHMS)
def test_blocking_mode_pauses_with_few_workers(alg):
    """5 ranks, 2 workers: blocking collectives must pause/resume, not
    deadlock the worker pool (paper §6.1 applied to collectives)."""
    n = 5
    _, coll = _world(n)
    vals = [np.arange(7.0) * (r + 1) for r in range(n)]
    ref = np.sum(np.stack(vals), axis=0)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(vals[r], rank=r, op="sum",
                                        algorithm=alg, mode="blocking",
                                        key="ar")
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    for r in range(n):
        np.testing.assert_allclose(results[r], ref)
    assert rt.stats.get("task_blocks", 0) > 0
    assert rt.stats["task_blocks"] == rt.stats["task_resumes"]


@pytest.mark.parametrize("alg", ALGORITHMS)
def test_event_mode_defers_release_without_pausing(alg):
    """Event-bound collectives: comm tasks never pause; consumers (gated
    by dependencies) observe the completed result (paper §6.2)."""
    n = 4
    _, coll = _world(n)
    vals = [np.full(5, float(r + 1)) for r in range(n)]
    ref = np.sum(np.stack(vals), axis=0)
    handles, got = {}, {}

    def comm(r):
        def body():
            h = coll.allreduce(vals[r], rank=r, op="sum", algorithm=alg,
                               mode="event", key="e")
            assert isinstance(h, CollectiveHandle)
            handles[r] = h
        return body

    def consume(r):
        def body():
            got[r] = handles[r].result
        return body

    with TaskRuntime(num_workers=2) as rt:
        for r in range(n):
            rt.submit(comm(r), out=[("res", r)])
            rt.submit(consume(r), in_=[("res", r)])
        rt.taskwait()
    for r in range(n):
        np.testing.assert_allclose(got[r], ref)
    assert rt.stats.get("task_blocks", 0) == 0


def test_event_mode_outside_task_completes_inline():
    """PMPI path: no task/event counter to bind — handle completes inline
    (driven by a helper thread for the peer rank)."""
    n = 2
    _, coll = _world(n)
    peer = threading.Thread(
        target=lambda: coll.allreduce(np.float64(1.0), rank=1, op="sum",
                                      mode="blocking", key="x"))
    peer.start()
    h = coll.allreduce(np.float64(2.0), rank=0, op="sum", mode="event",
                       key="x")
    peer.join(timeout=10)
    assert h.test() and float(h.result) == 3.0


def test_mixed_modes_in_one_collective():
    """Ranks may independently choose blocking vs event-bound."""
    n = 3
    _, coll = _world(n)
    out = {}

    def blocking(r):
        def body():
            out[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                    mode="blocking", key="m")
        return body

    def event(r):
        def body():
            out[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                    mode="event", key="m")
        return body

    with TaskRuntime(num_workers=3) as rt:
        rt.submit(blocking(0))
        rt.submit(event(1))
        rt.submit(blocking(2))
        rt.taskwait()
    vals = [out[r].result if isinstance(out[r], CollectiveHandle)
            else out[r] for r in range(n)]
    assert all(float(v) == 3.0 for v in vals)


# ---------------------------------------------------------------------------
# CommWorld deterministic semantics
# ---------------------------------------------------------------------------
def test_commworld_non_overtaking_interleaved_tags():
    """Order is preserved *per* (src, dst, tag); distinct tags are
    independent channels."""
    w = tac.CommWorld(2)
    for i in range(4):
        w.isend(("a", i), src=0, dst=1, tag="ta")
        w.isend(("b", i), src=0, dst=1, tag="tb")
    got_a = [w.irecv(src=0, dst=1, tag="ta").result for _ in range(4)]
    got_b = [w.irecv(src=0, dst=1, tag="tb").result for _ in range(4)]
    assert got_a == [("a", i) for i in range(4)]
    assert got_b == [("b", i) for i in range(4)]


def test_commworld_send_completion_semantics():
    """Buffered isend completes locally at post; synchronous send only on
    match; both deliver the same payload order."""
    w = tac.CommWorld(2)
    buffered = w.isend("x", src=0, dst=1, tag=1)
    assert buffered.test()                      # locally complete at post
    sync = w.isend("y", src=0, dst=1, tag=1, synchronous=True)
    assert not sync.test()                      # waits for the match
    assert w.irecv(src=0, dst=1, tag=1).result == "x"
    assert not sync.test()                      # matched the buffered one
    assert w.irecv(src=0, dst=1, tag=1).result == "y"
    assert sync.test()


def test_commworld_recv_before_send():
    w = tac.CommWorld(2)
    r = w.irecv(src=1, dst=0, tag=7)
    assert not r.test()
    s = w.isend("late", src=1, dst=0, tag=7, synchronous=True)
    assert r.test() and r.result == "late" and s.test()


# ---------------------------------------------------------------------------
# executor block modes under collective load
# ---------------------------------------------------------------------------
def test_spare_thread_mode_scales_threads_under_collective_load():
    """spare-thread: one worker, four ranks in a multi-round blocking
    collective — the runtime spawns spare threads per paused task (the §9
    thread-per-in-flight-operation overhead) and completes."""
    n = 4
    _, coll = _world(n)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(np.float64(r + 1), rank=r, op="sum",
                                        algorithm="ring", mode="blocking",
                                        key="s")
        return body

    with TaskRuntime(num_workers=1, block_mode="spare-thread") as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    assert all(float(results[r]) == 10.0 for r in range(n))
    assert rt.stats["threads_spawned"] > 1     # spares were needed
    assert rt.stats["task_blocks"] > 0


def test_nested_mode_single_round_collective_single_worker():
    """nested: a single-round collective (dissemination barrier at n=2)
    completes on ONE worker by running the peer's task on the blocked
    task's stack (§5 resolved without extra threads)."""
    n = 2
    _, coll = _world(n)
    done = []

    def make(r):
        def body():
            coll.barrier(rank=r, algorithm="doubling", mode="blocking",
                         key="nb")
            done.append(r)
        return body

    with TaskRuntime(num_workers=1, block_mode="nested") as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    assert sorted(done) == [0, 1]
    assert rt.stats["threads_spawned"] == 1    # no spares in nested mode


def test_nested_mode_multi_round_single_worker():
    """nested with ONE worker and a multi-round blocking collective: safe
    because blocking mode pauses once on the completion handle while the
    progress engine advances the rounds — per-round pausing would deadlock
    the help-first LIFO stack here."""
    n = 3
    _, coll = _world(n)
    results = {}

    def make(r):
        def body():
            results[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                        algorithm="ring", mode="blocking",
                                        key="nm")
        return body

    with TaskRuntime(num_workers=1, block_mode="nested") as rt:
        for r in range(n):
            rt.submit(make(r))
        rt.taskwait()
    assert all(float(results[r]) == 3.0 for r in range(n))
    assert rt.stats["threads_spawned"] == 1    # no spares in nested mode


@pytest.mark.parametrize("block_mode", ["nested", "spare-thread"])
def test_event_mode_is_block_mode_agnostic(block_mode):
    """Event-bound collectives never pause, so both executor block modes
    behave identically under collective load."""
    n = 4
    _, coll = _world(n)
    got = {}
    handles = {}

    def comm(r):
        def body():
            handles[r] = coll.allreduce(np.float64(r), rank=r, op="sum",
                                        algorithm="doubling", mode="event",
                                        key="bm")
        return body

    def consume(r):
        def body():
            got[r] = float(handles[r].result)
        return body

    with TaskRuntime(num_workers=2, block_mode=block_mode) as rt:
        for r in range(n):
            rt.submit(comm(r), out=[("c", r)])
            rt.submit(consume(r), in_=[("c", r)])
        rt.taskwait()
    assert all(got[r] == 6.0 for r in range(n))
    assert rt.stats.get("task_blocks", 0) == 0


# ---------------------------------------------------------------------------
# simulator collective nodes
# ---------------------------------------------------------------------------
def _coll_graph(kind, n=4, lat=0.2):
    tasks = []
    for r in range(n):
        tasks.append(SimTask(2 * r, r, 1.0 + r, name=f"c[{r}]"))
        tasks.append(SimTask(2 * r + 1, r, 0.05, kind=kind,
                             start_deps=[(2 * r, 0.0)], group="ar",
                             group_latency=lat, name=f"coll[{r}]"))
    tasks.append(SimTask(2 * n, 0, 1.0, start_deps=[(0, 0.0)],
                         name="other"))
    return tasks


def test_sim_collective_completion_time():
    """All members complete at last-arrival + group latency."""
    res = Simulator(4, 1).run(_coll_graph(COMM_EVENTS))
    # last member enters at 4.0 + 0.05 body; +0.2 latency
    for r in range(4):
        assert res.done_times[2 * r + 1] == pytest.approx(4.25)


def test_sim_collective_discipline_ordering():
    held = Simulator(4, 1).run(_coll_graph(COMM_HELD))
    paused = Simulator(4, 1, resume_overhead=0.01).run(
        _coll_graph(COMM_PAUSED))
    events = Simulator(4, 1).run(_coll_graph(COMM_EVENTS))
    # held: rank 0's worker is occupied by the collective → 'other' waits
    assert events.makespan < paused.makespan < held.makespan
    assert events.resumes == 0 and paused.resumes == 4
    assert sum(held.held_wait_time.values()) > 0


def test_sim_collective_compute_kind_rejected():
    t = SimTask(0, 0, 1.0, kind=COMPUTE, group="g")
    with pytest.raises(ValueError, match="comm kind"):
        Simulator(1, 1).run([t])


def test_sim_graph_reusable_across_runs():
    """Group expansion must not mutate the task list between runs."""
    tasks = _coll_graph(COMM_EVENTS)
    a = Simulator(4, 1).run(tasks).makespan
    b = Simulator(4, 1).run(tasks).makespan
    assert a == b
    assert all(not t.event_deps for t in tasks)   # no synthesized leftovers


def test_gauss_seidel_event_bound_beats_sentinel():
    """Acceptance: on the Gauss-Seidel task graph the event-bound
    collective schedule achieves strictly smaller makespan than the
    sentinel-serialized one."""
    from benchmarks.gauss_seidel import simulate_version
    kw = dict(n_ranks=4, nby=2, nbx=4, iters=4)
    ev = simulate_version("interop-nonblk", **kw)
    blk = simulate_version("interop-blk", **kw)
    sn = simulate_version("sentinel", **kw)
    assert ev < sn
    assert blk < sn
