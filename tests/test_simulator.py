"""Tests for the deterministic makespan simulator (core/simulate.py)."""

import pytest

from repro.core.simulate import (Simulator, SimTask, COMPUTE, COMM_HELD,
                                 COMM_PAUSED, COMM_EVENTS)


def test_serial_chain():
    tasks = [SimTask(0, 0, 1.0), SimTask(1, 0, 2.0, start_deps=[(0, 0.0)])]
    res = Simulator(1, 1).run(tasks)
    assert res.makespan == pytest.approx(3.0)


def test_parallel_width_limited_by_workers():
    tasks = [SimTask(i, 0, 1.0) for i in range(4)]
    assert Simulator(1, 2).run(tasks).makespan == pytest.approx(2.0)
    assert Simulator(1, 4).run(tasks).makespan == pytest.approx(1.0)


def test_edge_latency():
    tasks = [SimTask(0, 0, 1.0), SimTask(1, 1, 1.0, start_deps=[(0, 0.5)])]
    res = Simulator(2, 1).run(tasks)
    assert res.makespan == pytest.approx(2.5)


def test_comm_held_holds_the_worker():
    """A held communication task starves the second task on a 1-worker rank,
    while the paused variant lets it run during the wait."""
    def graph(kind):
        return [
            SimTask(0, 1, 5.0, name="remote-producer"),
            SimTask(1, 0, 0.1, kind=kind, event_deps=[(0, 0.0)], name="comm"),
            SimTask(2, 0, 1.0, name="independent-compute"),
        ]

    held = Simulator(2, 1).run(graph(COMM_HELD))
    paused = Simulator(2, 1, resume_overhead=0.01).run(graph(COMM_PAUSED))
    events = Simulator(2, 1).run(graph(COMM_EVENTS))
    # held: comm occupies the only worker until t=5 → compute ends at 6.
    assert held.makespan == pytest.approx(6.0)
    assert held.held_wait_time[0] == pytest.approx(4.9)
    # paused: compute runs during the wait; comm resumes at 5 + overhead.
    assert paused.makespan == pytest.approx(5.01)
    assert paused.resumes == 1 and paused.max_paused == 1
    # events: no resume round-trip at all.
    assert events.makespan == pytest.approx(5.0)
    assert events.resumes == 0 and events.max_paused == 0


def test_deadlock_detection_section5():
    """§5: two held comm tasks on one worker that match each other."""
    tasks = [
        SimTask(0, 0, 0.1, kind=COMM_HELD, event_deps=[(1, 0.0)]),
        SimTask(1, 0, 0.1, kind=COMM_HELD, event_deps=[(0, 0.0)]),
    ]
    with pytest.raises(RuntimeError, match="deadlock"):
        Simulator(1, 1).run(tasks)
    # The same graph with the pause/resume discipline completes (§5).
    for t in tasks:
        t.kind = COMM_PAUSED
    res = Simulator(1, 1, resume_overhead=0.0).run(tasks)
    assert res.makespan == pytest.approx(0.2)


def test_events_mode_releases_downstream_at_arrival():
    tasks = [
        SimTask(0, 1, 3.0),                                    # remote
        SimTask(1, 0, 0.1, kind=COMM_EVENTS, event_deps=[(0, 0.5)]),
        SimTask(2, 0, 1.0, start_deps=[(1, 0.0)]),             # consumer
    ]
    res = Simulator(2, 1).run(tasks)
    # consumer starts at event arrival 3.5, ends 4.5
    assert res.makespan == pytest.approx(4.5)


def test_utilization_accounting():
    tasks = [SimTask(i, 0, 1.0) for i in range(4)]
    res = Simulator(1, 2).run(tasks)
    assert res.utilization(2, 1) == pytest.approx(1.0)
