"""Documentation must not rot: README/docs links resolve and every
``repro.*`` symbol the docs mention exists under src/ (PR 2 acceptance).
The same checker runs standalone in the CI docs job."""

import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "collectives.md").exists()


def test_docs_links_and_symbols():
    checker = _load_checker()
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    errors = []
    for f in files:
        errors.extend(checker.check_file(f))
    assert not errors, "\n".join(str(e) for e in errors)


def test_symbol_resolver_detects_dangling_names():
    checker = _load_checker()
    assert checker.resolve_symbol("repro.core.collectives.HaloExchange")
    assert not checker.resolve_symbol("repro.core.collectives.NoSuchThing")
    assert not checker.resolve_symbol("repro.nonexistent_module")
