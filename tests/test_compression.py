"""Gradient-compression tests (core/overlap.py) — subprocess: 8 devices."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_quantized_reduction_accuracy_and_wire_dtype():
    """int8 reduction ≈ exact mean (1%% of max) and the wire collectives
    (all-to-all / all-gather) carry s8 tensors; bf16 halves the all-reduce
    payload."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import quantized_psum_mean, sync_grads

mesh = make_mesh((8,), ("data",))
n = 4096
xs = jax.random.normal(jax.random.PRNGKey(0), (8, n)) * \\
    jnp.linspace(0.1, 3.0, 8)[:, None]      # heterogeneous scales

def f(x_local):
    return quantized_psum_mean(x_local.reshape(-1), "data")

sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                       out_specs=P(), axis_names={"data"},
                       check_vma=False))
got = np.asarray(sf(xs.reshape(-1)))
exact = np.asarray(jnp.mean(xs, axis=0))
tol = float(jnp.max(jnp.abs(xs))) / 127.0 * 2.1   # two quantisation legs
assert np.max(np.abs(got - exact)) < tol, (np.max(np.abs(got - exact)), tol)

txt = sf.lower(xs.reshape(-1)).compile().as_text()
assert "s8[" in txt, "int8 tensors must be on the wire"

# bf16 compression path through sync_grads
def g(x_local):
    out = sync_grads({"w": x_local}, axes=("data",), mode="fused",
                     compress="bf16")
    return out["w"]
sg = jax.jit(shard_map(g, mesh=mesh, in_specs=P("data"),
                       out_specs=P(), axis_names={"data"},
                       check_vma=False))
got_bf = np.asarray(sg(xs.reshape(-1)))
assert np.max(np.abs(got_bf - exact)) < 0.05
assert "bf16[" in sg.lower(xs.reshape(-1)).compile().as_text()
print("COMPRESSION-OK")
""")
