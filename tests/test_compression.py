"""Gradient-compression tests (core/overlap.py) — subprocess: 8 devices."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_quantized_reduction_accuracy_and_wire_dtype():
    """int8 reduction ≈ exact mean (1%% of max) and the wire collectives
    (all-to-all / all-gather) carry s8 tensors; bf16 halves the all-reduce
    payload."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import quantized_psum_mean, sync_grads

mesh = make_mesh((8,), ("data",))
n = 4096
xs = jax.random.normal(jax.random.PRNGKey(0), (8, n)) * \\
    jnp.linspace(0.1, 3.0, 8)[:, None]      # heterogeneous scales

def f(x_local):
    return quantized_psum_mean(x_local.reshape(-1), "data")

sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                       out_specs=P(), axis_names={"data"},
                       check_vma=False))
got = np.asarray(sf(xs.reshape(-1)))
exact = np.asarray(jnp.mean(xs, axis=0))
tol = float(jnp.max(jnp.abs(xs))) / 127.0 * 2.1   # two quantisation legs
assert np.max(np.abs(got - exact)) < tol, (np.max(np.abs(got - exact)), tol)

txt = sf.lower(xs.reshape(-1)).compile().as_text()
assert "s8[" in txt, "int8 tensors must be on the wire"

# bf16 compression path through sync_grads
def g(x_local):
    out = sync_grads({"w": x_local}, axes=("data",), mode="fused",
                     compress="bf16")
    return out["w"]
sg = jax.jit(shard_map(g, mesh=mesh, in_specs=P("data"),
                       out_specs=P(), axis_names={"data"},
                       check_vma=False))
got_bf = np.asarray(sg(xs.reshape(-1)))
assert np.max(np.abs(got_bf - exact)) < 0.05
assert "bf16[" in sg.lower(xs.reshape(-1)).compile().as_text()
print("COMPRESSION-OK")
""")


def test_quantized_psum_error_bound_per_leg():
    """The documented bound: each quantisation leg contributes at most
    max|x|/127 per element — the reduce-scatter leg bounded by the max
    input magnitude, the all-gather leg by the max of the (mean-reduced)
    partials, so the end-to-end error is <= (max|x| + max|mean|)/127."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import quantized_psum_mean

mesh = make_mesh((8,), ("data",))
n = 8192
xs = jax.random.normal(jax.random.PRNGKey(7), (8, n)) * \\
    jnp.linspace(0.2, 5.0, 8)[:, None]

def f(x_local):
    return quantized_psum_mean(x_local.reshape(-1), "data")
sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                       axis_names={"data"}, check_vma=False))
got = np.asarray(sf(xs.reshape(-1)))
exact = np.asarray(jnp.mean(xs, axis=0))
bound = (float(jnp.max(jnp.abs(xs))) + float(jnp.max(jnp.abs(exact)))) \\
    / 127.0
err = np.max(np.abs(got - exact))
assert err <= bound * 1.0001, (err, bound)
# the bound is tight-ish: a constant input quantises exactly
ones = jnp.ones((8 * n,))
exact0 = np.asarray(sf(ones))
assert np.max(np.abs(exact0 - 1.0)) < 1e-6
print("BOUND-OK", err, bound)
""")


def test_quantized_psum_padding_non_divisible():
    """Sizes with n % world != 0 round-trip through the pad/unpad path
    with the same error bound and exact shape."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import quantized_psum_mean

mesh = make_mesh((8,), ("data",))
for n in (4097, 1001, 17, 8):          # 8 % 8 == 0 control included
    xs = jax.random.normal(jax.random.PRNGKey(n), (8, n))
    def f(x_local):
        return quantized_psum_mean(x_local.reshape(-1), "data")
    sf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                           axis_names={"data"}, check_vma=False))
    got = np.asarray(sf(xs.reshape(-1)))
    exact = np.asarray(jnp.mean(xs, axis=0))
    assert got.shape == (n,), (n, got.shape)
    tol = float(jnp.max(jnp.abs(xs))) / 127.0 * 2.1
    assert np.max(np.abs(got - exact)) < tol, n
print("PADDING-OK")
""")


def test_quantized_psum_round_trip_vs_fp32_psum():
    """End-to-end: one sync_grads step with compress="int8" agrees with
    the uncompressed fp32 psum path within the two-leg bound."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.core.overlap import sync_grads

mesh = make_mesh((8,), ("data",))
n = 2048
xs = jax.random.normal(jax.random.PRNGKey(3), (8, n))

def make(compress):
    def f(x_local):
        out = sync_grads({"w": x_local}, axes=("data",), mode="fused",
                         compress=compress)
        return out["w"]
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                             out_specs=P(), axis_names={"data"},
                             check_vma=False))

ref = np.asarray(make(None)(xs.reshape(-1)))       # fp32 psum mean
q = np.asarray(make("int8")(xs.reshape(-1)))
tol = float(jnp.max(jnp.abs(xs))) / 127.0 * 2.1
assert np.max(np.abs(q - ref)) < tol, np.max(np.abs(q - ref))
print("ROUND-TRIP-OK")
""")
