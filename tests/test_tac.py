"""Tests for TAC (the TAMPI analogue): blocking + non-blocking modes (§6)."""

import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import TaskRuntime, tac


@pytest.fixture(autouse=True)
def _task_multiple():
    tac.init(tac.TASK_MULTIPLE)
    yield
    tac.init(tac.TASK_MULTIPLE)


def test_threading_levels():
    assert tac.TASK_MULTIPLE > tac.THREAD_MULTIPLE
    assert tac.init(tac.TASK_MULTIPLE) == tac.TASK_MULTIPLE
    assert tac.is_enabled()
    assert tac.init(tac.THREAD_MULTIPLE) == tac.THREAD_MULTIPLE
    assert not tac.is_enabled()


def test_array_handle_completion():
    x = jnp.arange(8.0)
    h = tac.run_async(jax.jit(lambda v: v * 2), x)
    assert h.wait() is h.result
    assert h.test()
    assert jnp.allclose(h.result, x * 2)


def test_blocking_wait_inside_task_pauses():
    """Fig. 3 path: incomplete handle → ticket → pause → poll → resume."""
    h = tac.EventHandle()
    got = []

    def comm_task():
        got.append(tac.wait(h))

    with TaskRuntime(num_workers=2) as rt:
        rt.submit(comm_task)
        time.sleep(0.05)           # let it reach the pause
        assert rt.stats.get("task_blocks", 0) == 1
        h.complete("payload")
        rt.taskwait()
    assert got == ["payload"]
    assert rt.stats["task_resumes"] == 1


def test_blocking_wait_completed_handle_no_pause():
    h = tac.EventHandle()
    h.complete(42)
    with TaskRuntime(num_workers=1) as rt:
        t = rt.submit(lambda: tac.wait(h))
        rt.taskwait()
    assert t.result == 42
    assert rt.stats.get("task_blocks", 0) == 0


def test_iwait_defers_release_not_execution():
    """Fig. 4/5: the communication task finishes immediately; the consumer
    runs only once the bound operation completes."""
    h = tac.EventHandle()
    order = []

    def comm_task():
        tac.iwait(h)
        order.append("comm-body-done")

    def consumer():
        order.append("consumer")

    with TaskRuntime(num_workers=4) as rt:
        rt.submit(comm_task, out=["buf"])
        rt.submit(consumer, in_=["buf"])
        deadline = time.time() + 0.3
        while "comm-body-done" not in order and time.time() < deadline:
            time.sleep(0.005)
        assert order == ["comm-body-done"]
        h.complete()
        rt.taskwait()
    assert order == ["comm-body-done", "consumer"]
    assert rt.stats.get("task_blocks", 0) == 0  # no pause: non-blocking mode


def test_iwaitall_multiple_events():
    hs = [tac.EventHandle() for _ in range(3)]
    hs[1].complete()  # one completes immediately — must not be bound
    done = []

    def comm_task():
        tac.iwaitall(hs)

    with TaskRuntime(num_workers=2) as rt:
        rt.submit(comm_task, out=["b"])
        rt.submit(lambda: done.append(1), in_=["b"])
        time.sleep(0.1)
        assert not done
        hs[0].complete()
        time.sleep(0.1)
        assert not done
        hs[2].complete()
        rt.taskwait()
    assert done == [1]


def test_commworld_ordering_and_tags():
    w = tac.CommWorld(2)
    w.isend("a", src=0, dst=1, tag=9)
    w.isend("b", src=0, dst=1, tag=9)
    r1 = w.irecv(src=0, dst=1, tag=9)
    r2 = w.irecv(src=0, dst=1, tag=9)
    assert r1.result == "a" and r2.result == "b"  # non-overtaking

    r3 = w.irecv(src=1, dst=0, tag=5)
    assert not r3.test()
    w.isend("c", src=1, dst=0, tag=5)
    assert r3.test() and r3.result == "c"


def test_ssend_completes_on_match():
    w = tac.CommWorld(2)
    s = w.isend("x", src=0, dst=1, synchronous=True)
    assert not s.test()
    r = w.irecv(src=0, dst=1)
    assert s.test() and r.result == "x"


@pytest.mark.parametrize("mode", ["nested", "spare-thread"])
def test_deadlock_avoidance_section5(mode):
    """Paper §5: one worker, task A does a synchronous-mode send, task B the
    matching receive.  With plain blocking semantics this deadlocks; with
    TASK_MULTIPLE the pause/resume API lets the worker run B while A is
    paused, completing both."""
    w = tac.CommWorld(2)
    results = []

    def sender():
        w.ssend("ping", src=0, dst=1)   # blocks until matched
        results.append("sent")

    def receiver():
        results.append(w.recv(src=0, dst=1))

    with TaskRuntime(num_workers=1, block_mode=mode) as rt:
        rt.submit(sender)
        rt.submit(receiver)
        rt.taskwait()
    assert sorted(results) == ["ping", "sent"]


def test_fallback_is_the_sentinel_world():
    """With only THREAD_MULTIPLE, tac.wait degenerates to a plain blocking
    wait (the PMPI path): the §5 pattern now genuinely deadlocks unless the
    program serialises communication tasks — verify the blocking behaviour
    on a completed handle path (safe) and that no pause is recorded."""
    tac.init(tac.THREAD_MULTIPLE)
    h = tac.EventHandle()
    threading.Timer(0.05, h.complete, args=("late",)).start()
    with TaskRuntime(num_workers=1) as rt:
        t = rt.submit(lambda: tac.wait(h))
        rt.taskwait()
    assert t.result == "late"
    assert rt.stats.get("task_blocks", 0) == 0  # worker blocked in-place


def test_many_inflight_small_messages_nonblocking():
    """Stress the non-blocking mode: many communication tasks, none pause."""
    w = tac.CommWorld(2)
    n = 200
    received = []

    def send_task(i):
        w.isend(i, src=0, dst=1, tag=i)

    def recv_task(i):
        h = w.irecv(src=0, dst=1, tag=i)
        tac.iwait(h)
        # body finishes; release deferred until message arrival

    def collect(i):
        received.append(i)

    with TaskRuntime(num_workers=4) as rt:
        for i in range(n):
            rt.submit(recv_task, i, out=[("buf", i)])
            rt.submit(collect, i, in_=[("buf", i)])
        for i in range(n):
            rt.submit(send_task, i)
        rt.taskwait()
    assert sorted(received) == list(range(n))
    assert rt.stats.get("task_blocks", 0) == 0
