"""Reusable fault-injection harness for failure-recovery tests.

Drives one collective across all ranks of a fresh world on a real
:class:`~repro.core.TaskRuntime`, kills a chosen rank at a chosen
operation count (mid-send / mid-collective / between rounds — see
:meth:`repro.core.resilience.FaultInjector.arm`), harvests how the
failure surfaced on every rank, then runs the full ULFM recovery
(revoke + shrink) and a post-recovery collective on the survivors.

The harness asserts the protocol's *shape* (no hangs, leak-free
teardown, every rank either a result or a failure error); the caller
asserts the *semantics* (which ranks failed, survivor numerics).  Used
by tests/test_resilience.py both directly and under hypothesis.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import Collectives, TaskRuntime, tac
from repro.core.executor import TaskError
from repro.core.resilience import FaultInjector, recover

ALGORITHMS = ("ring", "doubling", "bruck", "tree")


@dataclass
class FaultOutcome:
    """What one injected failure did to an n-rank collective."""
    world: tac.CommWorld
    values: List[np.ndarray]
    results: Dict[int, Any] = field(default_factory=dict)   # rank -> value
    errors: Dict[int, BaseException] = field(default_factory=dict)
    survivors: Any = None          # CommGroup after recovery, or None
    recovered: Dict[int, Any] = field(default_factory=dict)

    @property
    def failed_ranks(self):
        return sorted(self.errors)

    @property
    def ok_ranks(self):
        return sorted(self.results)


def _resolve(store: Dict[int, Any], outcome: FaultOutcome) -> None:
    for r, v in store.items():
        try:
            if isinstance(v, tac.AsyncHandle):
                v = v.result
            outcome.results[r] = v
        except tac.RankFailedError as exc:    # includes CommRevokedError
            outcome.errors[r] = exc


def run_with_failure(*, n_ranks: int, victim: int, after_ops: int = 1,
                     algorithm: str = "ring", mode: str = "event",
                     notify: Optional[str] = None, op: str = "allreduce",
                     hierarchical: Optional[int] = None, workers: int = 2,
                     recover_after: bool = True,
                     seed: int = 0) -> FaultOutcome:
    """One allreduce over ``n_ranks`` with ``victim`` dying at its
    ``after_ops``-th posted operation; returns the harvested outcome.

    Guarantees checked here, for every parameter combination:

    * the taskwait returns (failure propagation is hang-free — the
      machine observing the dead peer revokes the communicator);
    * every rank lands in exactly one of ``results`` / ``errors``;
    * the runtime closes leak-free (no registered polling services).

    With ``recover_after`` the survivors then revoke + shrink and re-run
    the collective on the shrunken group (sequential driver), filling
    ``outcome.survivors`` / ``outcome.recovered``.
    """
    tac.init(tac.TASK_MULTIPLE)
    world = tac.CommWorld(n_ranks)
    coll = Collectives(world)
    injector = FaultInjector(world)
    rng = np.random.default_rng(seed)
    values = [rng.standard_normal(4) for _ in range(n_ranks)]
    outcome = FaultOutcome(world=world, values=values)
    store: Dict[int, Any] = {}
    kw: Dict[str, Any] = ({"hierarchical": hierarchical}
                          if hierarchical else {"algorithm": algorithm})

    def body(r):
        def run():
            store[r] = coll.allreduce(values[r], rank=r, mode=mode,
                                      key="fh", **kw)
        return run

    rt = TaskRuntime(num_workers=workers, notify=notify)
    rt.start()
    try:
        injector.arm(victim, after_ops=after_ops)
        for r in range(n_ranks):
            rt.submit(body(r), name=f"coll[{r}]")
        try:
            rt.taskwait()       # must NOT hang for any combination
        except TaskError as exc:
            # blocking mode: the raising body never filled its slot
            root = exc.error
            assert isinstance(root, tac.RankFailedError), exc
        # drain stragglers (other blocking bodies may error too)
        while True:
            try:
                rt.taskwait()
                break
            except TaskError:
                continue
    finally:
        injector.disarm()
        rt.close()
    assert rt.polling.num_services == 0, "leaked polling services"
    _resolve(store, outcome)
    claimed = set(outcome.results) | set(outcome.errors)
    # blocking-mode errored bodies never stored anything: their absence
    # from both maps IS the error record
    if mode == "event":
        assert claimed == set(range(n_ranks)), claimed
    assert outcome.errors or len(outcome.results) < n_ranks, \
        "injected failure was not observed anywhere"

    if recover_after:
        survivors = recover(world)
        outcome.survivors = survivors
        assert victim not in survivors.ranks
        assert survivors.size == n_ranks - 1
        scoll = Collectives(survivors)
        # the shrunken size may not divide a hierarchical pod shape —
        # recovery re-picks a flat algorithm in that case
        rkw = {} if hierarchical else {"algorithm": algorithm}
        out = scoll.run_group(
            "allreduce",
            [{"value": values[wr]} for wr in survivors.ranks],
            op="sum", key="fh-rec", **rkw)
        outcome.recovered = {gr: out[gr] for gr in range(survivors.size)}
        ref = np.sum([values[wr] for wr in survivors.ranks], axis=0)
        for gr, v in outcome.recovered.items():
            np.testing.assert_allclose(v, ref, rtol=1e-10, atol=1e-12)
    return outcome
