"""Schedule-IR tests: structure, cost model, selection, persistence.

The tentpole's acceptance criteria live here: (a) every collective ×
algorithm builds a structurally valid schedule whose critical-path rounds
equal the closed-form latency model the simulator always used; (b) the
host interpreter executes segmented schedules to the same result as
unsegmented ones; (c) under the α-β(-γ) cost model — analytic AND
discrete-event — a segmented ring allreduce strictly beats the
unsegmented one for large payloads; (d) α-β selection picks the
latency-optimal algorithm for small payloads and the bandwidth-optimal
(segmented) one for large.
"""

import numpy as np
import pytest

from repro.core import schedule as schedule_ir
from repro.core import simulate
from repro.core import tac
from repro.core.collectives import (Collectives, HaloExchange,
                                    HierarchicalCollectives,
                                    PersistentCollective, n_rounds)
from repro.core.schedule import Recv, Send, build, build_neighbor, \
    best_schedule

RANKS = (1, 2, 3, 4, 5, 7, 8)
ALPHA, BETA, GAMMA = 5e-6, 1e-9, 4e-10


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", schedule_ir.COLLECTIVES)
@pytest.mark.parametrize("alg", schedule_ir.ALGORITHMS)
@pytest.mark.parametrize("n", RANKS)
def test_build_validates_and_matches_closed_form_rounds(name, alg, n):
    sched = build(name, alg, n)
    sched.validate()                      # idempotent structural check
    assert sched.n == n
    assert sched.rounds == n_rounds(name, alg, n)


@pytest.mark.parametrize("n", (2, 3, 5, 8))
def test_transfers_are_matched_pairs(n):
    for name in schedule_ir.COLLECTIVES:
        for alg in schedule_ir.ALGORITHMS:
            sched = build(name, alg, n)
            sends = sum(isinstance(o, Send) for p in sched.programs
                        for o in p)
            recvs = sum(isinstance(o, Recv) for p in sched.programs
                        for o in p)
            assert sends == recvs == len(sched.transfers())


def test_schedules_are_cached_data():
    a = build("allreduce", "ring", 8)
    b = build("allreduce", "ring", 8)
    assert a is b                          # immutable, shared
    assert build("allreduce", "ring", 8, segments=2) is not a


def test_build_rejects_bad_arguments():
    with pytest.raises(ValueError):
        build("allreduce", "butterfly", 4)
    with pytest.raises(ValueError):
        build("gather", "ring", 4)
    with pytest.raises(ValueError):
        build("bcast", "ring", 4, root=4)
    with pytest.raises(ValueError):
        build("bcast", "ring", 4, segments=2)   # only ring allreduce
    with pytest.raises(ValueError):
        build("allreduce", "ring", 0)


@pytest.mark.parametrize("n,segments", [(4, 2), (5, 3), (8, 4)])
def test_segmented_ring_structure(n, segments):
    sched = build("allreduce", "ring", n, segments=segments)
    sched.validate()
    counts = sched.counts()
    # 2(n-1) rounds × S segments × n ranks transfers; combines only on
    # the reduce-scatter leg.
    assert counts["Send"] == 2 * (n - 1) * segments * n
    assert counts["Combine"] == (n - 1) * segments * n


def test_neighbor_schedule_matches_topology():
    world = tac.CommWorld(6)
    cart = world.cart_create((2, 3))
    sched = build_neighbor(cart.topology())
    # one transfer per directed grid edge
    n_edges = sum(len(cart.neighbor_dirs(r)) for r in range(6))
    assert len(sched.transfers()) == n_edges
    assert sched.out_dirs[0] == tuple(d for d, _ in cart.neighbor_dirs(0))
    # same-shape grids share the cached schedule object
    cart2 = tac.CommWorld(8).cart_create((2, 3))
    assert build_neighbor(cart2.topology()) is sched


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_cost_latency_point_equals_rounds():
    for name in schedule_ir.COLLECTIVES:
        for alg in schedule_ir.ALGORITHMS:
            sched = build(name, alg, 7)
            assert sched.cost(1.0, 0.0, 0.0) == pytest.approx(sched.rounds)


def test_cost_algorithm_crossover():
    """doubling wins the latency-bound regime, ring the bandwidth-bound."""
    ring = build("allreduce", "ring", 8)
    dbl = build("allreduce", "doubling", 8)
    small, large = 64, 256 << 20
    assert dbl.cost(ALPHA, BETA, small) < ring.cost(ALPHA, BETA, small)
    assert ring.cost(ALPHA, BETA, large) < dbl.cost(ALPHA, BETA, large)


def test_cost_monotone_in_size_and_alpha():
    sched = build("allreduce", "ring", 5)
    assert sched.cost(ALPHA, BETA, 1 << 20) < sched.cost(ALPHA, BETA,
                                                         1 << 24)
    assert sched.cost(ALPHA, BETA, 1 << 20) < sched.cost(10 * ALPHA, BETA,
                                                         1 << 20)


@pytest.mark.parametrize("segments", (2, 4))
def test_segmented_beats_unsegmented_analytic(segments):
    """Acceptance: S≥2 strictly beats the unsegmented ring for large
    payloads once combines cost anything (γ > 0) — the pipelining win."""
    size = 64 << 20
    un = build("allreduce", "ring", 8).cost(ALPHA, BETA, size, gamma=GAMMA)
    seg = build("allreduce", "ring", 8, segments=segments).cost(
        ALPHA, BETA, size, gamma=GAMMA)
    assert seg < un


@pytest.mark.parametrize("segments", (2, 4))
def test_segmented_beats_unsegmented_in_simulator(segments):
    """Same claim under the discrete-event simulator's replay of the
    schedule DAG (schedule_tasks/schedule_makespan)."""
    size = 64 << 20
    kw = dict(size=size, alpha=ALPHA, beta=BETA, gamma=GAMMA)
    un = simulate.schedule_makespan(build("allreduce", "ring", 8), **kw)
    seg = simulate.schedule_makespan(
        build("allreduce", "ring", 8, segments=segments), **kw)
    assert seg < un


def test_simulator_replay_tracks_analytic_cost():
    """The two consumers of one schedule agree (same DAG, slightly
    different port models): within 25% on a bandwidth-bound ring."""
    sched = build("allreduce", "ring", 8)
    size = 16 << 20
    analytic = sched.cost(ALPHA, BETA, size, gamma=GAMMA)
    replay = simulate.schedule_makespan(sched, size=size, alpha=ALPHA,
                                        beta=BETA, gamma=GAMMA)
    assert replay == pytest.approx(analytic, rel=0.25)


def test_best_schedule_selection():
    small = best_schedule("allreduce", 8, 64, alpha=ALPHA, beta=BETA,
                          gamma=GAMMA)
    assert (small.algorithm, small.segments) == ("doubling", 1)
    large = best_schedule("allreduce", 8, 64 << 20, alpha=ALPHA,
                          beta=BETA, gamma=GAMMA)
    assert large.algorithm == "ring" and large.segments > 1


# ---------------------------------------------------------------------------
# host interpreter over the IR
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", (2, 3, 5, 8))
@pytest.mark.parametrize("segments", (2, 3))
def test_segmented_allreduce_host_equals_unsegmented(n, segments):
    w = tac.CommWorld(n)
    coll = Collectives(w)
    vals = [np.arange(17, dtype=np.float64) * (r + 1) for r in range(n)]
    ref = coll.run_group("allreduce", [{"value": v} for v in vals],
                         algorithm="ring")
    seg = coll.run_group("allreduce", [{"value": v} for v in vals],
                         algorithm="ring", segments=segments)
    for a, b in zip(ref, seg):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, sum(vals))


def test_segmented_allreduce_rejects_doubling():
    coll = Collectives(tac.CommWorld(4))
    with pytest.raises(ValueError):
        coll.allreduce(np.ones(4), rank=0, algorithm="doubling",
                       segments=2)


def test_auto_algorithm_runs_and_matches():
    w = tac.CommWorld(4)
    coll = Collectives(w, alpha=ALPHA, beta=BETA, gamma=GAMMA)
    vals = [np.full(3, float(r)) for r in range(4)]
    out = coll.run_group("allreduce", [{"value": v} for v in vals],
                         algorithm="auto")
    for o in out:
        np.testing.assert_array_equal(o, sum(vals))
    # prediction helper exposes the model
    assert coll.predict("allreduce", 1 << 20) > 0.0


def test_auto_is_deterministic_for_ragged_payloads():
    """Size-based selection only applies to uniform-payload reductions;
    for ragged ops (alltoall blocks, non-root bcast values) every rank
    must resolve the SAME schedule or the collective stalls."""
    w = tac.CommWorld(4)
    coll = Collectives(w, alpha=ALPHA, beta=BETA, gamma=GAMMA)
    # rank 0 ships huge blocks, the rest tiny ones — must not stall
    blocks = [[np.zeros(100_000 if s == 0 else 1) for _ in range(4)]
              for s in range(4)]
    out = coll.run_group("alltoall", [{"blocks": b} for b in blocks],
                         algorithm="auto")
    assert out[1][0].shape == (100_000,)
    # bcast: non-root ranks pass None (0 bytes) while root has data
    got = coll.run_group(
        "bcast", [{"value": np.zeros(100_000) if r == 0 else None}
                  for r in range(4)], algorithm="auto")
    assert all(g.shape == (100_000,) for g in got)


def test_predict_auto_respects_nbytes():
    coll = Collectives(tac.CommWorld(8), alpha=ALPHA, beta=BETA,
                       gamma=GAMMA)
    big = coll.predict("allreduce", 64 << 20, algorithm="auto")
    # auto's choice for the big payload must match explicit best_schedule
    best = best_schedule("allreduce", 8, 64 << 20, alpha=ALPHA, beta=BETA,
                         gamma=GAMMA)
    assert big == pytest.approx(
        best.cost(ALPHA, BETA, 64 << 20, gamma=GAMMA))
    # and must beat the latency-optimal schedule it would pick at 0 bytes
    dbl = coll.predict("allreduce", 64 << 20, algorithm="doubling")
    assert big < dbl


def test_n_rounds_rejects_auto():
    with pytest.raises(ValueError):
        n_rounds("allreduce", "auto", 8)


# ---------------------------------------------------------------------------
# persistent collectives (MPI_*_init analogue)
# ---------------------------------------------------------------------------
def test_persistent_allreduce_reposts_with_isolated_tags():
    w = tac.CommWorld(5)
    coll = Collectives(w)
    p = coll.persistent("allreduce", algorithm="ring")
    assert isinstance(p, PersistentCollective)
    assert p.sched is build("allreduce", "ring", 5)   # pre-built, shared
    for it in range(4):
        vals = [np.arange(9, dtype=np.float64) + it * (r + 1)
                for r in range(5)]
        out = p.run_group(vals)
        for o in out:
            np.testing.assert_array_equal(o, sum(vals))


def test_persistent_alltoall_and_bcast():
    w = tac.CommWorld(4)
    coll = Collectives(w)
    pa = coll.persistent("alltoall")
    blocks = [[f"{s}->{d}" for d in range(4)] for s in range(4)]
    res = pa.run_group(blocks)
    for d in range(4):
        assert res[d] == [f"{s}->{d}" for s in range(4)]
    pb = coll.persistent("bcast", root=2)
    out = pb.run_group(["x" if r == 2 else None for r in range(4)])
    assert out == ["x"] * 4


def test_persistent_rejects_auto():
    coll = Collectives(tac.CommWorld(4))
    with pytest.raises(ValueError):
        coll.persistent("allreduce", algorithm="auto")


def test_persistent_hierarchical_residual_shape():
    """The Gauss–Seidel residual pattern: one persistent handle, one
    posting per iteration, every rank sees the same total."""
    world = tac.CommWorld(6)
    hier = HierarchicalCollectives(world, 3)
    res = hier.persistent(op="sum")
    for it in range(3):
        vals = [float(r + it) for r in range(6)]
        out = res.run_group(vals, key=("res", it))
        assert all(abs(o - sum(vals)) < 1e-12 for o in out)
    assert res.cost(ALPHA, BETA, 8) > 0.0


# ---------------------------------------------------------------------------
# one IR, two executors: neighbourhood parity
# ---------------------------------------------------------------------------
def test_halo_exchange_runs_the_neighbor_schedule():
    world = tac.CommWorld(4)
    cart = world.cart_create((2, 2), periodic=False)
    hx = HaloExchange(cart)
    assert hx.sched is build_neighbor(cart.topology())
    sends = [{d: np.full(2, float(r * 10 + i))
              for i, (d, _) in enumerate(hx.neighbors(r))}
             for r in range(4)]
    got = hx.run_group(sends)
    for r in range(4):
        for d, nbr in hx.neighbors(r):
            opp = (d[0], -d[1])
            np.testing.assert_array_equal(got[r][d], sends[nbr][opp])


def test_hierarchical_cost_latency_point_equals_n_rounds():
    world = tac.CommWorld(7)
    hier = HierarchicalCollectives(world, 3)
    assert hier.cost(1.0, 0.0, 0) == pytest.approx(hier.n_rounds())


def test_rank_translation_hooks():
    """The tac hooks schedule-IR consumers translate through: identity on
    the world, MPI_Group_translate_ranks on groups — including a
    CommWorld as translation target (HierarchicalCollectives' leader
    discovery)."""
    w = tac.CommWorld(6)
    assert w.world_rank(3) == 3
    assert w.group_rank(3) == 3
    assert w.group_rank(6) is None
    with pytest.raises(ValueError):
        w.world_rank(6)
    g = w.group([4, 1, 5])
    assert g.translate_many([0, 1, 2], w) == [4, 1, 5]
    other = w.group([1, 4])
    assert g.translate_many([0, 1, 2], other) == [1, 0, None]


def test_neighbor_schedule_memoised_on_communicator():
    cart = tac.CommWorld(4).cart_create((2, 2))
    coll = Collectives(cart)
    sends = {d: np.zeros(1) for d, _ in
             [(d, n) for d, n in cart.neighbor_dirs(0)]}
    # per-rank postings share one schedule object on the communicator
    from repro.core.collectives import _neighbor_schedule
    s1 = _neighbor_schedule(cart)
    s2 = _neighbor_schedule(cart)
    assert s1 is s2
    assert HaloExchange(cart).sched is s1


# ---------------------------------------------------------------------------
# hierarchical composition: one flat schedule spanning two mesh axes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("intra,inter",
                         [(4, 2), (2, 4), (3, 2), (2, 3), (4, 1), (1, 4),
                          (2, 2), (1, 1)])
def test_hierarchical_structure_and_rounds(intra, inter):
    """build_hierarchical validates and its critical path is the
    composed closed form: 2(intra-1) intra ring rounds + the inter
    doubling rounds (fold/unfold included for non-power-of-two pods)."""
    sched = schedule_ir.build_hierarchical(intra, inter)
    sched.validate()
    assert sched.n == intra * inter
    assert sched.algorithm == "hierarchical"
    assert dict(sched.axes) == {"inter": inter, "intra": intra}
    assert sched.n_chunks == intra
    expect = 2 * (intra - 1)
    if inter > 1:
        expect += n_rounds("allreduce", "doubling", inter)
    assert sched.rounds == expect


def test_hierarchical_is_cached_data():
    a = schedule_ir.build_hierarchical(4, 2)
    assert schedule_ir.build_hierarchical(4, 2) is a
    assert schedule_ir.build_hierarchical(2, 4) is not a


def test_hierarchical_rejects_bad_arguments():
    with pytest.raises(ValueError):
        schedule_ir.build_hierarchical(0, 2)
    with pytest.raises(ValueError):
        schedule_ir.build_hierarchical(2, 2, inter_algorithm="ring")


@pytest.mark.parametrize("intra,inter", [(4, 2), (2, 4), (3, 2), (2, 3)])
def test_hierarchical_host_matches_flat(intra, inter):
    """Level A interprets the composed schedule to the same result as the
    flat ring allreduce and the numpy reduction — including payloads not
    divisible by the chunk count."""
    n = intra * inter
    world = tac.CommWorld(n)
    coll = Collectives(world)
    vals = [np.arange(11, dtype=np.float64) * (r + 1) + 0.25
            for r in range(n)]
    want = np.sum(vals, axis=0)
    got = coll.run_group("allreduce", [{"value": v} for v in vals],
                         hierarchical=intra)
    flat = coll.run_group("allreduce", [{"value": v} for v in vals])
    for g, f in zip(got, flat):
        np.testing.assert_allclose(g, want)
        np.testing.assert_allclose(f, want)


def test_hierarchical_kwarg_validation():
    coll = Collectives(tac.CommWorld(6))
    with pytest.raises(ValueError):        # intra must divide the size
        coll.run_group("allreduce", [{"value": np.ones(2)}] * 6,
                       hierarchical=4)
    with pytest.raises(ValueError):        # composed schedule is fixed
        coll.run_group("allreduce", [{"value": np.ones(2)}] * 6,
                       hierarchical=2, algorithm="ring")


def test_hierarchical_composed_equals_grouped():
    """HierarchicalCollectives: the composed single-schedule form agrees
    with the three-stage sub-group form, and exposes the IR object the
    Level-B lowering consumes."""
    world = tac.CommWorld(8)
    hier = HierarchicalCollectives(world, 4)
    assert hier.sched is schedule_ir.build_hierarchical(4, 2)
    vals = [np.full(7, float(r + 1)) for r in range(8)]
    grouped = hier.run_group(vals)
    composed = hier.run_group(vals, composed=True)
    for g, c in zip(grouped, composed):
        np.testing.assert_allclose(g, np.full(7, 36.0))
        np.testing.assert_allclose(c, np.full(7, 36.0))
    # unequal intra groups: no flat factorisation exists
    ragged = HierarchicalCollectives(tac.CommWorld(6), 4)
    assert ragged.sched is None
    with pytest.raises(ValueError):
        ragged.run_group([np.ones(2)] * 6, composed=True)


def test_hierarchical_cost_beats_flat_ring_on_latency():
    """Uniform constants: (4, 2) moves the same bytes as the flat 8-rank
    ring in half the rounds, so it wins for latency-bound payloads and
    never costs more wire time."""
    hier = schedule_ir.build_hierarchical(4, 2)
    flat = build("allreduce", "ring", 8)
    assert hier.rounds < flat.rounds
    assert hier.cost(ALPHA, BETA, 1024) < flat.cost(ALPHA, BETA, 1024)


def test_hierarchical_simulator_replay_and_two_tier_link():
    """The discrete-event replay of the composed DAG: the latency point
    recovers the closed-form rounds, and under a two-tier machine
    (expensive inter-pod links) the hierarchical composition beats the
    flat ring replayed on the SAME link model — the paper's motivation
    for hierarchy on a production mesh."""
    hier = schedule_ir.build_hierarchical(4, 2)
    flat = build("allreduce", "ring", 8)
    assert simulate.schedule_makespan(
        hier, size=0.0, alpha=1.0, beta=0.0) == pytest.approx(hier.rounds)
    link = simulate.two_tier_link(4, alpha=1e-6, beta=1e-10,
                                  inter_alpha=2e-5, inter_beta=2e-9)
    mh = simulate.schedule_makespan(hier, size=1e6, alpha=1e-6,
                                    beta=1e-10, link=link)
    mf = simulate.schedule_makespan(flat, size=1e6, alpha=1e-6,
                                    beta=1e-10, link=link)
    assert mh < mf


# ---------------------------------------------------------------------------
# calibrated constants (tools/calibrate.py round trip)
# ---------------------------------------------------------------------------
def test_load_calibration_feeds_auto_selection(tmp_path):
    import json
    path = tmp_path / "CALIBRATION.json"
    path.write_text(json.dumps({"alpha": 2e-5, "beta": 3e-9,
                                "gamma": 1e-10, "overhead": 0.5}))
    consts = schedule_ir.load_calibration(path)
    assert consts == {"alpha": 2e-5, "beta": 3e-9, "gamma": 1e-10}
    coll = Collectives(tac.CommWorld(4), calibration=path)
    assert (coll.alpha, coll.beta, coll.gamma) == (2e-5, 3e-9, 1e-10)
    # the calibrated constants drive algorithm="auto" via best_schedule
    sched = best_schedule("allreduce", 4, 8, **consts)
    assert sched.algorithm == "doubling"   # tiny payload: latency-bound
    big = best_schedule("allreduce", 4, 1 << 24, **consts)
    assert big.algorithm == "ring"         # huge payload: bandwidth-bound
    # dicts work too (pre-loaded calibration shared across communicators)
    coll2 = Collectives(tac.CommWorld(4), calibration=consts)
    assert coll2.beta == 3e-9


def test_calibrate_fit_recovers_constants(tmp_path):
    """tools/calibrate.py round trip: synthesise measurements from known
    constants, fit, and gate against a self-written baseline."""
    import json
    import pathlib
    import subprocess
    import sys
    true = {"alpha": 2e-6, "beta": 4e-9, "gamma": 1e-10, "overhead": 3e-3}
    report = {"modes": {}}
    for i, (name, n, size) in enumerate(
            [("fused", 8, 1 << 20), ("bucketed", 8, 1 << 16),
             ("sentinel", 8, 1 << 18), ("tiny", 8, 1 << 8)]):
        sched = build("allreduce", "ring" if i % 2 else "doubling", n)
        feats = {"rounds": sched.cost(1.0, 0.0, 0.0),
                 "wire_bytes": sched.cost(0.0, 1.0, size),
                 "combine_bytes": sched.cost(0.0, 0.0, size, gamma=1.0)}
        measured = (true["alpha"] * feats["rounds"]
                    + true["beta"] * feats["wire_bytes"]
                    + true["gamma"] * feats["combine_bytes"]
                    + true["overhead"])
        report["modes"][name] = {"features": feats, "measured_s": measured}
    bench = tmp_path / "BENCH_overlap.json"
    bench.write_text(json.dumps(report))
    tool = pathlib.Path(__file__).resolve().parents[1] / "tools" / \
        "calibrate.py"
    base = tmp_path / "BENCH_baseline.json"
    out = tmp_path / "CALIBRATION.json"
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--out",
         str(out), "--apply", "--write-baseline", str(base)],
        capture_output=True, text=True)
    assert run.returncode == 0, run.stderr
    consts = json.loads(out.read_text())
    # exact data, 4 unknowns, 4 independent rows: the fit recovers them
    assert consts["alpha"] == pytest.approx(true["alpha"], rel=1e-3)
    assert consts["beta"] == pytest.approx(true["beta"], rel=1e-3)
    assert consts["overhead"] == pytest.approx(true["overhead"], rel=1e-3)
    # calibrated predictions land next to the nominal ones
    applied = json.loads(bench.read_text())
    row = applied["modes"]["fused"]
    assert row["predicted_calibrated_s"] == pytest.approx(
        row["measured_s"], rel=1e-6)
    # and the gate passes against the freshly written baseline...
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--gate",
         "--baseline", str(base), "--out", str(out)],
        capture_output=True, text=True)
    assert run.returncode == 0, run.stdout + run.stderr
    # ...but fails once a measurement drifts structurally
    report["modes"]["fused"]["measured_s"] *= 50.0
    bench.write_text(json.dumps(report))
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--gate",
         "--baseline", str(base), "--out", str(out)],
        capture_output=True, text=True)
    assert run.returncode == 1
    assert "DRIFT" in run.stdout
    # ...and when a baseline row vanishes from the report entirely
    del report["modes"]["sentinel"]
    bench.write_text(json.dumps(report))
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--gate",
         "--baseline", str(base), "--out", str(out)],
        capture_output=True, text=True)
    assert run.returncode == 1
    assert "MISSING" in run.stdout


def test_calibrate_per_executor_overheads_and_gate(tmp_path):
    """Rows labelled ``overhead_class`` fit per-family α/β/γ plus a
    per-class overhead intercept; the gate hard-asserts the compiled
    executor's intercept at ≤ 0.5× the interpreted one — and catches a
    compiled-path regression even when no per-row ratio drifts."""
    import json
    import pathlib
    import subprocess
    import sys
    # the default (XLA-leg) family, fitted exactly as before
    true = {"alpha": 2e-6, "beta": 4e-9, "gamma": 1e-10, "overhead": 3e-3}
    report = {"modes": {}, "level_a": {"compiled": {}, "interpreted": {}}}
    for i, (name, size) in enumerate(
            [("fused", 1 << 20), ("bucketed", 1 << 16),
             ("sentinel", 1 << 18), ("tiny", 1 << 8)]):
        sched = build("allreduce", "ring" if i % 2 else "doubling", 8)
        feats = {"rounds": sched.cost(1.0, 0.0, 0.0),
                 "wire_bytes": sched.cost(0.0, 1.0, size),
                 "combine_bytes": sched.cost(0.0, 0.0, size, gamma=1.0)}
        report["modes"][name] = {
            "features": feats,
            "measured_s": (true["alpha"] * feats["rounds"]
                           + true["beta"] * feats["wire_bytes"]
                           + true["gamma"] * feats["combine_bytes"]
                           + true["overhead"])}
    # the level_a family: its OWN transport constants (host isend/irecv,
    # orders of magnitude off the XLA legs') + per-executor overheads
    fam = {"alpha": 1.2e-5, "beta": 2e-10, "gamma": 5e-11}
    configs = [("ring_small", 112, 7168, 3584),
               ("ring_big", 112, 1835008, 917504),
               ("dbl_small", 24, 12288, 12288),
               ("dbl_big", 24, 3145728, 3145728)]

    def level_a_rows(overheads):
        rows = {"compiled": {}, "interpreted": {}}
        for executor, o in overheads.items():
            for name, r, w, v in configs:
                rows[executor][name] = {
                    "features": {"rounds": r, "wire_bytes": w,
                                 "combine_bytes": v},
                    "measured_s": (fam["alpha"] * r + fam["beta"] * w
                                   + fam["gamma"] * v + o),
                    "overhead_class": f"level_a:{executor}"}
        return rows

    report["level_a"] = level_a_rows({"compiled": 4e-5,
                                      "interpreted": 2e-4})
    bench = tmp_path / "BENCH_overlap.json"
    bench.write_text(json.dumps(report))
    tool = pathlib.Path(__file__).resolve().parents[1] / "tools" / \
        "calibrate.py"
    base = tmp_path / "BENCH_baseline.json"
    out = tmp_path / "CALIBRATION.json"
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--out",
         str(out), "--write-baseline", str(base)],
        capture_output=True, text=True)
    assert run.returncode == 0, run.stderr
    consts = json.loads(out.read_text())
    # top-level constants: the default family's, as before labels existed
    assert consts["alpha"] == pytest.approx(true["alpha"], rel=1e-3)
    assert consts["overhead"] == pytest.approx(true["overhead"], rel=1e-3)
    # the level_a family fits its own transport constants...
    la = consts["families"]["level_a"]
    assert la["alpha"] == pytest.approx(fam["alpha"], rel=1e-3)
    assert la["beta"] == pytest.approx(fam["beta"], rel=1e-3)
    # ...and one overhead intercept per executor class
    assert consts["overheads"]["level_a:compiled"] == pytest.approx(
        4e-5, rel=1e-3)
    assert consts["overheads"]["level_a:interpreted"] == pytest.approx(
        2e-4, rel=1e-3)
    # exact synthetic data: every per-row ratio is 1
    cal = json.loads(out.read_text())
    assert all(abs(r["ratio"] - 1.0) < 1e-6 for r in cal["rows"].values())
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--gate",
         "--baseline", str(base), "--out", str(out)],
        capture_output=True, text=True)
    assert run.returncode == 0, run.stdout + run.stderr
    assert "executor overhead" in run.stdout
    # compiled overhead regresses to 0.75× interpreted: the per-row
    # ratios barely move (well inside ×tolerance) but the executor
    # assertion fails the gate
    report["level_a"] = level_a_rows({"compiled": 1.5e-4,
                                      "interpreted": 2e-4})
    bench.write_text(json.dumps(report))
    run = subprocess.run(
        [sys.executable, str(tool), "--bench", str(bench), "--gate",
         "--baseline", str(base), "--out", str(out)],
        capture_output=True, text=True)
    assert run.returncode == 1
    assert "compiled executor per-call overhead" in run.stderr
    assert "DRIFT" not in run.stdout


def test_calibrate_history_directory_rolling_window(tmp_path):
    """--history accepts a directory of per-run artifacts; the rolling
    window keeps only the newest N (timestamped names sort
    chronologically), so an ancient outlier stops influencing the fit."""
    import json
    import pathlib
    import subprocess
    import sys
    sched = build("allreduce", "ring", 8)
    size = 1 << 18

    def report(measured):
        feats = {"rounds": sched.cost(1.0, 0.0, 0.0),
                 "wire_bytes": sched.cost(0.0, 1.0, size),
                 "combine_bytes": sched.cost(0.0, 0.0, size, gamma=1.0)}
        return {"modes": {"leg": {"features": feats,
                                  "measured_s": measured}}}

    true_s = 4e-9 * sched.cost(0.0, 1.0, size)
    bench = tmp_path / "BENCH_overlap.json"
    bench.write_text(json.dumps(report(true_s)))
    hist = tmp_path / "bench-history"
    hist.mkdir()
    # oldest artifact is a wild outlier; the next three agree with today
    (hist / "BENCH_overlap-20260101T000000Z.json").write_text(
        json.dumps(report(true_s * 1e4)))
    for i in range(1, 4):
        (hist / f"BENCH_overlap-2026020{i}T000000Z.json").write_text(
            json.dumps(report(true_s)))
    tool = pathlib.Path(__file__).resolve().parents[1] / "tools" / \
        "calibrate.py"
    out = tmp_path / "CALIBRATION.json"

    def fit(window):
        run = subprocess.run(
            [sys.executable, str(tool), "--bench", str(bench), "--out",
             str(out), "--history", str(hist),
             "--history-window", str(window)],
            capture_output=True, text=True)
        assert run.returncode == 0, run.stderr
        data = json.loads(out.read_text())
        return data, run.stdout
    # window 3 drops the outlier: the fit matches today's measurement
    data, stdout = fit(3)
    assert data["n_rows"] == 4                       # bench + 3 newest
    assert stdout.count("history:") == 3
    assert "20260101T000000Z" not in stdout          # oldest pruned
    assert data["rows"]["modes.leg"]["ratio"] == pytest.approx(1.0,
                                                               rel=1e-6)
    # window 0 (unlimited) lets the outlier drag the fit off
    data, stdout = fit(0)
    assert data["n_rows"] == 5 and stdout.count("history:") == 4
    assert data["rows"]["modes.leg"]["ratio"] < 0.5


def test_hierarchical_rejects_segments_at_both_levels():
    """Both executors refuse segments on the fixed composed schedule —
    silently dropping it would fake pipelining (Level B mirrors
    Collectives._resolve)."""
    from repro.core import lowering
    from repro.core import overlap
    with pytest.raises(ValueError, match="segments"):
        lowering.allreduce(None, ("pod", "data"),
                           algorithm="hierarchical", segments=4)
    with pytest.raises(ValueError, match="segments"):
        overlap.sync_grads({"w": np.zeros(4)}, axes=("pod", "data"),
                           hierarchical=True, segments=4)


# ---------------------------------------------------------------------------
# segmented allgather / reduce_scatter rings (Concat reassembly)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("segments", [2, 4])
@pytest.mark.parametrize("executor", ["interpreted", "compiled"])
def test_segmented_allgather_and_reduce_scatter_parity(segments, executor):
    n = 5
    vals = [np.arange(23.0).reshape(1, 23) + 7 * r for r in range(n)]
    coll = Collectives(tac.CommWorld(n), executor=executor)
    base_ag = coll.run_group("allgather", [{"value": v} for v in vals])
    seg_ag = coll.run_group(
        "allgather", [{"value": v, "segments": segments} for v in vals])
    for r in range(n):
        for i in range(n):
            got = np.asarray(seg_ag[r][i])
            assert got.shape == vals[i].shape        # reshaped like "in"
            np.testing.assert_array_equal(got, np.asarray(base_ag[r][i]))
    base_rs = coll.run_group("reduce_scatter", [{"value": v} for v in vals])
    seg_rs = coll.run_group(
        "reduce_scatter", [{"value": v, "segments": segments} for v in vals])
    for r in range(n):
        # Concat of the array_split segments is bit-identical to the
        # unsegmented chunk (split composes with itself).
        np.testing.assert_array_equal(seg_rs[r], base_rs[r])


def test_segmented_rs_pipelines_in_cost_model_and_simulator():
    n, size = 8, float(1 << 24)
    gamma = 8e-10                  # combine-heavy: segmentation must win
    base = build("reduce_scatter", "ring", n)
    seg = build("reduce_scatter", "ring", n, segments=4)
    assert seg.cost(ALPHA, BETA, size, gamma=gamma) < \
        base.cost(ALPHA, BETA, size, gamma=gamma)
    # the discrete-event replay agrees (transport of segment k+1 overlaps
    # the combine of segment k)
    mk = lambda s: simulate.schedule_makespan(
        s, size=size, alpha=ALPHA, beta=BETA, gamma=gamma)
    assert mk(seg) < mk(base)


def test_best_schedule_selects_segments_for_bulk_reduce_scatter():
    s = best_schedule("reduce_scatter", 8, float(1 << 24),
                      alpha=ALPHA, beta=BETA, gamma=1e-9)
    assert (s.algorithm, s.name) == ("ring", "reduce_scatter")
    assert s.segments > 1
    # latency-bound payloads keep the unsegmented log-round schedule
    s = best_schedule("allgather", 8, 64.0, alpha=1e-5, beta=BETA)
    assert s.segments == 1


def test_segmented_builds_rejected_for_unsupported_pairs():
    with pytest.raises(ValueError):
        build("alltoall", "ring", 4, segments=2)
    with pytest.raises(ValueError):
        build("allgather", "doubling", 4, segments=2)
    coll = Collectives(tac.CommWorld(4))
    with pytest.raises(ValueError):
        coll.run_group("allgather", [{"value": np.arange(4.0),
                                      "segments": 2}] * 4,
                       algorithm="doubling")


# ---------------------------------------------------------------------------
# two-tier auto selection (hierarchical candidates under a pod-aware link)
# ---------------------------------------------------------------------------
def test_two_tier_cost_and_hierarchical_auto_selection():
    size = float(1 << 22)
    ring = build("allreduce", "ring", 8)
    # an expensive cross-pod link must make the flat ring cost MORE than
    # under uniform constants (7 of its 8 hops stay intra, 1 crosses)
    def link(src, dst):
        return (ALPHA, BETA) if src // 4 == dst // 4 else (5e-4, 3e-7)
    assert ring.cost(ALPHA, BETA, size, link=link) > \
        ring.cost(ALPHA, BETA, size)
    picked = best_schedule("allreduce", 8, size, alpha=ALPHA, beta=BETA,
                           intra=4, inter_alpha=5e-4, inter_beta=3e-7)
    assert picked.algorithm == "hierarchical"
    assert picked.axes == (("inter", 2), ("intra", 4))
    # degenerate pod structures fall back to the flat candidate set
    flat = best_schedule("allreduce", 8, size, alpha=ALPHA, beta=BETA,
                         intra=8)
    assert flat.algorithm != "hierarchical"


def test_collectives_auto_with_hierarchy_runs_hierarchical():
    n = 8
    vals = [np.arange(32.0) + r for r in range(n)]
    coll = Collectives(tac.CommWorld(n), alpha=1e-6, beta=1e-9,
                       hierarchy=4, inter_alpha=5e-4, inter_beta=3e-7)
    sched = coll._resolve("allreduce", "auto",
                          nbytes=float(1 << 22))
    assert sched.algorithm == "hierarchical"
    out = coll.run_group("allreduce", [{"value": v} for v in vals],
                         algorithm="auto")
    for r in range(n):
        np.testing.assert_array_equal(out[r], np.sum(vals, axis=0))
    with pytest.raises(ValueError):
        Collectives(tac.CommWorld(6), hierarchy=4)   # 4 does not divide 6


def test_load_calibration_families():
    consts = schedule_ir.load_calibration("CALIBRATION.json",
                                          family="level_a")
    assert set(consts) == {"alpha", "beta", "gamma"}
    with pytest.raises(KeyError):
        schedule_ir.load_calibration("CALIBRATION.json",
                                     family="no-such-family")
