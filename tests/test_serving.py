"""Serving tentpole: continuous batching on the collectives runtime.

Covers the PR's acceptance surface:

1. **Request/queue mechanics** — state-machine legality, priority-then-
   FCFS admission, failure re-admission at the head of the class.
2. **Scheduling** — slot-bounded admission order, stepwise priority
   preemption with ``admission_log``/``eviction_log`` evidence, and the
   prefill→decode cache handoff threading adapter state step to step.
3. **Completion legs** — the event-bound and blocking-sentinel legs
   emit bit-identical token streams across all four mode × notify
   combinations, and the event leg (``tac.iwait`` binding device
   completion into task dependencies) beats the blocking sentinel on
   tokens/s AND p99 under worker starvation — the claim
   ``benchmarks/serve_bench.py`` gates in CI.
4. **Failure path** — a rank killed mid-serve surfaces through the
   stepwise taskwait, in-flight requests are evicted to the queue head,
   the world shrinks (ULFM revoke+shrink), and every request still
   finishes with its full, correct token stream.
5. **Deprecation shims** — the pre-``CollectiveOptions`` keyword
   spellings (``hierarchy=``, ``wire=``) and the retired ticket-pool
   entry points warn but keep working.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tac, FaultInjector
from repro.core.collectives import Collectives
from repro.core.executor import TaskRuntime
from repro.core.options import CollectiveOptions, renamed_kwarg
from repro.serving import (Request, RequestQueue, RequestState,
                           ServingEngine, SyntheticAdapter, percentile,
                           token_at)


# ---------------------------------------------------------------------------
# 1. request + queue mechanics
# ---------------------------------------------------------------------------
def test_request_state_machine_legality():
    r = Request(rid=0, prompt=1, gen_len=4)
    assert r.state is RequestState.QUEUED
    r.to(RequestState.PREFILL)
    r.to(RequestState.DECODE)
    with pytest.raises(RuntimeError, match="illegal"):
        r.to(RequestState.QUEUED)
    r.to(RequestState.EVICTED)
    r.reset_for_requeue()
    assert r.state is RequestState.QUEUED
    assert r.incarnation == 1 and r.evictions == 1
    assert r.tokens == [] and r.cache is None
    assert r.chain != Request(rid=0, prompt=1, gen_len=4).chain


def test_queue_priority_then_fcfs_and_push_front():
    q = RequestQueue()
    a = Request(rid=0, prompt=0, gen_len=1, priority=1)
    b = Request(rid=1, prompt=0, gen_len=1, priority=0)
    c = Request(rid=2, prompt=0, gen_len=1, priority=0)
    d = Request(rid=3, prompt=0, gen_len=1, priority=0)
    for r in (a, b, c):
        q.push(r)
    q.push_front(d)      # failure re-admission: head of its class
    assert [q.pop().rid for _ in range(4)] == [3, 1, 2, 0]
    assert q.pop() is None and not q


# ---------------------------------------------------------------------------
# 2. scheduling: admission order, handoff, preemption
# ---------------------------------------------------------------------------
class RecordingAdapter:
    """Synchronous adapter that logs every protocol call."""

    def __init__(self):
        self.calls = []

    def prefill(self, req):
        self.calls.append(("prefill", req.rid, req.incarnation))
        return token_at(req.prompt, 0), ("cache", req.rid, 0)

    def decode(self, req, state, step):
        # the handoff contract: decode must receive the state the
        # PREVIOUS step returned (prefill's cache for step 1)
        assert state == ("cache", req.rid, step - 1)
        self.calls.append(("decode", req.rid, step))
        return token_at(req.prompt, step), ("cache", req.rid, step)

    def detok(self, req, step, tok):
        return int(tok)


@pytest.mark.parametrize("completion", ["event", "blocking"])
def test_prefill_decode_handoff(completion):
    ad = RecordingAdapter()
    eng = ServingEngine(ad, slots=2, completion=completion, num_workers=2)
    reqs = [Request(rid=i, prompt=10 * i, gen_len=3) for i in range(2)]
    rep = eng.run(reqs)
    for r in reqs:
        assert r.state is RequestState.DONE
        assert rep.outputs[r.rid] == [token_at(10 * r.rid, s)
                                      for s in range(3)]
    for rid in (0, 1):
        assert [c for c in ad.calls if c[1] == rid] == [
            ("prefill", rid, 0), ("decode", rid, 1), ("decode", rid, 2)]


def test_slot_bounded_admission_order():
    ad = RecordingAdapter()
    eng = ServingEngine(ad, slots=2, completion="blocking", num_workers=2,
                        sync_every=1)
    reqs = [Request(rid=i, prompt=i, gen_len=2) for i in range(5)]
    eng.run(reqs)
    # FCFS admission, never more than `slots` in flight at once
    assert eng.admission_log == [0, 1, 2, 3, 4]
    assert eng.eviction_log == []


def test_priority_preemption_stepwise():
    """A high-priority arrival evicts the worst in-flight request when
    no slot is free (stepwise mode); the victim re-queues to the back
    of its class, re-runs under a new incarnation, and still emits its
    full stream."""

    class SlowAdapter(RecordingAdapter):
        # pace the rounds so the high-priority arrival deterministically
        # lands while the low-priority request is mid-decode
        def decode(self, req, state, step):
            import time
            time.sleep(0.02)
            return super().decode(req, state, step)

    ad = SlowAdapter()
    eng = ServingEngine(ad, slots=1, completion="blocking", num_workers=2,
                        sync_every=1)
    low = Request(rid=0, prompt=5, gen_len=6, priority=1)
    high = Request(rid=1, prompt=7, gen_len=2, priority=0,
                   arrival_s=0.05)
    rep = eng.run([low, high])
    assert eng.eviction_log == [0]
    assert low.evictions == 1 and low.state is RequestState.DONE
    assert rep.outputs[0] == [token_at(5, s) for s in range(6)]
    assert rep.outputs[1] == [token_at(7, s) for s in range(2)]
    # the victim restarted from prefill under a new incarnation
    assert ("prefill", 0, 1) in ad.calls


def test_explicit_evict_requires_inflight():
    eng = ServingEngine(RecordingAdapter(), slots=1)
    with pytest.raises(KeyError):
        eng.evict(99)


# ---------------------------------------------------------------------------
# 3. completion legs: parity and performance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("notify", ["polling", "continuation"])
@pytest.mark.parametrize("completion", ["event", "blocking"])
def test_token_parity_mode_x_notify(completion, notify):
    """All four completion × notification combinations produce the same
    (deterministic) token streams through the real async adapter."""
    with SyntheticAdapter(dev_ms=1.0, host_rounds=1, streams=8) as ad:
        ad.warmup()
        eng = ServingEngine(ad, slots=4, completion=completion,
                            num_workers=3, notify=notify)
        reqs = [Request(rid=i, prompt=30 + 7 * i, gen_len=4)
                for i in range(6)]
        rep = eng.run(reqs)
    for r in reqs:
        assert rep.outputs[r.rid] == [token_at(r.prompt, s)
                                      for s in range(4)]
    assert rep.tokens == 24 and rep.recoveries == 0


def test_event_leg_outperforms_blocking_sentinel():
    """The PR's acceptance claim, asserted: under worker starvation
    (slots > workers, asynchronous device latency), the event-bound leg
    sustains at least the blocking sentinel's throughput with no worse
    p99 — the blocking leg parks a worker per device wait, the event
    leg frees it at dispatch (tac.iwait -> continuation engine)."""
    with SyntheticAdapter(dev_ms=25.0, host_rounds=8, streams=16) as ad:
        ad.warmup()
        reports = {}
        for leg in ("event", "blocking"):
            # warm pass: pools, runtime, code paths
            ServingEngine(ad, slots=16, completion=leg, num_workers=4) \
                .run([Request(rid=900 + i, prompt=i, gen_len=2)
                      for i in range(4)])
            eng = ServingEngine(ad, slots=16, completion=leg,
                                num_workers=4)
            reports[leg] = eng.run(
                [Request(rid=i, prompt=100 + 17 * i, gen_len=6)
                 for i in range(16)])
    ev, bl = reports["event"], reports["blocking"]
    assert ev.tokens == bl.tokens == 96
    assert ev.tokens_per_s >= bl.tokens_per_s, (
        f"event {ev.tokens_per_s:.0f} < blocking {bl.tokens_per_s:.0f}")
    assert ev.p99_ms <= bl.p99_ms, (
        f"event p99 {ev.p99_ms:.1f} > blocking p99 {bl.p99_ms:.1f}")


def test_event_leg_pushes_through_continuation_engine():
    """The event leg's device handles are push-capable futures: the
    continuation engine must see real attaches (iwait on in-flight
    device work), not the always-ready fast path."""
    with SyntheticAdapter(dev_ms=3.0, host_rounds=1, streams=8) as ad:
        ad.warmup()
        rt = TaskRuntime(num_workers=3)
        eng = ServingEngine(ad, slots=4, completion="event", runtime=rt)
        eng.run([Request(rid=i, prompt=i, gen_len=3) for i in range(4)])
        stats = rt.continuations.stats
        rt.close()
    assert stats["attached"] > 0
    assert stats["completions"] >= stats["attached"]


def test_percentile_nearest_rank():
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert percentile([1.0], 99) == 1.0
    with pytest.raises(ValueError):
        percentile([], 50)


# ---------------------------------------------------------------------------
# 4. failure path: eviction under injected rank failure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("completion", ["event", "blocking"])
def test_eviction_under_rank_failure(completion):
    """Kill a rank mid-serve: the TP collective fails the micro-step,
    the stepwise taskwait surfaces it, in-flight requests evict to the
    queue head, the world shrinks, and every request re-runs to a full
    correct stream under a new incarnation."""
    with SyntheticAdapter(dev_ms=1.0, host_rounds=1, streams=8) as ad:
        ad.warmup()
        w = tac.CommWorld(3)
        inj = FaultInjector(w)
        killed = []

        def on_round(eng, rnd):
            if rnd == 4 and not killed:
                inj.kill(2)
                killed.append(2)

        eng = ServingEngine(ad, slots=3, completion=completion,
                            num_workers=4, sync_every=1, world=w,
                            tp_elems=4, on_round=on_round)
        reqs = [Request(rid=i, prompt=50 + 11 * i, gen_len=5)
                for i in range(6)]
        rep = eng.run(reqs)
    assert killed and rep.recoveries == 1
    assert rep.evictions > 0 and eng.eviction_log
    # the engine rebuilt its collectives over the shrunken world
    assert eng._coll.world.size == 2
    for r in reqs:
        assert r.state is RequestState.DONE
        assert rep.outputs[r.rid] == [token_at(r.prompt, s)
                                      for s in range(5)]
    # evicted requests re-ran under a bumped incarnation
    assert any(r.incarnation > 0 for r in reqs)


def test_failed_step_does_not_finish_request():
    """A request whose micro-step failed must NOT retire DONE with a
    short stream (the force-released finish task runs anyway); it stays
    in flight for the failure sweep."""

    class FailOnce(RecordingAdapter):
        def __init__(self):
            super().__init__()
            self.failed = False

        def decode(self, req, state, step):
            if step == 2 and not self.failed:
                self.failed = True
                raise tac.RankFailedError("injected")
            return super().decode(req, state, step)

    ad = FailOnce()
    w = tac.CommWorld(2)
    eng = ServingEngine(ad, slots=1, completion="blocking",
                        num_workers=2, sync_every=1, world=w)
    req = Request(rid=0, prompt=3, gen_len=4)
    rep = eng.run([req])
    assert req.state is RequestState.DONE
    assert rep.outputs[0] == [token_at(3, s) for s in range(4)]
    assert req.evictions == 1 and eng.recoveries == 1


# ---------------------------------------------------------------------------
# 5. deprecation shims (pre-CollectiveOptions spellings, ticket pool)
# ---------------------------------------------------------------------------
def test_collectives_hierarchy_kwarg_warns():
    w = tac.CommWorld(4)
    with pytest.warns(DeprecationWarning, match="hierarchy"):
        c = Collectives(w, hierarchy=2)
    assert c.hierarchy == 2


def test_renamed_kwarg_contract():
    with pytest.warns(DeprecationWarning, match="old_k"):
        assert renamed_kwarg("old_k", 5, "new_k", None) == 5
    assert renamed_kwarg("old_k", None, "new_k", 7) == 7
    with pytest.raises(TypeError, match="both"):
        renamed_kwarg("old_k", 5, "new_k", 7)


def test_lowering_wire_kwarg_warns():
    """``lowering.allreduce(wire=...)`` maps onto ``stage_wire=``: the
    shim warns, and the value lands where ``stage_wire`` lands (the
    native path rejects BOTH spellings with the same message — proof
    the deprecated kwarg reached the canonical slot).  The multi-device
    numeric path is covered by tests/test_lowering.py."""
    from repro.core import lowering
    x = jnp.ones((8,), jnp.float32)
    with pytest.warns(DeprecationWarning, match="stage_wire"):
        with pytest.raises(ValueError, match="stage_impl=/stage_wire="):
            lowering.allreduce(x, ("data",), algorithm="native",
                               wire="bf16")
    with pytest.raises(ValueError, match="stage_impl=/stage_wire="):
        lowering.allreduce(x, ("data",), algorithm="native",
                           stage_wire="bf16")


def test_sync_grads_wire_kwarg_warns():
    from repro.core.overlap import sync_grads
    x = jnp.ones((4,), jnp.float32)
    with pytest.warns(DeprecationWarning, match="reduce_dtype"):
        out = sync_grads({"w": x}, axes=(), mode="fused", wire="fp32")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(x))


def test_collective_options_spec():
    opts = CollectiveOptions(algorithm="ring", segments=4)
    algorithm, segments = CollectiveOptions.merge(
        opts, algorithm=None, segments=1)
    assert (algorithm, segments) == ("ring", 4)
    # explicit keyword beats the spec
    [algorithm] = CollectiveOptions.merge(
        CollectiveOptions(algorithm="ring"), algorithm="recursive")
    assert algorithm == "recursive"
    with pytest.raises(ValueError, match="not.*applicable"):
        CollectiveOptions(stage_wire="bf16").take(algorithm=None)


def test_ticket_pool_shims_warn_and_delegate():
    rt = TaskRuntime(num_workers=1)
    try:
        with pytest.warns(DeprecationWarning, match="ticket pool"):
            pool = tac._TicketPool(rt)
        h = tac.EventHandle()
        with pytest.warns(DeprecationWarning, match="ticket pool"):
            ticket = tac._Ticket(h)
        assert pool.pending == rt.continuations.polled
        with pytest.warns(DeprecationWarning, match="ticket pool"):
            assert tac._use_continuations(rt) is True
        with pytest.warns(DeprecationWarning, match="ticket pool"):
            tac._pool(rt)
    finally:
        rt.close()
