"""Tests for the pause/resume and external-events APIs (paper §4)."""

import threading
import time

import pytest

from repro.core import (TaskRuntime, get_current_blocking_context,
                        block_current_task, unblock_task,
                        get_current_event_counter,
                        increase_current_task_event_counter,
                        decrease_task_event_counter)


@pytest.mark.parametrize("mode", ["spare-thread", "nested"])
def test_pause_resume_roundtrip(mode):
    """Fig. 1: a task pauses; another thread unblocks it; it resumes."""
    ctx_box = {}
    resumed = []

    def blocker():
        ctx = get_current_blocking_context()
        ctx_box["ctx"] = ctx
        block_current_task(ctx)
        resumed.append(True)

    with TaskRuntime(num_workers=2, block_mode=mode) as rt:
        rt.submit(blocker)
        for _ in range(200):
            if "ctx" in ctx_box:
                break
            time.sleep(0.01)
        assert "ctx" in ctx_box
        unblock_task(ctx_box["ctx"])
        rt.taskwait()
    assert resumed == [True]


def test_paused_task_frees_the_core():
    """While one task is paused, the worker must run other ready tasks —
    with a single designated worker (spare-thread mode spawns the spare)."""
    ctx_box = {}
    progressed = threading.Event()

    def blocker():
        ctx = get_current_blocking_context()
        ctx_box["ctx"] = ctx
        block_current_task(ctx)

    def other():
        progressed.set()

    with TaskRuntime(num_workers=1) as rt:
        rt.submit(blocker)
        rt.submit(other)
        assert progressed.wait(timeout=5.0), \
            "core was not handed to the other task while paused"
        while "ctx" not in ctx_box:
            time.sleep(0.005)
        unblock_task(ctx_box["ctx"])
        rt.taskwait()
    assert rt.stats["task_blocks"] == 1
    assert rt.stats["task_resumes"] == 1


def test_blocking_context_single_use():
    errors = []

    def body():
        ctx = get_current_blocking_context()
        ctx2 = get_current_blocking_context()  # invalidates ctx
        try:
            block_current_task(ctx)
        except RuntimeError as e:
            errors.append(str(e))
        unblock_task(ctx2)        # pre-set: block returns immediately
        block_current_task(ctx2)

    with TaskRuntime(num_workers=2) as rt:
        rt.submit(body)
        rt.taskwait()
    assert errors and "stale" in errors[0]


def test_external_events_defer_release():
    """§4.3/Fig. 2: the task finishes but its successors only become ready
    once the bound external event is fulfilled."""
    counter_box = {}
    order = []

    def producer():
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, 1)
        counter_box["cnt"] = cnt
        order.append("producer-finished")

    def consumer():
        order.append("consumer")

    with TaskRuntime(num_workers=4) as rt:
        rt.submit(producer, out=["buf"])
        rt.submit(consumer, in_=["buf"])
        # Give the runtime ample opportunity to (incorrectly) run consumer.
        deadline = time.time() + 0.3
        while time.time() < deadline:
            time.sleep(0.01)
        assert order == ["producer-finished"], \
            "dependencies released before the external event was fulfilled"
        decrease_task_event_counter(counter_box["cnt"], 1)
        rt.taskwait()
    assert order == ["producer-finished", "consumer"]


def test_events_completing_before_task_finish():
    """§4.3: if all events complete before the task finishes, dependencies
    are released as soon as the task finishes its execution."""
    order = []
    release_gate = threading.Event()

    def producer():
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, 2)
        decrease_task_event_counter(cnt, 2)  # both events fulfilled early
        release_gate.wait(timeout=5.0)
        order.append("producer")

    with TaskRuntime(num_workers=2) as rt:
        rt.submit(producer, out=["d"])
        rt.submit(lambda: order.append("consumer"), in_=["d"])
        release_gate.set()
        rt.taskwait()
    assert order == ["producer", "consumer"]


def test_only_owner_can_increase():
    box = {}

    def body():
        box["cnt"] = get_current_event_counter()

    with TaskRuntime(num_workers=1) as rt:
        rt.submit(body)
        rt.taskwait()
        with pytest.raises(RuntimeError):
            increase_current_task_event_counter(box["cnt"], 1)


def test_event_counter_underflow_guard():
    box = {}

    def body():
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, 1)
        box["cnt"] = cnt

    with TaskRuntime(num_workers=1) as rt:
        rt.submit(body)
        while "cnt" not in box:
            time.sleep(0.005)
        decrease_task_event_counter(box["cnt"], 1)
        rt.taskwait()
    with pytest.raises(RuntimeError):
        decrease_task_event_counter(box["cnt"], 1)


def test_polling_service_periodic_and_unregister():
    from repro.core import PollingRegistry
    reg = PollingRegistry(interval=0.001)
    calls = []
    reg.register_polling_service("svc", lambda d: calls.append(d) or False, 7)
    reg.start()
    time.sleep(0.05)
    reg.stop()
    assert len(calls) >= 5 and calls[0] == 7
    n = len(calls)
    reg.unregister_polling_service("svc", None, None)  # no match: stays
    assert reg.num_services == 1

    # auto-unregister on truthy return
    reg2 = PollingRegistry(interval=0.001)
    reg2.register_polling_service("once", lambda d: True, None)
    reg2.poll_once()
    assert reg2.num_services == 0


def test_unregister_waits_for_inflight_callback_despite_concurrent_gc():
    """Unregister must not return while the callback is still executing,
    even when a concurrent poller _gc()s the done-marked service off the
    registry list (the reference must be captured in the SAME locked pass
    that marks it done — a second list snapshot can miss it)."""
    from repro.core import PollingRegistry
    reg = PollingRegistry()
    entered = threading.Event()
    release = threading.Event()
    state = {"running": False}

    def slow_cb(_data):
        state["running"] = True
        entered.set()
        release.wait(5)
        state["running"] = False
        return False

    reg.register_polling_service("slow", slow_cb)
    poller = threading.Thread(target=reg.poll_once)
    poller.start()
    assert entered.wait(5)

    unreg_done = threading.Event()

    def unreg():
        reg.unregister_polling_service("slow", slow_cb)
        unreg_done.set()

    u = threading.Thread(target=unreg)
    u.start()
    # The concurrent poll_once tail: hammer _gc() while unregister runs —
    # the service vanishes from the list, but unregister already holds
    # its reference and must stay blocked on the callback's lock.
    for _ in range(50):
        reg._gc()
        time.sleep(0.001)
    assert state["running"]
    assert not unreg_done.is_set()
    release.set()
    u.join(5)
    poller.join(5)
    assert unreg_done.is_set()
    assert not state["running"]     # returned only after the callback left
    assert reg.num_services == 0


def test_unregister_removes_exactly_one_duplicate():
    """register x2 + unregister x1 leaves ONE live registration (the old
    code marked every (name, fn, data) match done at once)."""
    from repro.core import PollingRegistry
    reg = PollingRegistry()
    calls = []

    def cb(data):
        calls.append(data)
        return False

    reg.register_polling_service("dup", cb, 7)
    reg.register_polling_service("dup", cb, 7)
    assert reg.num_services == 2

    reg.unregister_polling_service("dup", cb, 7)
    assert reg.num_services == 1
    reg.poll_once()
    assert calls == [7]             # the survivor still fires

    reg.unregister_polling_service("dup", cb, 7)
    assert reg.num_services == 0
    reg.poll_once()
    assert calls == [7]
