"""The unified metrics registry: relaxed counters, gauges, histograms.

The runtime's hot paths used to keep ad-hoc ``stats`` dicts whose every
increment took the owning engine's lock
(:class:`repro.core.continuations.ContinuationEngine` paid one lock
round-trip per attach, per completion, and per dispatch).  This module
replaces that with instruments designed for the emit side:

* :class:`Counter` — **striped** per-thread cells: ``inc()`` touches only
  the calling thread's private cell (a one-element list; CPython list
  item assignment is atomic under the GIL), so the hot path takes no lock
  and suffers no cache-line ping-pong.  ``value`` sums the cells under a
  lock — totals are *exact* (each increment lands in exactly one cell;
  the relaxation is only in ordering), which
  ``tests/test_continuations.py`` asserts by reconciling engine totals
  against ground truth after a multi-threaded run.
* :class:`Gauge` — a lock-protected level (in-flight handles, queue
  depths); emission sites only touch gauges when tracing/metrics are
  wanted, so the lock is off the default path.
* :class:`Histogram` — power-of-two bucketed latencies (dispatch latency,
  token latency) with exact count/sum/min/max.

:data:`REGISTRY` is the process-wide registry; engines may also own
private instruments (the continuation engine pre-binds its counters as
attributes so the emit site is one method call).
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY"]


class Counter:
    """A monotonically increasing counter with per-thread cells.

    ``inc`` is lock-free after a thread's first increment; ``value`` is
    an exact total (sum over cells).  Decrements are not supported — use
    a :class:`Gauge` for levels.
    """

    __slots__ = ("name", "_lock", "_cells", "_tls")

    def __init__(self, name: str = "") -> None:
        self.name = name
        self._lock = threading.Lock()
        self._cells: List[List[int]] = []
        self._tls = threading.local()

    def inc(self, n: int = 1) -> None:
        try:
            cell = self._tls.cell
        except AttributeError:
            cell = [0]
            with self._lock:
                self._cells.append(cell)
            self._tls.cell = cell
        cell[0] += n

    @property
    def value(self) -> int:
        with self._lock:
            return sum(cell[0] for cell in self._cells)

    def reset(self) -> None:
        with self._lock:
            for cell in self._cells:
                cell[0] = 0

    def __repr__(self) -> str:    # pragma: no cover - debugging aid
        return f"<Counter {self.name!r} {self.value}>"


class Gauge:
    """A settable level (in-flight operations, queue depth)."""

    __slots__ = ("name", "_lock", "_value", "_max")

    def __init__(self, name: str = "") -> None:
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n
            if self._value > self._max:
                self._max = self._value

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value
            if value > self._max:
                self._max = value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    @property
    def high_water(self) -> float:
        """The maximum level ever set (peak in-flight / peak depth)."""
        with self._lock:
            return self._max

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0
            self._max = 0.0

    def __repr__(self) -> str:    # pragma: no cover - debugging aid
        return f"<Gauge {self.name!r} {self.value}>"


class Histogram:
    """Power-of-two bucketed samples (latencies, sizes).

    Bucket ``k`` counts samples in ``(2^(k-1)·base, 2^k·base]`` with
    ``base`` the smallest resolvable magnitude (default 1 µs for
    second-denominated latencies).  Exact count/sum/min/max ride along,
    so means are exact and the buckets only approximate quantiles.
    """

    __slots__ = ("name", "base", "_lock", "_buckets", "_count", "_sum",
                 "_min", "_max")

    N_BUCKETS = 64

    def __init__(self, name: str = "", base: float = 1e-6) -> None:
        self.name = name
        self.base = base
        self._lock = threading.Lock()
        self._buckets = [0] * self.N_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def _index(self, x: float) -> int:
        if x <= self.base:
            return 0
        return min(self.N_BUCKETS - 1,
                   1 + int(math.floor(math.log2(x / self.base))))

    def observe(self, x: float) -> None:
        with self._lock:
            self._buckets[self._index(x)] += 1
            self._count += 1
            self._sum += x
            if self._min is None or x < self._min:
                self._min = x
            if self._max is None or x > self._max:
                self._max = x

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def summary(self) -> Dict[str, float]:
        with self._lock:
            return {"count": float(self._count), "sum": self._sum,
                    "mean": self._sum / self._count if self._count else 0.0,
                    "min": self._min or 0.0, "max": self._max or 0.0}

    def reset(self) -> None:
        with self._lock:
            self._buckets = [0] * self.N_BUCKETS
            self._count = 0
            self._sum = 0.0
            self._min = None
            self._max = None

    def __repr__(self) -> str:    # pragma: no cover - debugging aid
        return f"<Histogram {self.name!r} n={self.count}>"


class MetricsRegistry:
    """Named instruments, created on first use and shared thereafter."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, **kwargs):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, **kwargs)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, base: float = 1e-6) -> Histogram:
        return self._get(name, Histogram, base=base)

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        """A snapshot of every instrument (for reports / otherData)."""
        with self._lock:
            items = list(self._instruments.items())
        out: Dict[str, Dict[str, float]] = {}
        for name, inst in items:
            if isinstance(inst, Counter):
                out[name] = {"value": float(inst.value)}
            elif isinstance(inst, Gauge):
                out[name] = {"value": inst.value,
                             "high_water": inst.high_water}
            elif isinstance(inst, Histogram):
                out[name] = inst.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            items = list(self._instruments.values())
        for inst in items:
            inst.reset()             # type: ignore[attr-defined]


#: The process-wide registry.
REGISTRY = MetricsRegistry()
