"""Trace-file validation + summary CLI: ``python -m repro.obs out.json``.

Validates each given trace file against :data:`repro.obs.SPAN_SCHEMA`
(exit 1 on any violation — the CI ``obs-smoke`` job gates on this) and
prints event counts, per-rank overlap fractions, and straggler scores.
"""

from __future__ import annotations

import json
import sys

from .analysis import summarize
from .trace import validate_trace


def main(argv=None) -> int:
    paths = sys.argv[1:] if argv is None else list(argv)
    if not paths:
        print("usage: python -m repro.obs TRACE.json [TRACE.json ...]",
              file=sys.stderr)
        return 2
    status = 0
    for path in paths:
        with open(path) as fh:
            doc = json.load(fh)
        errors = validate_trace(doc)
        events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
        summary = summarize(events)
        print(f"== {path}: {summary['events']} events, "
              f"ranks={summary['ranks']}, "
              f"wall={summary['wall_us'] / 1e3:.2f} ms")
        for key, n in summary["counts"].items():
            print(f"   {key:40s} {n}")
        print(f"   overlap fraction (all ranks): "
              f"{summary['overlap_fraction']:.3f}")
        for r, f in summary["per_rank_overlap"].items():
            print(f"   overlap[rank {r}] = {f:.3f}")
        for r, s in summary["straggler_scores"].items():
            print(f"   straggler[rank {r}]: busy={s['busy']:.4f}s "
                  f"tasks={int(s['tasks'])} score={s['score']:.2f}")
        if isinstance(doc, dict) and doc.get("otherData"):
            print(f"   otherData: {json.dumps(doc['otherData'])[:400]}")
        if errors:
            status = 1
            print(f"   INVALID: {len(errors)} schema violations",
                  file=sys.stderr)
            for err in errors[:10]:
                print(f"     {err}", file=sys.stderr)
        else:
            print("   schema: OK")
    return status


if __name__ == "__main__":
    sys.exit(main())
