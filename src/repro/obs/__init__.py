"""``repro.obs`` — tracing, metrics registry, and derived observability.

The cross-cutting observability layer of the runtime:

* :mod:`repro.obs.trace` — bounded per-thread ring-buffer tracing with
  Chrome trace-event / Perfetto JSON export (:func:`export_trace`) and a
  validated span schema shared with the simulator's replay.
* :mod:`repro.obs.registry` — striped lock-free counters, gauges and
  histograms (the unified metrics registry the continuation engine's
  hot-path stats moved onto).
* :mod:`repro.obs.analysis` — derived headline metrics:
  :func:`overlap_fraction` (share of communication hidden under
  compute — the paper's central claim as a number) and
  :func:`straggler_scores`.
* :mod:`repro.obs.metrics` — shared helpers (``percentile``,
  ``TokenRecord``, ``MetricSink``) formerly in ``repro.serving.metrics``.

Tracing is **off by default** (:class:`NullTracer`); every
instrumentation site in the runtime guards on the live module flag
``repro.obs.trace.TRACING`` so the disabled cost is one attribute read.
Enable it with::

    from repro import obs
    with obs.tracing() as tr:
        ...                       # run the workload
        obs.export_trace("out.json", tracer=tr)

``python -m repro.obs out.json`` validates a trace file against the
schema and prints its summary.
"""

from __future__ import annotations

from .analysis import (overlap_fraction, per_rank_overlap, straggler_scores,
                       summarize)
from .metrics import MetricSink, TokenRecord, percentile
from .registry import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry
from .trace import (CATEGORIES, DEFAULT_CAPACITY, SPAN_SCHEMA, NullTracer,
                    Tracer, assert_valid_trace, counter_event, export_trace,
                    get_tracer, instant_event, set_tracer, span_event,
                    tracing, validate_trace)

__all__ = [
    # trace
    "Tracer", "NullTracer", "set_tracer", "get_tracer", "tracing",
    "export_trace", "validate_trace", "assert_valid_trace",
    "span_event", "instant_event", "counter_event",
    "CATEGORIES", "SPAN_SCHEMA", "DEFAULT_CAPACITY",
    "TRACING", "TRACER",
    # registry
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    # analysis
    "overlap_fraction", "per_rank_overlap", "straggler_scores", "summarize",
    # metrics helpers
    "percentile", "TokenRecord", "MetricSink",
]


def __getattr__(name: str):
    # TRACING / TRACER are *live* module globals of repro.obs.trace —
    # forwarding instead of re-exporting keeps `repro.obs.TRACING`
    # truthful after set_tracer() flips the flag.
    if name in ("TRACING", "TRACER"):
        from . import trace
        return getattr(trace, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
