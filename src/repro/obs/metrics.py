"""Shared metric helpers (moved from ``repro.serving.metrics``).

``percentile``, :class:`TokenRecord` and :class:`MetricSink` started life
in the serving layer but are generic observability primitives; they live
here so every layer (benchmarks, reports, serving) shares one
implementation.  ``repro.serving.metrics`` keeps deprecated re-export
shims.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import List

__all__ = ["percentile", "TokenRecord", "MetricSink"]


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        raise ValueError("percentile of an empty list")
    xs = sorted(values)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


@dataclasses.dataclass(frozen=True)
class TokenRecord:
    """One emitted token: which request/step, and its latency window.

    ``t_submit`` is when the scheduler handed the decode micro-step to
    the runtime, ``t_emit`` when the host detokeniser finished with the
    token — so the latency covers device compute, completion
    notification, and host post-processing, which is exactly the window
    the event-bound vs blocking-sentinel legs differ in.
    """

    rid: int
    step: int
    t_submit: float
    t_emit: float

    @property
    def latency_s(self) -> float:
        return self.t_emit - self.t_submit


class MetricSink:
    """Thread-safe collector the engine's tasks append records to."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: List[TokenRecord] = []

    def emit(self, rec: TokenRecord) -> None:
        with self._lock:
            self._records.append(rec)

    @property
    def records(self) -> List[TokenRecord]:
        with self._lock:
            return list(self._records)
