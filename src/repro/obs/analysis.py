"""Derived metrics over trace events: overlap fractions, straggler scores.

These functions consume the raw event dictionaries of
:mod:`repro.obs.trace` — from the host tracer or from the simulator's
replay (:func:`repro.core.simulate.trace_events`), which emit the same
schema — and turn the timeline into the paper's headline numbers:

* :func:`overlap_fraction` — the share of communication time hidden
  under compute.  Communication time is the union of ``handle/inflight``
  spans (operation posted, not yet complete — §4.3's deferred-release
  window); compute time is the union of ``task/run`` spans whose
  ``label`` is ``"compute"``.  A fraction of 1.0 means every in-flight
  microsecond had compute running beside it (perfect overlap, the
  non-blocking mode's goal); 0.0 means communication was fully exposed
  (the sentinel's serialisation).
* :func:`straggler_scores` — per-rank busy-time slowdown vs the median
  rank, the signal behind the executor's speculative re-execution
  (``speculative_timeout``) now derivable from any trace.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["overlap_fraction", "per_rank_overlap", "straggler_scores",
           "summarize"]

Interval = Tuple[float, float]


# ---------------------------------------------------------------------------
# Interval algebra.
# ---------------------------------------------------------------------------
def _union(intervals: Sequence[Interval]) -> List[Interval]:
    """Merge overlapping/touching intervals; returns a sorted disjoint set."""
    out: List[Interval] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1] = (out[-1][0], e)
        else:
            out.append((s, e))
    return out


def _intersect(a: Sequence[Interval],
               b: Sequence[Interval]) -> List[Interval]:
    """Intersection of two disjoint sorted interval sets (two-pointer)."""
    out: List[Interval] = []
    i = j = 0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if e > s:
            out.append((s, e))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _measure(intervals: Sequence[Interval]) -> float:
    return sum(e - s for s, e in intervals)


def _spans(events: Sequence[Dict[str, Any]], cat: str, name: str, *,
           rank: Optional[int] = None,
           label: Optional[str] = None) -> List[Interval]:
    out: List[Interval] = []
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != cat \
                or ev.get("name") != name:
            continue
        args = ev.get("args") or {}
        if rank is not None and args.get("rank") != rank:
            continue
        if label is not None and args.get("label") != label:
            continue
        ts = ev["ts"]
        out.append((ts, ts + ev["dur"]))
    return out


# ---------------------------------------------------------------------------
# Overlap accounting.
# ---------------------------------------------------------------------------
def overlap_fraction(events: Sequence[Dict[str, Any]], *,
                     rank: Optional[int] = None) -> float:
    """Fraction of in-flight communication time covered by compute.

    ``|union(inflight) ∩ union(compute runs)| / |union(inflight)|`` over
    the given events, optionally restricted to one rank (events carry
    their rank in ``args["rank"]``; unattributed events only count in the
    unrestricted call).  Returns 0.0 when no communication was in flight.
    """
    comm = _union(_spans(events, "handle", "inflight", rank=rank))
    if not comm:
        return 0.0
    compute = _union(_spans(events, "task", "run", rank=rank,
                            label="compute"))
    return _measure(_intersect(comm, compute)) / _measure(comm)


def per_rank_overlap(events: Sequence[Dict[str, Any]]) -> Dict[int, float]:
    """Per-rank overlap fractions, keyed by ``args["rank"]``.

    Only ranks that had at least one attributed in-flight span appear.
    """
    ranks = sorted({(ev.get("args") or {}).get("rank")
                    for ev in events
                    if ev.get("ph") == "X" and ev.get("cat") == "handle"
                    and isinstance((ev.get("args") or {}).get("rank"), int)})
    return {r: overlap_fraction(events, rank=r) for r in ranks}


# ---------------------------------------------------------------------------
# Straggler accounting.
# ---------------------------------------------------------------------------
def straggler_scores(
        events: Sequence[Dict[str, Any]]) -> Dict[int, Dict[str, float]]:
    """Per-rank busy time and slowdown score vs the median rank.

    Busy time is the sum of ``task/run`` span durations attributed to
    each rank; ``score`` is that rank's busy time divided by the median
    across ranks (1.0 == median pace; the executor's speculative
    re-execution targets scores well above 1).  Returns
    ``{rank: {"busy": seconds, "tasks": n, "score": x}}``.
    """
    busy: Dict[int, float] = {}
    count: Dict[int, int] = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != "task" \
                or ev.get("name") != "run":
            continue
        rank = (ev.get("args") or {}).get("rank")
        if not isinstance(rank, int):
            continue
        busy[rank] = busy.get(rank, 0.0) + ev["dur"]
        count[rank] = count.get(rank, 0) + 1
    if not busy:
        return {}
    ordered = sorted(busy.values())
    median = ordered[len(ordered) // 2]
    return {r: {"busy": busy[r] / 1e6, "tasks": float(count[r]),
                "score": busy[r] / median if median > 0 else 1.0}
            for r in sorted(busy)}


# ---------------------------------------------------------------------------
# Summaries (the `python -m repro.obs` CLI and CI smoke use this).
# ---------------------------------------------------------------------------
def summarize(events: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Counts per (cat, name), the rank set, and headline metrics."""
    counts: Dict[str, int] = {}
    ranks = set()
    t_min, t_max = None, None
    for ev in events:
        key = f"{ev.get('cat', '?')}/{ev.get('name', '?')}[{ev.get('ph')}]"
        counts[key] = counts.get(key, 0) + 1
        r = (ev.get("args") or {}).get("rank")
        if isinstance(r, int):
            ranks.add(r)
        ts = ev.get("ts")
        if isinstance(ts, (int, float)):
            end = ts + ev.get("dur", 0.0) if ev.get("ph") == "X" else ts
            t_min = ts if t_min is None else min(t_min, ts)
            t_max = end if t_max is None else max(t_max, end)
    return {
        "events": sum(counts.values()),
        "counts": dict(sorted(counts.items())),
        "ranks": sorted(ranks),
        "wall_us": (t_max - t_min) if t_min is not None else 0.0,
        "overlap_fraction": overlap_fraction(events),
        "per_rank_overlap": per_rank_overlap(events),
        "straggler_scores": straggler_scores(events),
    }
