"""Structured task-timeline tracing (Chrome trace-event / Perfetto JSON).

The paper's two APIs are *scheduling* claims — a blocking wait pauses the
task instead of the core (§4.1) and an event-bound operation defers the
task's dependency release to completion time (§4.3) — and end-to-end bench
ratios only show their effect.  This module records the mechanism itself:
every layer of the runtime emits **span events** (task run/pause, handle
in-flight windows, continuation dispatches, collective round advances,
serving micro-steps) into bounded per-thread ring buffers, exported as one
Chrome trace-event JSON document that loads directly in
`ui.perfetto.dev <https://ui.perfetto.dev>`_ or ``chrome://tracing``.

Design constraints, in order:

1. **Disabled means free.**  Tracing is off by default.  Every
   instrumentation site in the runtime is guarded by a single module
   attribute read (``if trace.TRACING: ...``) — no tracer method call, no
   argument packing, nothing allocated on the disabled path.  The bound is
   asserted by ``benchmarks/overlap_bench.py`` (``obs.null`` sentinel row:
   guard cost ≤ 2% of the hot-path work it guards).
2. **Enabled means bounded.**  Each emitting thread appends to its own
   ring buffer (``collections.deque(maxlen=capacity)``) — no lock on the
   emit path after the first event per thread, and a runaway run
   overwrites its oldest events instead of growing without bound.
3. **One schema, two producers.**  The host tracer and the discrete-event
   simulator (:func:`repro.core.simulate.trace_events`) emit the *same*
   event dictionaries, so expected-vs-measured timelines diff directly
   and :func:`repro.obs.analysis.overlap_fraction` computes the paper's
   headline number from either source.

Timestamps ride ``time.monotonic()`` and are exported in microseconds
relative to the tracer's epoch (trace-event convention).  ``pid`` carries
the logical rank (0 when unattributed) and ``tid`` a small per-thread
index, so Perfetto renders one process row per rank with one track per
worker thread.
"""

from __future__ import annotations

import collections
import contextlib
import json
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "CATEGORIES", "SPAN_SCHEMA", "DEFAULT_CAPACITY",
    "Tracer", "NullTracer", "TRACER", "TRACING",
    "set_tracer", "get_tracer", "tracing",
    "span_event", "instant_event", "counter_event",
    "export_trace", "validate_trace", "assert_valid_trace",
]

DEFAULT_CAPACITY = 65536

#: Event categories, one per instrumented layer.
CATEGORIES = ("task", "handle", "continuation", "collective", "serving")

#: The span schema both producers (host tracer, simulator replay) follow:
#: per category, which complete-span (``ph="X"``) and instant (``ph="i"``)
#: names may appear.  ``validate_trace`` enforces it.
SPAN_SCHEMA: Dict[str, Dict[str, tuple]] = {
    # Task lifecycle (executor): submit -> run (pause/resume nested within
    # the run span) -> release; speculate marks a straggler re-enqueue.
    "task": {"spans": ("run", "pause"),
             "instants": ("submit", "release", "speculate")},
    # Handle lifecycle (tac): the inflight span opens at post time and
    # closes at complete/fail; match marks eager matching, bind marks
    # iwait/iwaitall event binding, dep-release the deferred dependency
    # release of §4.3 firing from the completion callback.
    "handle": {"spans": ("inflight",),
               "instants": ("post", "match", "complete", "bind",
                            "dep-release")},
    # Continuation engine: attach and queue->callback dispatch.
    "continuation": {"spans": (), "instants": ("attach", "dispatch")},
    # Collective machines / compiled programs: one resolved wait == one
    # round advanced.
    "collective": {"spans": (), "instants": ("round",)},
    # Serving micro-steps.
    "serving": {"spans": ("device_step", "detok"),
                "instants": ("token",)},
}


# ---------------------------------------------------------------------------
# Raw event constructors — shared by the host tracer and the simulator.
# ---------------------------------------------------------------------------
def span_event(cat: str, name: str, ts_us: float, dur_us: float, *,
               rank: Optional[int] = None, tid: int = 0,
               **args: Any) -> Dict[str, Any]:
    """A complete span (``ph="X"``) in exported form (timestamps in µs)."""
    if rank is not None:
        args.setdefault("rank", rank)
    return {"ph": "X", "cat": cat, "name": name,
            "ts": float(ts_us), "dur": max(0.0, float(dur_us)),
            "pid": 0 if rank is None else int(rank), "tid": int(tid),
            "args": args}


def instant_event(cat: str, name: str, ts_us: float, *,
                  rank: Optional[int] = None, tid: int = 0,
                  **args: Any) -> Dict[str, Any]:
    """An instant event (``ph="i"``, thread scope) in exported form."""
    if rank is not None:
        args.setdefault("rank", rank)
    return {"ph": "i", "s": "t", "cat": cat, "name": name,
            "ts": float(ts_us),
            "pid": 0 if rank is None else int(rank), "tid": int(tid),
            "args": args}


def counter_event(name: str, value: float, ts_us: float, *,
                  rank: Optional[int] = None, tid: int = 0) -> Dict[str, Any]:
    """A counter sample (``ph="C"``) in exported form."""
    return {"ph": "C", "name": name, "ts": float(ts_us),
            "pid": 0 if rank is None else int(rank), "tid": int(tid),
            "args": {name: float(value)}}


# ---------------------------------------------------------------------------
# Tracers.
# ---------------------------------------------------------------------------
class NullTracer:
    """The default tracer: every method is a no-op, ``events()`` is empty.

    Instrumentation sites never even reach these methods — they are
    guarded by the module-level :data:`TRACING` flag — so the disabled
    cost is one attribute read per site, not a call.
    """

    capacity = 0

    def span(self, cat: str, name: str, t0: float, t1: float, *,
             rank: Optional[int] = None, **args: Any) -> None:
        pass

    def instant(self, cat: str, name: str, *, t: Optional[float] = None,
                rank: Optional[int] = None, **args: Any) -> None:
        pass

    def counter(self, name: str, value: float, *,
                rank: Optional[int] = None) -> None:
        pass

    def events(self) -> List[Dict[str, Any]]:
        return []

    def clear(self) -> None:
        pass


class Tracer:
    """Bounded per-thread ring-buffer tracer.

    Each emitting thread gets its own ``deque(maxlen=capacity)`` — created
    (and registered under the tracer's lock) on that thread's first event,
    lock-free afterwards.  ``events()`` merges all rings sorted by
    timestamp.  Span inputs are ``time.monotonic()`` seconds; storage and
    export are µs relative to the tracer's construction epoch.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.epoch = time.monotonic()
        self._lock = threading.Lock()
        self._rings: List[collections.deque] = []
        self._tls = threading.local()

    # -- emit path -----------------------------------------------------------
    def _ring(self) -> collections.deque:
        try:
            return self._tls.ring
        except AttributeError:
            ring: collections.deque = collections.deque(maxlen=self.capacity)
            with self._lock:
                self._tls.tid = len(self._rings)
                self._rings.append(ring)
            self._tls.ring = ring
            return ring

    def _us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def span(self, cat: str, name: str, t0: float, t1: float, *,
             rank: Optional[int] = None, **args: Any) -> None:
        """Record a complete span covering monotonic seconds [t0, t1]."""
        ring = self._ring()
        ring.append(span_event(cat, name, self._us(t0),
                               (t1 - t0) * 1e6, rank=rank,
                               tid=self._tls.tid, **args))

    def instant(self, cat: str, name: str, *, t: Optional[float] = None,
                rank: Optional[int] = None, **args: Any) -> None:
        """Record an instant event (now, unless ``t`` is given)."""
        ring = self._ring()
        ring.append(instant_event(
            cat, name, self._us(time.monotonic() if t is None else t),
            rank=rank, tid=self._tls.tid, **args))

    def counter(self, name: str, value: float, *,
                rank: Optional[int] = None) -> None:
        """Record a counter sample at the current time."""
        ring = self._ring()
        ring.append(counter_event(name, value,
                                  self._us(time.monotonic()),
                                  rank=rank, tid=self._tls.tid))

    # -- collection ----------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """All recorded events, merged across threads, sorted by ts."""
        with self._lock:
            rings = list(self._rings)
        out: List[Dict[str, Any]] = []
        for ring in rings:
            out.extend(ring)
        out.sort(key=lambda ev: ev["ts"])
        return out

    def clear(self) -> None:
        with self._lock:
            for ring in self._rings:
                ring.clear()


#: The active tracer.  Instrumentation sites read :data:`TRACING` first
#: and only touch :data:`TRACER` when it is True.
TRACER: Any = NullTracer()
TRACING: bool = False


def set_tracer(tracer: Any) -> Any:
    """Install ``tracer`` as the active tracer; returns the previous one.

    Passing a :class:`NullTracer` (or ``None``) disables tracing —
    :data:`TRACING` flips accordingly, so guarded sites go back to their
    single-attribute-read cost.
    """
    global TRACER, TRACING
    prev = TRACER
    TRACER = NullTracer() if tracer is None else tracer
    TRACING = not isinstance(TRACER, NullTracer)
    return prev


def get_tracer() -> Any:
    """The active tracer (a :class:`NullTracer` when tracing is off)."""
    return TRACER


@contextlib.contextmanager
def tracing(capacity: int = DEFAULT_CAPACITY,
            tracer: Optional[Any] = None) -> Iterator[Any]:
    """Context manager: install a (fresh) :class:`Tracer`, restore after.

    >>> with tracing() as tr:            # doctest: +SKIP
    ...     run_workload()
    ...     doc = export_trace("out.json", tracer=tr)
    """
    tr = Tracer(capacity) if tracer is None else tracer
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)


# ---------------------------------------------------------------------------
# Export + validation.
# ---------------------------------------------------------------------------
def export_trace(path: Optional[str] = None, *, tracer: Optional[Any] = None,
                 events: Optional[List[Dict[str, Any]]] = None,
                 extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Build (and optionally write) the Chrome trace-event JSON document.

    ``events`` overrides the tracer's buffer — pass simulator events
    (:func:`repro.core.simulate.trace_events`) to export a replay under
    the identical schema.  ``extra`` lands in ``otherData`` (derived
    metrics like per-rank overlap fractions ride there).  Returns the
    document; writes it to ``path`` when given.  Load the file in
    ``ui.perfetto.dev`` or ``chrome://tracing``.
    """
    if events is None:
        events = (TRACER if tracer is None else tracer).events()
    doc: Dict[str, Any] = {
        "traceEvents": list(events),
        "displayTimeUnit": "ms",
        "otherData": dict(extra or {}),
    }
    if path is not None:
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=None, separators=(",", ":"),
                      default=str)
    return doc


def validate_trace(doc: Any) -> List[str]:
    """Check a trace document against :data:`SPAN_SCHEMA`.

    Returns a list of human-readable problems (empty == valid).  Accepts
    either the full document or a bare event list.
    """
    errors: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["document has no 'traceEvents' list"]
    elif isinstance(doc, list):
        events = doc
    else:
        return [f"expected a dict or list, got {type(doc).__name__}"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "C"):
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing name")
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{where} ({name}): non-numeric ts")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errors.append(f"{where} ({name}): non-integer {field}")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where} ({name}): args is not an object")
        if ph == "C":
            continue
        cat = ev.get("cat")
        if cat not in SPAN_SCHEMA:
            errors.append(f"{where} ({name}): unknown cat {cat!r}")
            continue
        allowed = SPAN_SCHEMA[cat]["spans" if ph == "X" else "instants"]
        if name not in allowed:
            errors.append(f"{where}: {ph!r} name {name!r} not in schema "
                          f"for cat {cat!r} (allowed: {allowed})")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where} ({name}): bad dur {dur!r}")
    return errors


def assert_valid_trace(doc: Any) -> None:
    """Raise ``ValueError`` listing every schema violation (if any)."""
    errors = validate_trace(doc)
    if errors:
        head = "\n  ".join(errors[:20])
        more = f"\n  ... and {len(errors) - 20} more" if len(errors) > 20 \
            else ""
        raise ValueError(f"invalid trace ({len(errors)} problems):\n"
                         f"  {head}{more}")
