"""Chunkwise mLSTM (xLSTM matrix-memory) Pallas-TPU kernel.

Same TPU shape as the SSD kernel: the chunk-quadratic gate/score matrices
live in VMEM, the (d×d) matrix memory C plus normalizer n and stabilizer m
are carried across the innermost (sequential) grid dimension in VMEM
scratch, and each chunk contributes three MXU matmuls (q·kᵀ, scores·v,
kᵀ·v).  Numerics follow the stabilised xLSTM recurrence exactly
(log-space forget-gate accumulation, running max stabiliser, |n·q| floor).

Validated in interpret mode against ``ref.mlstm_sequential``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref,
            y_ref, cf_ref, nf_ref, mf_ref,
            C_ref, n_ref, m_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0, :, 0, :].astype(jnp.float32)           # (l, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    ig = ig_ref[0, :, 0].astype(jnp.float32)            # (l,)
    lf = jax.nn.log_sigmoid(fg_ref[0, :, 0].astype(jnp.float32))

    C = C_ref[...]                                       # (d, d)
    n = n_ref[...]                                       # (1, d)
    m = m_ref[0, 0]                                      # scalar

    lf_cum = jnp.cumsum(lf)                              # (l,)
    seg = lf_cum[:, None] - lf_cum[None, :]              # sum_{j<k<=i} lf
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a_loc = jnp.where(row >= col, seg + ig[None, :], NEG)
    m_local = jnp.max(a_loc, axis=-1)                    # (l,)
    m_in = lf_cum + m                                    # (l,)
    m_new = jnp.maximum(m_local, m_in)

    w = jnp.exp(a_loc - m_new[:, None])                  # (l, l)
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = qk * w
    num = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jnp.sum(scores, axis=-1)                       # (l,)

    scale_in = jnp.exp(m_in - m_new)                     # (l,)
    num += jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32) \
        * scale_in[:, None]
    den += jnp.sum(q * n, axis=-1) * scale_in
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    y_ref[0, :, 0, :] = (num / den[:, None]).astype(y_ref.dtype)

    # carry to end of chunk
    total = lf_cum[-1]
    m_end = m_new[-1]
    w_end = jnp.exp(ig + total - lf_cum - m_end)         # (l,)
    decay = jnp.exp(total + m - m_end)
    C_ref[...] = C * decay + jax.lax.dot_general(
        k * w_end[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = n * decay + jnp.sum(k * w_end[:, None], axis=0)[None]
    m_ref[0, 0] = m_end

    @pl.when(ic == n_chunks - 1)
    def _fin():
        cf_ref[0, 0] = C_ref[...]
        nf_ref[0, 0] = n_ref[0]
        mf_ref[0, 0] = m_ref[0, 0]


def mlstm_chunk(q: jax.Array, k: jax.Array, v: jax.Array,
                i_gate: jax.Array, f_gate: jax.Array, *, chunk: int = 128,
                interpret: bool = False
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array,
                                            jax.Array]]:
    """q,k,v: (b,s,h,d); i_gate,f_gate: (b,s,h) pre-activation logits.

    Returns (y, (C, n, m)) with C: (b,h,d,d), n: (b,h,d), m: (b,h) fp32.
    """
    b, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    grid = (b, h, nc)

    y, Cf, nf, mf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, d, d), lambda ib, ih, ic: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda ib, ih, ic: (ib, ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ib, ih)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_gate, f_gate)
    return y, (Cf, nf, mf)
