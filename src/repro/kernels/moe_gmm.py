"""Expert grouped-matmul Pallas-TPU kernel (MoE expert compute).

The expert FFN matmuls are the FLOPs hot spot of the MoE architectures
(olmoe, mixtral).  After capacity-based dispatch, the activations are laid
out as (E, C, K) — a fixed capacity of C token slots per expert — and each
expert applies its own (K, N) weight.  This kernel runs the batched expert
matmul as a blocked MXU pipeline: grid (E, C/bc, N/bn, K/bk) with an fp32
VMEM accumulator carried over the innermost (sequential) K dimension, so
each weight tile is streamed HBM→VMEM exactly once per (expert, row-block,
col-block).

The dynamic-group-size variant (megablocks-style, rows sorted by expert with
ragged boundaries) is handled by the ops wrapper by padding group sizes to
the capacity grid — on TPU the fixed-capacity layout is what keeps the MXU
dense, which is the hardware-adaptation story for this kernel (GPU
megablocks relies on CSR-style tile indirection instead).

Validated against ``ref.gmm`` with ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_kblocks: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (bc, bk)
    w = w_ref[0].astype(jnp.float32)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == n_kblocks - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
            block_n: int = 128, block_k: int = 128,
            interpret: bool = False) -> jax.Array:
    """Batched expert matmul.  x: (E, C, K); w: (E, K, N) -> (E, C, N)."""
    E, C, K = x.shape
    N = w.shape[-1]
    block_c = min(block_c, C)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert C % block_c == 0 and N % block_n == 0 and K % block_k == 0
    nk = K // block_k
    grid = (E, C // block_c, N // block_n, nk)

    kernel = functools.partial(_gmm_kernel, n_kblocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, ic, jn, kk: (e, ic, kk)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, ic, jn, kk: (e, kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_n),
                               lambda e, ic, jn, kk: (e, ic, jn)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
