"""Flash attention Pallas-TPU kernel (tiled online softmax).

TPU-native adaptation of the attention hot spot: q/k/v tiles are staged
HBM→VMEM via BlockSpecs, scores for one (block_q × block_k) tile live
entirely in VMEM/VREGs, and the online-softmax running statistics (m, l) and
the output accumulator are carried across the k-block grid dimension in VMEM
scratch.  The MXU sees (block_q, d) × (d, block_k) and
(block_q, block_k) × (block_k, d) matmuls, with d and the block sizes kept
at multiples of 128 where the head dim allows.

Supports GQA (kv-head broadcast through the index map), causal masking and
sliding windows.  Fully-masked tiles are skipped with ``pl.when`` so the
causal kernel does ~half the MXU work of the dense one.

Validated in ``interpret=True`` mode against ``ref.attention`` over a shape
and dtype sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_k: int, n_kblocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Tile-level skip: with causal masking, tiles strictly above the
    # diagonal contribute nothing; with a sliding window, tiles entirely
    # left of the window contribute nothing either.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1
                              > q_start - window)

    @pl.when(run)
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                         # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows (keep exp(NEG_INF - NEG_INF) at 0).
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kblocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Tiled attention.  q: (B, S, H, D); k/v: (B, T, Hkv, D)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    rep = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_kblocks = T // block_k
    grid = (B, H, S // block_q, n_kblocks)

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kblocks=n_kblocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, rep=rep: (b, ik, h // rep, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, rep=rep: (b, ik, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # fp32 accumulator + online-softmax stats in VMEM, persistent
            # across the (innermost, sequential) k-block grid dimension.
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
