"""Public kernel entry points with platform dispatch.

Models call these wrappers; each dispatches to the Pallas-TPU kernel on TPU
backends and to the pure-jnp oracle elsewhere (CPU dry-run / tests), unless
forced with ``impl=``:

* ``impl="pallas"``            — the TPU kernel (compiled)
* ``impl="pallas_interpret"``  — the TPU kernel body, interpreted (CPU)
* ``impl="ref"``               — the jnp oracle
* ``impl=None``                — auto: pallas on TPU else ref
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import collective_stages as _stages
from . import ref
from .flash_attention import flash_attention as _flash_pallas
from .mamba2_ssd import mamba2_ssd as _ssd_pallas
from .moe_gmm import moe_gmm as _gmm_pallas
from .mlstm_chunk import mlstm_chunk as _mlstm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Optional[str]) -> str:
    if impl is None:
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    impl: Optional[str] = None, constrain=None) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        # Flash-style chunked jnp (O(S·chunk) memory) — dense ref.attention
        # stays the oracle for small-shape kernel tests.  Long causal
        # self-attention uses the one-level causal split (-25% flops).
        S, T = q.shape[1], k.shape[1]
        if causal and window is None and S == T and S >= 4096 \
                and S % 2 == 0:
            return ref.attention_causal_split(q, k, v, constrain=constrain)
        return ref.attention_chunked(q, k, v, causal=causal, window=window,
                                     constrain=constrain)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         interpret=(impl == "pallas_interpret"))


def mamba2_ssd(x, dt, A, B, C, *, chunk: int = 128, init_state=None,
               impl: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    s = x.shape[1]
    chunk = min(chunk, s) if s % chunk != 0 else chunk
    pad = (-s) % chunk
    if pad:
        # dt = 0 on padded steps: decay exp(0·A) = 1 and zero input, so the
        # final state is untouched; padded outputs are sliced off.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    impl = _resolve(impl)
    if impl == "ref":
        y, st = ref.ssd_chunked(x, dt, A, B, C, chunk=chunk,
                                init_state=init_state)
    else:
        y, st = _ssd_pallas(x, dt, A, B, C, chunk=chunk,
                            init_state=init_state,
                            interpret=(impl == "pallas_interpret"))
    return (y[:, :s] if pad else y), st


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int, init=None,
                  impl: Optional[str] = None):
    s = q.shape[1]
    chunk = min(chunk, s) if s % chunk != 0 else chunk
    pad = (-s) % chunk
    if pad:
        # i = -inf on padded steps (no insertion), f logits >> 0 (log-sigmoid
        # ≈ 0 ⇒ no decay): the carry state passes through unchanged.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-1e30)
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=30.0)
    impl = _resolve(impl)
    if impl != "ref" and init is None:
        # Pallas kernel path (zero initial state only — prefill/train);
        # decode chaining goes through the jnp chunked implementation.
        y, st = _mlstm_pallas(q, k, v, i_gate, f_gate, chunk=chunk,
                              interpret=(impl == "pallas_interpret"))
    else:
        y, st = ref.mlstm_chunked(q, k, v, i_gate, f_gate, chunk=chunk,
                                  init=init)
    return (y[:, :s] if pad else y), st


def moe_gmm(x, w, *, impl: Optional[str] = None, **blocks) -> jax.Array:
    """Batched expert matmul: x (E, C, K) × w (E, K, N) -> (E, C, N)."""
    impl = _resolve(impl)
    if impl == "ref":
        E, C, K = x.shape
        sizes = jnp.full((E,), C, jnp.int32)
        return ref.gmm(x.reshape(E * C, K), w, sizes).reshape(
            E, C, w.shape[-1])
    return _gmm_pallas(x, w, interpret=(impl == "pallas_interpret"),
                       **blocks)


# ---------------------------------------------------------------------------
# Fused collective stages (the Level-B executor tier; see
# repro.kernels.collective_stages and repro.core.lowering stage_impl=)
# ---------------------------------------------------------------------------
def combine_stage(acc, got, scale=None, *, accumulate: bool = True,
                  impl: Optional[str] = None) -> jax.Array:
    """Fused reduce-scatter combine: ``acc + dequant(got)`` in one pass.

    ``got`` may be in a narrower wire dtype (bf16, or int8 with
    ``scale``); ``accumulate=False`` is the allgather-leg chunk install.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.combine_stage(acc, got, scale, accumulate=accumulate)
    return _stages.fused_combine(acc, got, scale, accumulate=accumulate,
                                 interpret=(impl == "pallas_interpret"))


def quantize_stage(x, *, impl: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 wire quantisation; returns ``(q, scale)``.

    The scalar ``max|x|/127`` reduction happens in XLA (one read); the
    round/clip/cast store is the fused single-pass kernel.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))),
                        1e-20) / 127.0
    impl = _resolve(impl)
    if impl == "ref":
        return ref.quantize_stage(x, scale), scale
    return _stages.quantize_wire(
        x, scale, interpret=(impl == "pallas_interpret")), scale


def dequantize_stage(q, scale, dtype=jnp.float32, *,
                     impl: Optional[str] = None) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return ref.dequantize_stage(q, scale, dtype)
    return _stages.dequantize_wire(q, scale, dtype,
                                   interpret=(impl == "pallas_interpret"))


def gs_stencil(block, top, left, bottom, right, *,
               impl: Optional[str] = None):
    """Fused Gauss–Seidel block stage: 4-point update, L1 residual and
    the four outgoing boundary edges in one pass over the block.
    Returns ``(new_block, residual, (top, bottom, left, right))``."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.gs_stencil(block, top, left, bottom, right)
    return _stages.gs_stencil(block, top, left, bottom, right,
                              interpret=(impl == "pallas_interpret"))


# Pure-jnp layers with no Pallas variant (documented in DESIGN.md):
mlstm_sequential = ref.mlstm_sequential
mlstm_decode_step = ref.mlstm_decode_step
ssd_decode_step = ref.ssd_decode_step
attention_ref = ref.attention
