"""Mamba2 SSD (state-space dual) chunked-scan Pallas-TPU kernel.

The SSD hot loop is the sequence-mixing hot spot of the Mamba2/Zamba2
architectures.  The TPU-native formulation keeps the chunk-quadratic part on
the MXU — three (chunk × chunk/state) matmuls per chunk — and carries the
inter-chunk recurrent state in VMEM scratch across the innermost grid
dimension, which Pallas-TPU executes sequentially.  This mirrors how the
GPU algorithm's cross-chunk pass is replaced by a grid-carried accumulator
instead of a separate kernel launch: one HBM→VMEM pass over x/dt/B/C, no
intermediate state tensor in HBM.

Layout choices for the TPU memory hierarchy:
* chunk length and state width default to 128 (MXU-aligned);
* per-(batch, head) state tile (headdim × state) lives in VMEM scratch;
* fp32 accumulation throughout; inputs may be bf16.

Validated against ``ref.ssd_chunked`` / ``ref.ssd_sequential`` with
``interpret=True`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref,
                y_ref, fin_ref, state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)   # (p, n)

    x = x_ref[0, :, 0, :].astype(jnp.float32)                 # (l, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                  # (l,)
    a = a_ref[0].astype(jnp.float32)                          # scalar
    B = b_ref[0].astype(jnp.float32)                          # (l, n)
    C = c_ref[0].astype(jnp.float32)                          # (l, n)

    xd = x * dt[:, None]                                      # (l, p)
    dA = dt * a                                               # (l,) negative
    cums = jnp.cumsum(dA)                                     # (l,)

    # intra-chunk: Y_diag = ((C B^T) ∘ L) xd,  L[i,j] = exp(sum_{j<k<=i} dA)
    seg = cums[:, None] - cums[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(row >= col, jnp.exp(seg), 0.0)              # (l, l)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(cb * L, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                                    # (p, n)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: state' = exp(sum dA) state + xd^T (B ∘ decay)
    decay_states = jnp.exp(cums[-1] - cums)                   # (l,)
    state_ref[...] = state * jnp.exp(cums[-1]) + jax.lax.dot_general(
        xd, B * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _fin():
        fin_ref[0, 0] = state_ref[...]


def mamba2_ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, chunk: int = 128,
               init_state: Optional[jax.Array] = None,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (b, s, h, p);  dt: (b, s, h);  A: (h,);  B, C: (b, s, n).
    Returns (y: (b, s, h, p), final_state: (b, h, p, n) fp32).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    grid = (b, h, nc)

    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, init_state)
    return y, fin
