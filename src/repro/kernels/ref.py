"""Pure-jnp oracle implementations for every Pallas kernel.

These are the ground truth the kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes with ``interpret=True``) and
the CPU execution path of the models.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (GQA, causal / bidirectional, sliding window, logit softcap)
# ---------------------------------------------------------------------------
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: Optional[int] = None,
              softcap: float = 0.0,
              kv_valid_len: Optional[jax.Array] = None,
              q_offset: Optional[jax.Array] = None) -> jax.Array:
    """Reference multi-head attention.

    q: (B, S, H, D); k, v: (B, T, Hkv, D) with H % Hkv == 0.
    ``kv_valid_len``: (B,) — only cache positions < len attend (decode).
    ``q_offset``: (B,) — absolute position of q[:, 0] (decode: cache index).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    dtype = q.dtype
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", qf, kf) / jnp.sqrt(D)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap

    q_pos = jnp.arange(S)[:, None]  # (S, 1)
    if q_offset is not None:
        q_pos = q_pos[None] + q_offset[:, None, None]  # (B, S, 1)
    else:
        q_pos = q_pos[None]
    k_pos = jnp.arange(T)[None, None, :]  # (1, 1, T)
    mask = jnp.ones((B if q_offset is not None else 1, S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_valid_len is not None:
        mask &= k_pos < kv_valid_len[:, None, None]
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      chunk: int = 512, constrain=None,
                      q_offset: int = 0) -> jax.Array:
    """Flash-style attention in pure jnp with a flash-style custom VJP.

    Forward: ``lax.scan`` over key chunks with online-softmax running
    statistics — O(S·chunk) memory instead of O(S·T).  Backward: the
    standard flash recomputation — saves only (q, k, v, out, lse) and
    rebuilds each chunk's probabilities from lse, so autodiff does NOT
    stack per-chunk score tensors (which at 4k×4k×heads was the dominant
    activation-memory term).  Numerics match :func:`attention`.

    ``constrain``: optional hook with ``.attn_acc`` ((B,H,S,D)) and
    ``.attn_stats`` ((B,H,S)) sharding constraints — GSPMD's while-loop
    sharding propagation otherwise REPLICATES the scan carries, which at
    (256,48,4096,128) fp32 is a 24 GiB-per-device bug, not a perf knob.
    """
    return _attn_vjp(q, k, v, causal, window, chunk, constrain, q_offset)


def attention_causal_split(q, k, v, *, chunk: int = 512, constrain=None):
    """One-level causal split: the first half of q attends only the first
    half of k/v — removes the fully-masked lower-left quadrant, cutting
    causal attention flops by 25% (and its kernel-tile traffic likewise).
    The halves are independent, so GSPMD parallelism is unaffected."""
    B, S, H, D = q.shape
    half = S // 2
    o1 = attention_chunked(q[:, :half], k[:, :half], v[:, :half],
                           causal=True, chunk=chunk, constrain=constrain)
    o2 = attention_chunked(q[:, half:], k, v, causal=True, chunk=chunk,
                           constrain=constrain, q_offset=half)
    return jnp.concatenate([o1, o2], axis=1)


def _chunk_mask(ci, chunk, T, S, causal, window, q_offset=0):
    q_pos = q_offset + jnp.arange(S)[:, None]
    k_pos = ci * chunk + jnp.arange(chunk)[None, :]
    mask = k_pos < T
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    return mask  # (S, chunk)


def _c_acc(constrain, x):
    return constrain.attn_acc(x) if constrain is not None else x


def _c_stats(constrain, x):
    return constrain.attn_stats(x) if constrain is not None else x


def _attn_fwd_impl(q, k, v, causal, window, chunk, constrain=None,
                   q_offset=0):
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (T + pad) // chunk
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, Hkv, D), 1, 0)

    qf = q.astype(jnp.float32)
    scale = 1.0 / (D ** 0.5)

    def body(carry, xs):
        # named_scope tags every op in this body as kernel-resident: on the
        # TPU Pallas kernel these tiles never touch HBM, and the roofline
        # analyzer reports a kernel-adjusted memory term (hlo_cost.py).
        with jax.named_scope("vmem_resident_flash"):
            m, l, acc = carry
            kb, vb, ci = xs
            kb = kb.astype(jnp.float32)
            vb = vb.astype(jnp.float32)
            if rep > 1:
                kb = jnp.repeat(kb, rep, axis=2)
                vb = jnp.repeat(vb, rep, axis=2)
            s = jnp.einsum("bshd,bthd->bhst", qf, kb) * scale
            mask = _chunk_mask(ci, chunk, T, S, causal, window, q_offset)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            alpha = jnp.where(m == NEG_INF, 0.0, jnp.exp(m - m_new))
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhst,bthd->bhsd",
                                                      p, vb)
            return (_c_stats(constrain, m_new), _c_stats(constrain, l),
                    _c_acc(constrain, acc)), None

    m0 = _c_stats(constrain, jnp.full((B, H, S), NEG_INF, jnp.float32))
    l0 = _c_stats(constrain, jnp.zeros((B, H, S), jnp.float32))
    a0 = _c_acc(constrain, jnp.zeros((B, H, S, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nc)))
    lsafe = jnp.where(l == 0.0, 1.0, l)
    out = acc / lsafe[..., None]
    lse = m + jnp.log(lsafe)                          # (B,H,S)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _attn_vjp(q, k, v, causal, window, chunk, constrain=None, q_offset=0):
    out, _ = _attn_fwd_impl(q, k, v, causal, window, chunk, constrain,
                            q_offset)
    return out


def _attn_vjp_fwd(q, k, v, causal, window, chunk, constrain=None,
                  q_offset=0):
    out, lse = _attn_fwd_impl(q, k, v, causal, window, chunk, constrain,
                              q_offset)
    return out, (q, k, v, out, lse)


def _attn_vjp_bwd(causal, window, chunk, constrain, q_offset, res, dout):
    """Flash backward: recompute per-chunk probabilities from lse."""
    q, k, v, out, lse = res
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (T + pad) // chunk
    kcs = jnp.moveaxis(k.reshape(B, nc, chunk, Hkv, D), 1, 0)
    vcs = jnp.moveaxis(v.reshape(B, nc, chunk, Hkv, D), 1, 0)

    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    of = out.astype(jnp.float32)
    scale = 1.0 / (D ** 0.5)
    # delta[b,s,h] = sum_d dout*out — the softmax-jacobian diagonal term
    delta = jnp.einsum("bshd,bshd->bhs", do, of)       # (B,H,S)

    def body(dq, xs):
        with jax.named_scope("vmem_resident_flash"):
            kb, vb, ci = xs
            kb = kb.astype(jnp.float32)
            vb = vb.astype(jnp.float32)
            if rep > 1:
                kbr = jnp.repeat(kb, rep, axis=2)
                vbr = jnp.repeat(vb, rep, axis=2)
            else:
                kbr, vbr = kb, vb
            s = jnp.einsum("bshd,bthd->bhst", qf, kbr) * scale
            mask = _chunk_mask(ci, chunk, T, S, causal, window, q_offset)
            p = jnp.exp(s - lse[..., None])                # (B,H,S,chunk)
            p = jnp.where(mask[None, None], p, 0.0)
            dv_c = jnp.einsum("bhst,bshd->bthd", p, do)    # (B,chunk,H,D)
            dp = jnp.einsum("bshd,bthd->bhst", do, vbr)
            ds = p * (dp - delta[..., None]) * scale
            dq = dq + jnp.einsum("bhst,bthd->bshd", ds, kbr)
            if constrain is not None:
                dq = constrain.heads(dq)
            dk_c = jnp.einsum("bhst,bshd->bthd", ds, qf)   # (B,chunk,H,D)
            if rep > 1:
                dk_c = dk_c.reshape(B, chunk, Hkv, rep, D).sum(3)
                dv_c = dv_c.reshape(B, chunk, Hkv, rep, D).sum(3)
            return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, S, H, D), jnp.float32)
    if constrain is not None:
        dq0 = constrain.heads(dq0)
    dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                  (kcs, vcs, jnp.arange(nc)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, nc * chunk, Hkv, D)[:, :T]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, nc * chunk, Hkv, D)[:, :T]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_attn_vjp.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)


# ---------------------------------------------------------------------------
# Mamba2 / SSD (state space dual) — chunked scan
# ---------------------------------------------------------------------------
def _segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) lower-triangular segment sums.

    out[i, j] = sum_{j < k <= i} x[k]  (i >= j), -inf above the diagonal.
    """
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    d = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, *, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba2, "ssd_minimal_discrete" algorithm).

    x: (b, s, h, p) — per-head inputs;   dt: (b, s, h) — timestep (>0);
    A: (h,) — negative per-head decay;   B, C: (b, s, n) — shared across heads
    (single-group).  Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xd = x.astype(f32) * dt.astype(f32)[..., None]     # discretized input
    dA = (dt.astype(f32) * A).astype(f32)              # (b, s, h) — negative

    def ch(t, extra=()):  # (b, s, ...) -> (b, nc, chunk, ...)
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xd_c = ch(xd)                                      # (b,c,l,h,p)
    dA_c = jnp.transpose(ch(dA), (0, 3, 1, 2))          # (b,h,c,l)
    B_c = ch(B.astype(f32))                            # (b,c,l,n)
    C_c = ch(C.astype(f32))                            # (b,c,l,n)

    # 1. intra-chunk (diagonal block) outputs — in the Pallas kernel the
    # (l,l) decay matrices live in VMEM; tagged for the adjusted roofline.
    with jax.named_scope("vmem_resident_ssd"):
        L = jnp.exp(_segsum(dA_c))                     # (b,h,c,l,l)
        Y_diag = jnp.einsum("bcln,bcmn,bhclm,bcmhp->bclhp",
                            C_c, B_c, L, xd_c)

    # 2. per-chunk final states
    dA_cum = jnp.cumsum(dA_c, axis=-1)                 # (b,h,c,l)
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", B_c, decay_states, xd_c)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])             # (b,h,c)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)
    else:
        init_state = init_state.astype(f32)

    def step(carry, inp):
        st, dec = inp                                  # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                              # emit state ENTERING chunk

    final_state, prev_states = jax.lax.scan(
        step, init_state,
        (jnp.transpose(states, (1, 0, 2, 3, 4)),        # (c,b,h,p,n)
         jnp.transpose(chunk_decay, (2, 0, 1))))        # (c,b,h)
    prev_states = jnp.transpose(prev_states, (1, 0, 2, 3, 4))  # (b,c,h,p,n)

    # 4. chunk-input contribution
    state_decay_out = jnp.exp(dA_cum)                  # (b,h,c,l)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", C_c, prev_states,
                       state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, h, p).astype(x.dtype)
    return y, final_state


def ssd_sequential(x, dt, A, B, C, *, init_state=None):
    """Stepwise oracle for :func:`ssd_chunked` (and the decode path)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        state, yt = ssd_decode_step(state, xt, dtt, A, Bt, Ct)
        return state, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    final, ys = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_decode_step(state, xt, dtt, A, Bt, Ct):
    """One recurrent SSD step.  state: (b,h,p,n) fp32."""
    f32 = jnp.float32
    xt, dtt, Bt, Ct = (t.astype(f32) for t in (xt, dtt, Bt, Ct))
    decay = jnp.exp(dtt * A)[..., None, None]            # (b,h,1,1)
    upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
    state = state * decay + upd
    yt = jnp.einsum("bhpn,bn->bhp", state, Ct)
    return state, yt


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell) — sequential oracle + chunked parallel
# ---------------------------------------------------------------------------
def mlstm_sequential(q, k, v, i_gate, f_gate, *, init=None):
    """Stabilised mLSTM recurrence (xLSTM eq. 19-27).

    q,k,v: (b, s, h, d);  i_gate, f_gate: (b, s, h) — pre-activation logits.
    Returns (y: (b,s,h,d), state=(C: (b,h,d,d), n: (b,h,d), m: (b,h))).
    """
    b, s, h, d = q.shape
    f32 = jnp.float32
    if init is None:
        C0 = jnp.zeros((b, h, d, d), f32)
        n0 = jnp.zeros((b, h, d), f32)
        m0 = jnp.full((b, h), -jnp.inf, f32)
    else:
        C0, n0, m0 = (t.astype(f32) for t in init)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        qt, kt, vt = qt.astype(f32), kt.astype(f32), vt.astype(f32)
        it, ft = it.astype(f32), ft.astype(f32)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fs = jnp.exp(logf + m - m_new)
        is_ = jnp.exp(it - m_new)
        C = C * fs[..., None, None] + \
            is_[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = n * fs[..., None] + is_[..., None] * kt
        num = jnp.einsum("bhdj,bhd->bhj", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_gate, f_gate))
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), (C, n, m)


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int, init=None):
    """Chunkwise-parallel mLSTM (matmul-heavy; quadratic inside chunks).

    Same interface as :func:`mlstm_sequential`; validated against it.
    """
    b, s, h, d = q.shape
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32

    qf = q.astype(f32).reshape(b, nc, chunk, h, d)
    kf = k.astype(f32).reshape(b, nc, chunk, h, d)
    vf = v.astype(f32).reshape(b, nc, chunk, h, d)
    ig = i_gate.astype(f32).reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)
    lf = jax.nn.log_sigmoid(f_gate.astype(f32)) \
        .reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)   # (b,h,c,l)

    if init is None:
        C0 = jnp.zeros((b, h, d, d), f32)
        n0 = jnp.zeros((b, h, d), f32)
        m0 = jnp.full((b, h), -jnp.inf, f32)
    else:
        C0, n0, m0 = (t.astype(f32) for t in init)

    lf_cum = jnp.cumsum(lf, axis=-1)                       # (b,h,c,l)
    # local (within-chunk) stabilizer candidates: decay-to-t + gate at source
    # a[i,j] = sum_{j<k<=i} logf_k + i_j   (j <= i)
    seg = _segsum(lf)                                      # (b,h,c,l,l)
    a_local = seg + ig[..., None, :]                       # (b,h,c,l,l)
    m_local = jnp.max(jnp.where(jnp.isfinite(a_local), a_local, -jnp.inf),
                      axis=-1)                             # (b,h,c,l)

    # sequential scan over chunks for carry state (C, n, m)
    def chunk_step(carry, idx):
        C, n, m = carry
        qc = qf[:, idx]
        kc = kf[:, idx]
        vc = vf[:, idx]
        igc = ig[:, :, idx]                                # (b,h,l)
        lfc = lf[:, :, idx]
        lf_cumc = lf_cum[:, :, idx]                        # (b,h,l)
        segc = seg[:, :, idx]                              # (b,h,l,l)
        a_loc = a_local[:, :, idx]                         # (b,h,l,l)

        # incoming-state contribution has log-scale lf_cum + m_prev
        m_in = lf_cumc + m[..., None]                      # (b,h,l)
        m_new = jnp.maximum(m_local[:, :, idx], m_in)      # (b,h,l)

        # intra-chunk attention-style term
        w = jnp.exp(a_loc - m_new[..., None])              # (b,h,l,l)
        scores = jnp.einsum("blhd,bmhd->bhlm", qc, kc) * w
        num_local = jnp.einsum("bhlm,bmhd->blhd", scores, vc)
        den_local_q = jnp.sum(scores, axis=-1)             # (b,h,l) = q·n_loc

        # inter-chunk contribution
        scale_in = jnp.exp(m_in - m_new)                   # (b,h,l)
        num_in = jnp.einsum("blhd,bhde->blhe", qc, C) * scale_in.transpose(
            0, 2, 1)[..., None]
        den_in_q = jnp.einsum("blhd,bhd->bhl", qc, n) * scale_in

        num = num_local + num_in
        den = den_local_q + den_in_q                       # (b,h,l)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        y = num / den.transpose(0, 2, 1)[..., None]

        # update carry to end of chunk: the stabiliser at the chunk's last
        # position is exactly the sequential m there, so reuse it.
        total = lf_cumc[..., -1]                           # (b,h)
        m_end = m_new[..., -1]                             # (b,h)
        # contribution of each position j to the end-of-chunk state:
        # exp(i_j + sum_{j<k<=L} logf_k - m_end)
        w_end = jnp.exp(igc + total[..., None] - lf_cumc - m_end[..., None])
        C_new = C * jnp.exp(total + m - m_end)[..., None, None] + \
            jnp.einsum("bhl,blhd,blhe->bhde", w_end, kc, vc)
        n_new = n * jnp.exp(total + m - m_end)[..., None] + \
            jnp.einsum("bhl,blhd->bhd", w_end, kc)
        return (C_new, n_new, m_end), y

    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, d)
    return y.astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, qt, kt, vt, it, ft):
    """One mLSTM step. state=(C,n,m) fp32; qt/kt/vt: (b,h,d); it/ft: (b,h)."""
    C, n, m = state
    f32 = jnp.float32
    qt, kt, vt = qt.astype(f32), kt.astype(f32), vt.astype(f32)
    logf = jax.nn.log_sigmoid(ft.astype(f32))
    m_new = jnp.maximum(logf + m, it.astype(f32))
    fs = jnp.exp(logf + m - m_new)
    is_ = jnp.exp(it.astype(f32) - m_new)
    C = C * fs[..., None, None] + is_[..., None, None] * (
        kt[..., :, None] * vt[..., None, :])
    n = n * fs[..., None] + is_[..., None] * kt
    num = jnp.einsum("bhdj,bhd->bhj", C, qt)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                      jnp.exp(-m_new))
    return (C, n, m_new), num / den[..., None]


# ---------------------------------------------------------------------------
# Grouped matmul over expert segments (MoE)
# ---------------------------------------------------------------------------
def gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul: rows of ``x`` are sorted by expert; ``group_sizes[e]``
    consecutive rows use ``w[e]``.

    x: (T, K);  w: (E, K, N);  group_sizes: (E,) int32 summing to T.
    Returns (T, N).
    """
    T = x.shape[0]
    E = w.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes
    # expert id per row
    row = jnp.arange(T)
    eid = jnp.sum(row[:, None] >= starts[None, :], axis=1) - 1
    eid = jnp.clip(eid, 0, E - 1)
    w_rows = w[eid]                       # (T, K, N) — gather (oracle only)
    return jnp.einsum("tk,tkn->tn", x.astype(jnp.float32),
                      w_rows.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Collective-stage oracles (fused combine / wire cast / Gauss–Seidel block)
# ---------------------------------------------------------------------------
def combine_stage(acc, got, scale=None, *, accumulate: bool = True):
    """Oracle for :func:`repro.kernels.collective_stages.fused_combine`:
    ``acc + dequant(got)`` (or just the dequant with ``accumulate=False``)
    as plain jnp — what the unfused Level-B path computes across separate
    elementwise stages."""
    if scale is None:
        got = got.astype(acc.dtype)
    else:
        got = (got.astype(jnp.float32) * scale).astype(acc.dtype)
    return acc + got if accumulate else got


def quantize_stage(x, scale):
    """Symmetric int8 quantisation oracle (round, clip, cast)."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_stage(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def gs_stencil(block, top, left, bottom, right):
    """Oracle for the fused Gauss–Seidel block stage: 4-point update,
    L1 residual and boundary edges, mirroring
    ``benchmarks/gauss_seidel.gs_block`` plus its residual/edge reads."""
    b = block.astype(jnp.float32)
    H, W = b.shape
    up = jnp.concatenate([top.reshape(1, W).astype(jnp.float32),
                          b[:-1, :]], axis=0)
    down = jnp.concatenate([b[1:, :],
                            bottom.reshape(1, W).astype(jnp.float32)],
                           axis=0)
    lft = jnp.concatenate([left.reshape(H, 1).astype(jnp.float32),
                           b[:, :-1]], axis=1)
    rgt = jnp.concatenate([b[:, 1:],
                           right.reshape(H, 1).astype(jnp.float32)],
                          axis=1)
    new = 0.25 * (up + down + lft + rgt)
    res = jnp.sum(jnp.abs(new - b))
    new = new.astype(block.dtype)
    return new, res, (new[0, :], new[-1, :], new[:, 0], new[:, -1])
