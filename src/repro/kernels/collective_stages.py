"""Fused collective-stage Pallas kernels — the Level-B executor tier.

The explicit-round allreduce lowerings (:mod:`repro.core.lowering`) emit,
between ``lax.ppermute`` rounds, purely memory-bound elementwise stages:
the reduce-scatter combine (``recv_chunk + accum``), the allgather chunk
install, and — under a narrow wire dtype — the int8/bf16 cast and dequant.
Left to XLA these lower as separate elementwise ops whose intermediates
round-trip HBM once per stage.  The kernels here fuse each round's stage
into ONE VMEM pass:

* :func:`fused_combine` — ``out = acc + dequant(got)`` (or just
  ``dequant(got)`` with ``accumulate=False``): the received chunk is cast
  out of its wire dtype, optionally scaled (int8 symmetric quantisation),
  and accumulated in a single read of ``acc``/``got`` and a single write
  of ``out`` — no materialised fp32 copy of the wire payload.
* :func:`quantize_wire` — symmetric int8 quantisation of an outgoing
  chunk against a precomputed scale (round, clip, cast, store in one
  pass).
* :func:`dequantize_wire` — the standalone inverse for allgather-leg
  chunks that travelled the whole ring in wire dtype.
* :func:`gs_stencil` — the Gauss–Seidel block stage: 4-point interior
  update, L1 residual, and the four outgoing boundary edges
  (boundary-pack) produced in one pass over the block; the halo transfers
  themselves stay event-bound host tasks.

All kernels take 1-D payloads of ANY length (odd sizes included): the
wrappers pad to the fp32/bf16/int8 tile granularity and reshape to
``(rows, 128)`` lanes before entering ``pl.pallas_call``, then strip the
padding.  ``interpret=True`` runs the kernel bodies under the Pallas
interpreter on CPU — the parity mode ``tests/test_kernels.py`` pins
against the jnp oracles in :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# One lane register row is 128 wide on every TPU generation; 32 sublanes
# cover the minimum tile height of fp32 (8), bf16 (16) and int8 (32), so
# padding to (32k, 128) keeps every wire dtype tile-aligned.
_LANE = 128
_SUBLANE = 32
_BLOCK_ROWS = 256          # (256, 128) fp32 block = 128 KiB of VMEM


def _pad_rows(flat: jax.Array) -> Tuple[jax.Array, int]:
    """Pad a flat vector to a (rows, 128) tile-aligned matrix."""
    m = flat.shape[0]
    pad = (-m) % (_SUBLANE * _LANE)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, _LANE), m


def _row_grid(rows: int) -> Tuple[int, int]:
    """(grid, block_rows) over the padded row dimension."""
    br = min(rows, _BLOCK_ROWS)
    return pl.cdiv(rows, br), br


# ---------------------------------------------------------------------------
# Fused combine (+ cast/dequant)
# ---------------------------------------------------------------------------
def _combine_kernel(acc_ref, got_ref, o_ref, *, accumulate: bool):
    got = got_ref[...].astype(o_ref.dtype)
    o_ref[...] = acc_ref[...] + got if accumulate else got


def _combine_scaled_kernel(scale_ref, acc_ref, got_ref, o_ref, *,
                           accumulate: bool):
    got = got_ref[...].astype(jnp.float32) * scale_ref[0]
    got = got.astype(o_ref.dtype)
    o_ref[...] = acc_ref[...] + got if accumulate else got


def fused_combine(acc: jax.Array, got: jax.Array,
                  scale: Optional[jax.Array] = None, *,
                  accumulate: bool = True,
                  interpret: bool = False) -> jax.Array:
    """``acc + dequant(got)`` in one VMEM pass (1-D operands).

    ``got`` may arrive in a narrower wire dtype (bf16, int8); it is cast
    to ``acc.dtype`` — via ``× scale`` for int8 symmetric quantisation —
    inside the kernel, so the fp32 copy of the wire payload never touches
    HBM.  ``accumulate=False`` skips the add (the allgather-leg chunk
    install).  Output dtype and shape follow ``acc``.
    """
    if acc.shape != got.shape:
        raise ValueError(f"acc/got shape mismatch: {acc.shape} vs "
                         f"{got.shape}")
    a2, m = _pad_rows(acc.reshape(-1))
    g2, _ = _pad_rows(got.reshape(-1))
    grid, br = _row_grid(a2.shape[0])
    row_spec = pl.BlockSpec((br, _LANE), lambda i: (i, 0))
    if scale is None:
        out = pl.pallas_call(
            functools.partial(_combine_kernel, accumulate=accumulate),
            grid=(grid,),
            in_specs=[row_spec, row_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(a2.shape, acc.dtype),
            interpret=interpret,
        )(a2, g2)
    else:
        out = pl.pallas_call(
            functools.partial(_combine_scaled_kernel,
                              accumulate=accumulate),
            grid=(grid,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                row_spec, row_spec,
            ],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(a2.shape, acc.dtype),
            interpret=interpret,
        )(jnp.asarray(scale, jnp.float32).reshape(1), a2, g2)
    return out.reshape(-1)[:m].reshape(acc.shape)


# ---------------------------------------------------------------------------
# Wire quantisation
# ---------------------------------------------------------------------------
def _quant_kernel(scale_ref, x_ref, q_ref):
    inv = 1.0 / scale_ref[0]
    q = jnp.round(x_ref[...].astype(jnp.float32) * inv)
    q_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def quantize_wire(x: jax.Array, scale: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """Symmetric int8 quantisation against ``scale`` (one pass).

    ``scale`` is the caller-computed ``max|x|/127`` (a scalar reduction
    XLA already does in one read); the kernel fuses divide, round, clip
    and the int8 store so the quantised copy is the only write.
    """
    x2, m = _pad_rows(x.reshape(-1))
    grid, br = _row_grid(x2.shape[0])
    row_spec = pl.BlockSpec((br, _LANE), lambda i: (i, 0))
    q = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), row_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.int8),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1), x2)
    return q.reshape(-1)[:m].reshape(x.shape)


def _dequant_kernel(scale_ref, q_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * scale_ref[0]).astype(o_ref.dtype)


def dequantize_wire(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32, *,
                    interpret: bool = False) -> jax.Array:
    """``q × scale`` cast to ``dtype`` in one pass (allgather-leg chunks
    that travelled the ring in wire dtype)."""
    q2, m = _pad_rows(q.reshape(-1))
    grid, br = _row_grid(q2.shape[0])
    row_spec = pl.BlockSpec((br, _LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), row_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(q2.shape, jnp.dtype(dtype)),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1), q2)
    return out.reshape(-1)[:m].reshape(q.shape)


# ---------------------------------------------------------------------------
# Fused Gauss–Seidel block stage: interior update + residual + boundary pack
# ---------------------------------------------------------------------------
def _gs_kernel(b_ref, t_ref, l_ref, btm_ref, r_ref,
               o_ref, res_ref, te_ref, be_ref, le_ref, re_ref):
    b = b_ref[...].astype(jnp.float32)
    up = jnp.concatenate([t_ref[...], b[:-1, :]], axis=0)
    down = jnp.concatenate([b[1:, :], btm_ref[...]], axis=0)
    left = jnp.concatenate([l_ref[...], b[:, :-1]], axis=1)
    right = jnp.concatenate([b[:, 1:], r_ref[...]], axis=1)
    new = 0.25 * (up + down + left + right)
    o_ref[...] = new.astype(o_ref.dtype)
    res_ref[0, 0] = jnp.sum(jnp.abs(new - b))
    te_ref[...] = new[:1, :].astype(te_ref.dtype)
    be_ref[...] = new[-1:, :].astype(be_ref.dtype)
    le_ref[...] = new[:, :1].astype(le_ref.dtype)
    re_ref[...] = new[:, -1:].astype(re_ref.dtype)


def gs_stencil(block: jax.Array, top: jax.Array, left: jax.Array,
               bottom: jax.Array, right: jax.Array, *,
               interpret: bool = False):
    """Fused Gauss–Seidel block stage.

    One pass over the (H, W) block producing the 4-point average update,
    the block's L1 residual ``sum|new - old|``, and the four NEW boundary
    edges packed for the next halo exchange — the separate residual
    re-read and edge-slice passes of the unfused path never happen.
    Returns ``(new_block, residual, (top, bottom, left, right))`` with
    edges shaped like the inputs (length W, W, H, H).

    The whole block lives in VMEM for the pass (a 512×512 fp32 block is
    1 MiB — comfortably resident); halo transfers stay event-bound tasks
    on the host runtime.
    """
    H, W = block.shape
    dt = block.dtype
    t2 = jnp.asarray(top, dt).reshape(1, W)
    b2 = jnp.asarray(bottom, dt).reshape(1, W)
    l2 = jnp.asarray(left, dt).reshape(H, 1)
    r2 = jnp.asarray(right, dt).reshape(H, 1)
    out_shapes = (
        jax.ShapeDtypeStruct((H, W), dt),          # new block
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # residual
        jax.ShapeDtypeStruct((1, W), dt),          # top edge
        jax.ShapeDtypeStruct((1, W), dt),          # bottom edge
        jax.ShapeDtypeStruct((H, 1), dt),          # left edge
        jax.ShapeDtypeStruct((H, 1), dt),          # right edge
    )
    new, res, te, be, le, re = pl.pallas_call(
        _gs_kernel,
        out_shape=out_shapes,
        interpret=interpret,
    )(block, t2, l2, b2, r2)
    return new, res[0, 0], (te.reshape(W), be.reshape(W),
                            le.reshape(H), re.reshape(H))
