"""Shims over jax API drift so the runtime spans 0.4.x and 0.5+.

The repo targets the current ``jax.shard_map`` / ``jax.sharding.AxisType``
surface; this module maps those calls onto the older spellings when running
under jax 0.4.x (where manual sharding lives in
``jax.experimental.shard_map`` and meshes have no axis types).  Keep every
version guard here — call sites should read like modern jax.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional

# jax is imported lazily inside each helper: launch/mesh.py (and the
# dry-run path behind it) must be importable before the first jax
# initialisation so XLA_FLAGS can still be set.


def mesh_axis_kwargs(n_axes: int) -> Dict[str, Any]:
    """``axis_types=`` kwarg for ``jax.make_mesh`` on any jax.

    ``jax.sharding.AxisType`` (and the kwarg) only exist from jax 0.5; on
    0.4.x every mesh axis is Auto-typed already, so the kwarg is omitted.
    """
    import jax
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def axis_size(axis_name: Any) -> int:
    """``jax.lax.axis_size`` (jax ≥ 0.5) on any jax.

    On 0.4.x, ``lax.psum`` of a Python literal is evaluated statically, so
    ``psum(1, axis)`` yields the axis size as a plain int — usable for
    reshapes and padding, exactly like the modern primitive.
    """
    import jax
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Iterable[Any]] = None,
              check_vma: bool = False):
    """``jax.shard_map`` with the modern keyword surface on any jax.

    ``axis_names`` selects the mesh axes that are *manual* inside ``f``
    (the rest stay auto); on jax 0.4.x this maps onto the old ``auto=``
    complement-set and ``check_vma`` onto ``check_rep``.
    """
    import jax
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kwargs)
    from jax.experimental.shard_map import shard_map as legacy
    manual = (frozenset(axis_names) if axis_names is not None
              else frozenset(mesh.axis_names))
    auto = frozenset(mesh.axis_names) - manual
    return legacy(f, mesh, in_specs, out_specs,
                  check_rep=check_vma, auto=auto)
