"""Composable block-pattern language model (decoder & encoder).

A model is ``cfg.pattern`` (a repeating unit of layers, each a tuple of
blocks) scanned ``cfg.repeats`` times with stacked parameters
(``jax.lax.scan`` over the leading layer dimension keeps HLO size — and
hence multi-pod compile time — independent of depth).  Heterogeneous
patterns (Zamba2's shared attention every 6 Mamba2 blocks, xLSTM's
mLSTM/sLSTM interleave) are expressed inside the unit; weights shared
across repeats (Zamba2's shared block) ride along as loop invariants while
their per-invocation KV caches are scanned.

Three entry modes:
* ``train``   — full sequence, logits for every position.
* ``prefill`` — full sequence, returns the serving cache.
* ``decode``  — one token against a fixed-size cache at ``cache_index``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import (ModelConfig, ATTN, SWA, SHARED_ATTN, MLP, MOE, MAMBA2,
                     SLSTM, MLSTM)

STATEFUL = (ATTN, SWA, SHARED_ATTN, MAMBA2, SLSTM, MLSTM)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key, kind: str, cfg: ModelConfig):
    if kind in (ATTN, SWA):
        return L.attn_init(key, cfg)
    if kind == MLP:
        return L.mlp_init(key, cfg)
    if kind == MOE:
        return L.moe_init(key, cfg)
    if kind == MAMBA2:
        return L.mamba2_init(key, cfg)
    if kind == SLSTM:
        return L.slstm_init(key, cfg)
    if kind == MLSTM:
        return L.mlstm_init(key, cfg)
    if kind == SHARED_ATTN:
        return {}  # weights live in params["shared"]
    raise ValueError(kind)


def _unit_init(key, cfg: ModelConfig):
    p: Dict[str, Any] = {}
    i = 0
    for li, layer in enumerate(cfg.pattern):
        for bi, kind in enumerate(layer):
            k1, k2, key = jax.random.split(jax.random.fold_in(key, i), 3)
            name = f"L{li}_{bi}_{kind}"
            p[name] = _block_init(k1, kind, cfg)
            if kind != SHARED_ATTN:
                p[f"L{li}_{bi}_norm"] = L.norm_init(cfg)
            i += 1
    return p


def init(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    params: Dict[str, Any] = {}
    if cfg.frontend != "audio":
        params["embed"] = (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model))
                           * 0.02).astype(cfg.activation_dtype)
    unit_keys = jax.random.split(ks[1], cfg.repeats)
    params["layers"] = jax.vmap(lambda k: _unit_init(k, cfg))(unit_keys)
    if any(SHARED_ATTN in layer for layer in cfg.pattern):
        params["shared"] = L.shared_attn_init(ks[2], cfg)
    params["final_norm"] = L.norm_init(cfg)
    if not cfg.tie_embeddings or cfg.frontend == "audio":
        params["lm_head"] = L._dense_init(ks[3], (cfg.d_model, cfg.vocab),
                                          cfg.activation_dtype)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def _block_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int):
    if kind in (ATTN, SWA, SHARED_ATTN):
        return L.attn_cache_init(cfg, batch, cache_len)
    if kind == MAMBA2:
        return L.mamba2_cache_init(cfg, batch)
    if kind == SLSTM:
        return L.slstm_cache_init(cfg, batch)
    if kind == MLSTM:
        return L.mlstm_cache_init(cfg, batch)
    return None


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Decode cache template, stacked over the unit-repeat dimension."""
    unit = {}
    for li, layer in enumerate(cfg.pattern):
        for bi, kind in enumerate(layer):
            c = _block_cache(kind, cfg, batch, cache_len)
            if c is not None:
                unit[f"L{li}_{bi}_{kind}"] = c
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.repeats,) + x.shape), unit)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------
class _NoConstrain:
    """Default no-op sharding-constraint hooks (runtime installs real ones)."""

    def __getattr__(self, name):
        return lambda x: x


def _unit_apply(x, unit_p, unit_cache, *, shared, x0, cfg: ModelConfig,
                angles, mode: str, cache_index, constrain):
    make_cache = mode == "prefill"
    decoding = mode == "decode"
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    for li, layer in enumerate(cfg.pattern):
        for bi, kind in enumerate(layer):
            name = f"L{li}_{bi}_{kind}"
            c = unit_cache.get(name) if unit_cache else None
            if kind == SHARED_ATTN:
                h, nc = L.shared_attn_apply(
                    shared, x, x0, cfg, angles=angles, cache=c,
                    cache_index=cache_index, make_cache=make_cache,
                    constrain=constrain)
                x = x + h
            else:
                xin = L.norm_apply(unit_p[f"L{li}_{bi}_norm"], x, cfg)
                nc = None
                if kind in (ATTN, SWA):
                    win = cfg.sliding_window if kind == SWA else None
                    h, nc = L.attn_apply(unit_p[name], xin, cfg,
                                         angles=angles, window=win, cache=c,
                                         cache_index=cache_index,
                                         make_cache=make_cache,
                                         constrain=constrain)
                elif kind == MLP:
                    h = L.mlp_apply(unit_p[name], xin, cfg,
                                    constrain=constrain)
                elif kind == MOE:
                    h, a = L.moe_apply(unit_p[name], xin, cfg,
                                       constrain=constrain)
                    aux = aux + a
                elif kind == MAMBA2:
                    h, nc = L.mamba2_apply(unit_p[name], xin, cfg, cache=c,
                                           make_cache=make_cache,
                                           constrain=constrain)
                elif kind == SLSTM:
                    h, nc = L.slstm_apply(unit_p[name], xin, cfg, cache=c,
                                          make_cache=make_cache,
                                          constrain=constrain)
                elif kind == MLSTM:
                    h, nc = L.mlstm_apply(unit_p[name], xin, cfg, cache=c,
                                          make_cache=make_cache,
                                          constrain=constrain)
                else:
                    raise ValueError(kind)
                x = x + h
            x = constrain.residual(x)
            if nc is not None and (make_cache or decoding):
                new_cache[name] = nc
    return x, new_cache, aux


def apply(params: Dict[str, Any], cfg: ModelConfig, batch: Dict[str, Any], *,
          mode: str = "train", cache=None, cache_index=None,
          constrain=None, remat: Optional[str] = None
          ) -> Tuple[jax.Array, Any, jax.Array]:
    """Forward pass.  Returns (logits, new_cache, aux_loss)."""
    constrain = constrain or _NoConstrain()

    if cfg.frontend == "audio":
        x = batch["embeds"].astype(cfg.activation_dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.frontend == "vlm" and mode != "decode" \
                and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    x = constrain.residual(x)

    if mode == "decode":
        positions = jnp.full((B, S), 0, jnp.int32) + cache_index
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (B, S))
    angles = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    x0 = x

    unit_fn = functools.partial(
        _unit_apply, shared=params.get("shared"), cfg=cfg, angles=angles,
        mode=mode, cache_index=cache_index, constrain=constrain)

    def scan_body(carry, xs):
        xc, aux_acc = carry
        unit_p, unit_cache = xs
        xc, new_cache, aux = unit_fn(xc, unit_p, unit_cache, x0=x0)
        return (xc, aux_acc + aux), new_cache

    if remat == "full":
        scan_body = jax.checkpoint(scan_body, prevent_cse=False)
    elif remat == "dots":
        scan_body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)

    cache_xs = cache if cache is not None else \
        jax.tree_util.tree_map(lambda *_: None, {})  # empty dict
    if cache is None:
        cache_xs = {}
    (x, aux), new_cache = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache_xs))

    x = L.norm_apply(params["final_norm"], x, cfg)
    if "lm_head" in params:
        logits = x @ params["lm_head"]
    else:
        logits = x @ params["embed"].T
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    logits = constrain.logits(logits)
    return logits, (new_cache if (mode != "train") else None), aux


def pad_cache(cfg: ModelConfig, cache, target_len: int):
    """Grow a prefill cache to a fixed decode size (KV time axis padding).

    SSM/xLSTM state caches are size-independent and pass through.
    """
    def pad(path, x):
        keys = "/".join(str(p) for p in path)
        if ("_attn" in keys or "_swa" in keys or "_shared" in keys) \
                and x.ndim == 5:  # (repeats, B, T, Hkv, dh)
            padn = target_len - x.shape[2]
            if padn > 0:
                x = jnp.pad(x, ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map_with_path(pad, cache)


# ---------------------------------------------------------------------------
# loss / utilities
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy in fp32.  logits: (B,S,V); labels: (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE experts scaled by top_k/E)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        size = int(leaf.size)
        keys = "/".join(str(p) for p in path)
        if "_moe" in keys and "router" not in keys:
            size = size * cfg.top_k // max(cfg.n_experts, 1)
        total += size
    return total
