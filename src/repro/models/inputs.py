"""Model input construction: abstract specs (dry-run) and concrete batches.

Per the assignment, ``[audio]``/``[vlm]`` modality frontends are stubs —
``input_specs()`` provides precomputed frame/patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeConfig


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    sd = jax.ShapeDtypeStruct
    dt = cfg.activation_dtype
    if cfg.frontend == "audio":
        batch: Dict[str, Any] = {"embeds": sd((B, S, cfg.d_model), dt)}
    else:
        batch = {"tokens": sd((B, S), jnp.int32)}
        if cfg.frontend == "vlm" and shape.kind != "decode":
            batch["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), dt)
    if shape.kind == "train":
        batch["labels"] = sd((B, S), jnp.int32)
    return batch


def make_batch(cfg: ModelConfig, *, batch: int, seq: int, kind: str = "train",
               key: Optional[jax.Array] = None) -> Dict[str, Any]:
    """Concrete random batch (smoke tests / examples / training driver)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    if cfg.frontend == "audio":
        out: Dict[str, Any] = {
            "embeds": jax.random.normal(ks[0], (batch, seq, cfg.d_model), dt)}
    else:
        out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                            cfg.vocab, jnp.int32)}
        if cfg.frontend == "vlm" and kind != "decode":
            out["patch_embeds"] = jax.random.normal(
                ks[1], (batch, cfg.n_patches, cfg.d_model), dt)
    if kind == "train":
        out["labels"] = jax.random.randint(ks[2], (batch, seq), 0,
                                           cfg.vocab, jnp.int32)
    return out
