"""Neural network blocks: norms, RoPE, GQA attention, MLP/MoE, SSM, xLSTM.

Functional style: every block has ``<block>_init(key, cfg) -> params`` and
``<block>_apply(params, x, ...) -> (y, new_cache)``.  Params are plain
pytrees (dicts of arrays) so sharding rules can be attached by path
(runtime/sharding.py) and stacked along a leading layer dimension for
``lax.scan`` over layers (models/model.py).

Numerics policy: weights and activations in ``cfg.dtype`` (bf16 by
default); norms, SSM decay/bias terms and recurrent states in fp32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig

Params = Dict[str, Any]
Cache = Optional[Dict[str, Any]]


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(jnp.maximum(fan_in, 1))).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim // 2) fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def rope_apply(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, D); angles: (B, S, D//2) or (S, D//2)."""
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; global or sliding window)
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    dh, H, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.activation_dtype
    return {
        "wq": _dense_init(ks[0], (d, H * dh), dt),
        "wk": _dense_init(ks[1], (d, Hkv * dh), dt),
        "wv": _dense_init(ks[2], (d, Hkv * dh), dt),
        "wo": _dense_init(ks[3], (H * dh, d), dt),
    }


def attn_cache_init(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    dt = cfg.activation_dtype
    return {"k": jnp.zeros((batch, cache_len, Hkv, dh), dt),
            "v": jnp.zeros((batch, cache_len, Hkv, dh), dt)}


def attn_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
               angles: jax.Array, window: Optional[int] = None,
               cache: Cache = None, cache_index: Optional[jax.Array] = None,
               make_cache: bool = False, constrain=None
               ) -> Tuple[jax.Array, Cache]:
    B, S, d = x.shape
    dh, H, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    q = rope_apply(q, angles)
    k = rope_apply(k, angles)
    if constrain is not None:
        q, k, v = constrain.heads(q), constrain.heads(k), constrain.heads(v)

    new_cache: Cache = None
    if cache is not None and cache_index is not None:
        # decode: write new k/v at cache_index, attend over the cache
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_index, 0, 0))
        new_cache = {"k": kc, "v": vc}
        valid = jnp.full((B,), cache_index + S, jnp.int32)
        offs = jnp.full((B,), cache_index, jnp.int32)
        o = ops.attention_ref(q, kc, vc, causal=cfg.causal, window=window,
                              kv_valid_len=valid, q_offset=offs)
    else:
        o = ops.flash_attention(q, k, v, causal=cfg.causal, window=window,
                                constrain=constrain)
        if make_cache:
            new_cache = {"k": k, "v": v}
    y = o.reshape(B, S, H * dh) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wg": _dense_init(ks[0], (d, ff), dt),
                "wu": _dense_init(ks[1], (d, ff), dt),
                "wd": _dense_init(ks[2], (ff, d), dt)}
    return {"wu": _dense_init(ks[0], (d, ff), dt),
            "wd": _dense_init(ks[1], (ff, d), dt)}


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
              constrain=None) -> jax.Array:
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    if constrain is not None:
        h = constrain.ffn(h)
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based dispatch, expert-parallel friendly)
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "wg": _dense_init(ks[1], (E, d, ff), dt, fan_in=d),
        "wu": _dense_init(ks[2], (E, d, ff), dt, fan_in=d),
        "wd": _dense_init(ks[3], (E, ff, d), dt, fan_in=ff),
    }


def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
              constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss).  Grouped sort-based dispatch into a
    fixed-capacity expert layout.

    Sharding-aware formulation (GShard-style local groups, adapted to the
    sort-based megablocks dispatch): tokens are split into G = batch
    dispatch groups (one per sequence), each group routes and sorts ONLY
    its own tokens — so the sort/scatter stay local to the data shard and
    the only cross-shard movement is the (G-sharded → E-sharded)
    redistribution of the dense (G, E, C, d) expert buffers, which GSPMD
    lowers to the MoE all-to-all.  A global-token sort would be
    unpartitionable (verified: it replicates the dispatch buffers and
    blows temp memory three orders of magnitude past HBM).

    The MXU-dense (E, C, d) capacity tiles are the TPU adaptation of
    megablocks' ragged CSR tiles; overflow beyond an expert's per-group
    capacity is dropped (Switch-style).  Cost: O(T·k·d·ff) expert compute,
    O(T·k log) local sorts, no one-hot dispatch einsum.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = B                       # one dispatch group per sequence
    Tg = S                      # tokens per group
    Tk = Tg * k                 # routed rows per group

    xt = x.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)                  # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch/olmoe style), global over tokens
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    cap = int(Tk / E * cfg.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)
    cap = min(cap, Tk)

    # --- gather-only dispatch (no scatters: XLA scatters materialise
    # row×d index tensors and partition poorly; every step below is a sort
    # or a take_along_axis whose index arrays have no feature dim) --------
    flat_e = eidx.reshape(G, Tk)                               # (G, Tk)
    order = jnp.argsort(flat_e, axis=-1)                       # per-group
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_of = order // k                                        # (G, Tk)
    # group-local histogram via binary search on the sorted ids (gather-
    # friendly; a one-hot here would be (G,Tg,k,E) ≈ TBs for 64 experts)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype),
                                    side="left"))(sorted_e)    # (G, E)
    counts = jnp.diff(
        jnp.concatenate([starts, jnp.full((G, 1), Tk, starts.dtype)],
                        axis=-1), axis=-1)                     # (G, E)

    # expert e's capacity slot c holds sorted row starts[e] + c (if valid)
    slot_rows = starts[:, :, None] + jnp.arange(cap)[None, None]  # (G,E,cap)
    slot_valid = jnp.arange(cap)[None, None] < counts[:, :, None]
    slot_rows = jnp.clip(slot_rows, 0, Tk - 1).reshape(G, E * cap)
    src_tok = jnp.take_along_axis(tok_of, slot_rows, axis=-1)  # (G, E*cap)
    xe = jnp.take_along_axis(xt, src_tok[..., None], axis=1)   # (G,E*cap,d)
    xe = xe * slot_valid.reshape(G, E * cap, 1).astype(x.dtype)
    xe = xe.reshape(G, E, cap, d)
    if constrain is not None:
        xe = constrain.experts(xe)                             # G:dp, E:ep
    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    if constrain is not None:
        # Gather FSDP-sharded expert weights before use: contracting over
        # an FSDP-sharded d/ff dim would otherwise emit partial-sum
        # all-reduces of the (G,E,C,ff) activations — orders of magnitude
        # more wire bytes than re-gathering the weights.
        wg, wu, wd = (constrain.expert_weights(w) for w in (wg, wu, wd))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wg)) \
        * jnp.einsum("gecd,edf->gecf", xe, wu)
    ye = jnp.einsum("gecf,efd->gecd", h, wd)                   # (G,E,cap,d)
    if constrain is not None:
        ye = constrain.experts(ye)

    # return path, also gather-only: row r (sorted) sits in buffer slot
    # sorted_e[r]*cap + (r - starts[sorted_e[r]]) when within capacity;
    # token t collects its k rows through the inverse permutation.
    slot_of_row = jnp.arange(Tk)[None] - \
        jnp.take_along_axis(starts, sorted_e, axis=-1)         # (G, Tk)
    keep = slot_of_row < cap
    buf_pos = jnp.clip(sorted_e * cap + slot_of_row, 0, E * cap - 1)
    inv = jnp.argsort(order, axis=-1)                          # (G, Tk)
    ye_flat = ye.reshape(G, E * cap, d)
    row_pos = jnp.take_along_axis(buf_pos, inv, axis=-1)       # by (t, j)
    row_keep = jnp.take_along_axis(keep, inv, axis=-1)
    contrib = jnp.take_along_axis(ye_flat, row_pos[..., None], axis=1)
    w = (gate_vals.reshape(G, Tk)
         * row_keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.sum((contrib * w[..., None]).reshape(G, Tg, k, d), axis=2)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba2 block (SSD)
# ---------------------------------------------------------------------------
def mamba2_init(key, cfg: ModelConfig) -> Params:
    d, din, n, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 8)
    conv_ch = din + 2 * n
    return {
        "wz": _dense_init(ks[0], (d, din), dt),
        "wx": _dense_init(ks[1], (d, din), dt),
        "wB": _dense_init(ks[2], (d, n), dt),
        "wC": _dense_init(ks[3], (d, n), dt),
        "wdt": _dense_init(ks[4], (d, H), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (cfg.ssm_conv, conv_ch))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "gn": jnp.ones((din,), jnp.float32),        # gated RMSNorm scale
        "out": _dense_init(ks[6], (din, d), dt),
    }


def mamba2_cache_init(cfg: ModelConfig, batch: int) -> Params:
    din, n, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = din // H
    return {"state": jnp.zeros((batch, H, P, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * n),
                              cfg.activation_dtype)}


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).  Returns (y, new_cache)."""
    K = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_cache = xp[:, -(K - 1):, :] if K > 1 else None
    return (y + b.astype(x.dtype)), new_cache


def mamba2_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 cache: Cache = None, make_cache: bool = False,
                 constrain=None) -> Tuple[jax.Array, Cache]:
    B, S, d = x.shape
    din, n, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = din // H
    z = x @ p["wz"]
    xin = x @ p["wx"]
    Bp = x @ p["wB"]
    Cp = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xbc = jnp.concatenate([xin, Bp, Cp], axis=-1)
    conv_cache = cache.get("conv") if cache else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xbc = jax.nn.silu(xbc)
    xin, Bp, Cp = jnp.split(xbc, [din, din + n], axis=-1)

    xh = xin.reshape(B, S, H, P)
    if constrain is not None:
        xh = constrain.ssm_heads(xh)
    if cache is not None and S == 1:
        state, y = ops.ssd_decode_step(
            cache["state"], xh[:, 0], dt[:, 0], A, Bp[:, 0], Cp[:, 0])
        y = y[:, None].astype(x.dtype)
    else:
        init = cache["state"] if cache else None
        y, state = ops.mamba2_ssd(xh, dt.astype(xh.dtype), A, Bp, Cp,
                                  chunk=cfg.ssm_chunk, init_state=init)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, din)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    g = g * jax.lax.rsqrt(jnp.mean(jnp.square(g), -1, keepdims=True)
                          + cfg.norm_eps) * p["gn"]
    out = g.astype(x.dtype) @ p["out"]

    new_cache: Cache = None
    if make_cache or cache is not None:
        new_cache = {"state": state, "conv": new_conv}
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory, chunkwise parallel)
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = int(cfg.mlstm_proj_factor * d)
    H = cfg.n_heads
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 8)
    return {
        "wup": _dense_init(ks[0], (d, 2 * din), dt),
        "wq": _dense_init(ks[1], (din, din), dt),
        "wk": _dense_init(ks[2], (din, din), dt),
        "wv": _dense_init(ks[3], (din, din), dt),
        "wi": _dense_init(ks[4], (din, H), jnp.float32),
        "bi": jnp.zeros((H,), jnp.float32),
        "wf": _dense_init(ks[5], (din, H), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "gn": jnp.ones((din,), jnp.float32),
        "wd": _dense_init(ks[6], (din, d), dt),
    }


def mlstm_cache_init(cfg: ModelConfig, batch: int) -> Params:
    din = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = din // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                cache: Cache = None, make_cache: bool = False,
                constrain=None) -> Tuple[jax.Array, Cache]:
    B, S, d = x.shape
    din = int(cfg.mlstm_proj_factor * d)
    H = cfg.n_heads
    dh = din // H
    up = x @ p["wup"]
    z, xi = jnp.split(up, 2, axis=-1)
    q = (xi @ p["wq"]).reshape(B, S, H, dh) * (dh ** -0.5)
    k = (xi @ p["wk"]).reshape(B, S, H, dh)
    v = (xi @ p["wv"]).reshape(B, S, H, dh)
    ig = xi.astype(jnp.float32) @ p["wi"] + p["bi"]            # (B,S,H)
    fg = xi.astype(jnp.float32) @ p["wf"] + p["bf"]

    init = None
    if cache is not None:
        init = (cache["C"], cache["n"],
                jnp.where(cache["m"] <= -1e29, -jnp.inf, cache["m"]))
    if cache is not None and S == 1:
        state, y = ops.mlstm_decode_step(init, q[:, 0], k[:, 0], v[:, 0],
                                         ig[:, 0], fg[:, 0])
        y = y[:, None].astype(x.dtype)
    else:
        chunk = min(cfg.ssm_chunk, S)
        y, state = ops.mlstm_chunked(q, k, v, ig, fg, chunk=chunk, init=init)
    y = y.reshape(B, S, din).astype(jnp.float32)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True)
                          + cfg.norm_eps) * p["gn"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["wd"]

    new_cache: Cache = None
    if make_cache or cache is not None:
        C, n, m = state
        new_cache = {"C": C, "n": n,
                     "m": jnp.where(jnp.isfinite(m), m, -1e30)}
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (scalar memory, sequential recurrence)
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 11)
    ffd = int(cfg.slstm_proj_factor * d)
    ffd = -(-ffd // 64) * 64
    p = {"gn": jnp.ones((d,), jnp.float32),
         "wu": _dense_init(ks[8], (d, ffd), dt),
         "wd2": _dense_init(ks[9], (ffd, d), dt)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = _dense_init(ks[i], (d, d), jnp.float32)
        p[f"r{g}"] = (_dense_init(ks[4 + i], (H, dh, dh), jnp.float32, dh))
        p[f"b{g}"] = (jnp.full((d,), 1.0, jnp.float32) if g == "f"
                      else jnp.zeros((d,), jnp.float32))
    return p


def slstm_cache_init(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32)}


def _slstm_step(p, cfg, state, xt):
    """One sLSTM step.  xt: (B, d) fp32 pre-projected gate inputs."""
    c, n, h, m = state
    B = h.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    hh = h.reshape(B, H, dh)

    def rec(g):
        return jnp.einsum("bhd,hde->bhe", hh, p[f"r{g}"]).reshape(B, -1)

    it = xt @ p["wi"] + p["bi"] + rec("i")
    ft = xt @ p["wf"] + p["bf"] + rec("f")
    zt = jnp.tanh(xt @ p["wz"] + p["bz"] + rec("z"))
    ot = jax.nn.sigmoid(xt @ p["wo"] + p["bo"] + rec("o"))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c = f_s * c + i_s * zt
    n = f_s * n + i_s
    h = ot * (c / jnp.maximum(n, 1e-6))
    return (c, n, h, m_new)


def slstm_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                cache: Cache = None, make_cache: bool = False,
                constrain=None) -> Tuple[jax.Array, Cache]:
    B, S, d = x.shape
    xf = x.astype(jnp.float32)
    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        state = (jnp.zeros((B, d), jnp.float32),
                 jnp.ones((B, d), jnp.float32),
                 jnp.zeros((B, d), jnp.float32),
                 jnp.zeros((B, d), jnp.float32))

    def step(carry, xt):
        new = _slstm_step(p, cfg, carry, xt)
        return new, new[2]  # h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xf, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True)
                          + cfg.norm_eps) * p["gn"]
    y = y.astype(x.dtype)
    y = jax.nn.gelu(y @ p["wu"]) @ p["wd2"]
    new_cache: Cache = None
    if make_cache or cache is not None:
        new_cache = {"c": state[0], "n": state[1], "h": state[2],
                     "m": state[3]}
    return y, new_cache


# ---------------------------------------------------------------------------
# Zamba2-style shared attention block (one weight set reused across depth)
# ---------------------------------------------------------------------------
def shared_attn_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, ff = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype
    return {
        "win": _dense_init(ks[0], (2 * d, d), dt),  # concat(x, embeds) proj
        "norm": norm_init(cfg),
        "attn": attn_init(ks[1], cfg),
        "norm2": norm_init(cfg),
        "mlp": {"wg": _dense_init(ks[2], (d, ff), dt),
                "wu": _dense_init(ks[3], (d, ff), dt),
                "wd": _dense_init(jax.random.fold_in(key, 9), (ff, d), dt)},
    }


def shared_attn_apply(p: Params, x: jax.Array, x0: jax.Array,
                      cfg: ModelConfig, *, angles, cache: Cache = None,
                      cache_index=None, make_cache: bool = False,
                      constrain=None) -> Tuple[jax.Array, Cache]:
    h = jnp.concatenate([x, x0], axis=-1) @ p["win"]
    a_in = norm_apply(p["norm"], h, cfg)
    a, new_cache = attn_apply(p["attn"], a_in, cfg, angles=angles,
                              cache=cache, cache_index=cache_index,
                              make_cache=make_cache, constrain=constrain)
    h = h + a
    m_in = norm_apply(p["norm2"], h, cfg)
    h = h + (jax.nn.silu(m_in @ p["mlp"]["wg"]) * (m_in @ p["mlp"]["wu"])) \
        @ p["mlp"]["wd"]
    return h, new_cache
