"""Model / run configuration dataclasses.

One :class:`ModelConfig` describes any architecture in the assigned pool via
a *block pattern*: a repeating unit of block kinds (attention, SWA, MLP, MoE,
Mamba2, sLSTM, mLSTM, shared attention) applied pre-norm with residual
connections.  `configs/<arch>.py` instantiates the exact published
configurations.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# Block kinds
ATTN = "attn"              # global GQA attention
SWA = "swa"                # sliding-window GQA attention
SHARED_ATTN = "shared_attn"  # Zamba2-style: one shared weight set, reused
MLP = "mlp"                # SwiGLU / GeLU MLP
MOE = "moe"                # top-k mixture of experts
MAMBA2 = "mamba2"          # state-space dual (SSD) block
SLSTM = "slstm"            # xLSTM scalar-memory block (sequential recurrence)
MLSTM = "mlstm"            # xLSTM matrix-memory block (chunkwise parallel)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # Block pattern: the repeating unit; len(pattern)*repeats == n_layers.
    # Each entry is a tuple of block kinds executed inside one "layer".
    pattern: Tuple[Tuple[str, ...], ...] = ((ATTN, MLP),)

    # attention
    d_head: Optional[int] = None     # default d_model // n_heads
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    causal: bool = True              # False for encoder-only architectures

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0               # Mamba2 heads; default d_inner // 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # xLSTM
    slstm_proj_factor: float = 4.0 / 3.0
    mlstm_proj_factor: float = 2.0

    # frontends ([audio]/[vlm] are stubs: precomputed embeddings)
    frontend: Optional[str] = None   # None | "audio" | "vlm"
    n_patches: int = 256             # vlm: image patch positions

    # MLP activation
    act: str = "swiglu"              # swiglu | gelu

    # numerics
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # citation
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else \
            self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def scaled(self, **kw) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/pattern, tiny dims)."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Assignment rules: encoder-only archs skip decode shapes; long_500k
    runs only for architectures with sub-quadratic sequence mixing
    (SSM/hybrid); full-attention archs skip it (see DESIGN.md)."""
    shapes = [TRAIN_4K, PREFILL_32K]
    if cfg.causal:  # decoder: has a decode step
        shapes.append(DECODE_32K)
        if cfg.family in ("ssm", "hybrid"):
            shapes.append(LONG_500K)
    return tuple(shapes)
