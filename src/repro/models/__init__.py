from . import config, layers, model
from .config import (ModelConfig, ShapeConfig, ALL_SHAPES, TRAIN_4K,
                     PREFILL_32K, DECODE_32K, LONG_500K, applicable_shapes,
                     shape_by_name)
from .model import init, apply, init_cache, lm_loss, param_count, \
    active_param_count

__all__ = [
    "config", "layers", "model", "ModelConfig", "ShapeConfig", "ALL_SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "applicable_shapes", "shape_by_name", "init", "apply", "init_cache",
    "lm_loss", "param_count", "active_param_count",
]
