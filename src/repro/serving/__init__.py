"""repro.serving — continuous-batching LM serving on the task runtime.

Task-aware serving: every prefill, decode micro-step, and host
detokenisation is a :class:`repro.core.executor.TaskRuntime` task, bound
to device/communication completion through the unified
:class:`repro.core.tac.AsyncHandle` protocol — continuous batching,
compute/host overlap, and ULFM failure recovery all fall out of the
runtime the training path already uses.  See ``docs/api.md`` and the
"Serving" section of ``docs/architecture.md``.
"""

from ..obs.metrics import MetricSink, TokenRecord, percentile
from .engine import ServingEngine
from .metrics import ServeReport
from .queue import RequestQueue
from .request import Request, RequestState
from .synthetic import SyntheticAdapter, token_at

__all__ = [
    "ServingEngine", "Request", "RequestState", "RequestQueue",
    "ServeReport", "TokenRecord", "MetricSink", "percentile",
    "SyntheticAdapter", "token_at",
]
