"""Admission queue of the continuous-batching scheduler.

FCFS within a priority class (lower ``priority`` values run first);
arrival order is preserved by a monotone sequence number, so two
requests of equal priority never reorder.  Two re-entry points:

* :meth:`RequestQueue.push` — normal arrival (and preemption victims,
  which go to the BACK of their class so a preempted request cannot
  immediately preempt someone else — no thrash).
* :meth:`RequestQueue.push_front` — failure re-admission: a request
  evicted because a *rank* died (not because it lost an admission
  race) resumes at the head of its class.
"""

from __future__ import annotations

import heapq
import itertools
import threading
from typing import List, Optional, Tuple

from .request import Request

__all__ = ["RequestQueue"]


class RequestQueue:
    """Priority-then-FCFS admission queue (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._heap: List[Tuple[int, int, Request]] = []
        self._seq = itertools.count()
        # push_front entries take sequence numbers counting DOWN from 0,
        # so within a priority class they beat every normal arrival.
        self._front_seq = itertools.count(-1, -1)

    def push(self, req: Request) -> None:
        with self._lock:
            heapq.heappush(self._heap, (req.priority, next(self._seq), req))

    def push_front(self, req: Request) -> None:
        with self._lock:
            heapq.heappush(self._heap,
                           (req.priority, next(self._front_seq), req))

    def pop(self) -> Optional[Request]:
        with self._lock:
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Request]:
        with self._lock:
            return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)

    def __bool__(self) -> bool:
        return len(self) > 0
