"""LM adapter: the real model path behind the serving engine.

Wraps the framework's sharded prefill/decode steps
(:func:`repro.runtime.steps.build_prefill_step` /
:func:`~repro.runtime.steps.build_decode_step`) into the adapter
protocol of :class:`repro.serving.engine.ServingEngine`: each request
runs batch-1 greedy decoding with its own fixed-size KV cache, handed
off from prefill via :func:`repro.models.model.pad_cache`.  Device
values stay un-synchronised — XLA's async dispatch is the in-flight
operation, and the engine binds completion through
``tac.as_handle(token)`` (an :class:`repro.core.tac.ArrayHandle`), so
the event leg overlaps host detokenisation with the next decode steps.

``Request.prompt`` is an integer seed; the prompt tokens are drawn with
:func:`repro.models.inputs.make_batch` under that seed, which keeps the
two completion legs (and re-admissions after eviction) bit-identical.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model, inputs as model_inputs
from ..runtime import steps
from .request import Request

__all__ = ["LMAdapter"]


class LMAdapter:
    """Batch-1 greedy-decode adapter over the sharded step functions."""

    def __init__(self, cfg: Any, mesh: Any, policy: Any, params: Any, *,
                 prompt_len: int, gen_len: int) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.prompt_len = prompt_len
        self.total_len = prompt_len + gen_len
        with mesh:
            batch = model_inputs.make_batch(
                cfg, batch=1, seq=prompt_len, kind="prefill",
                key=jax.random.PRNGKey(0))
            self._prefill = steps.build_prefill_step(
                cfg, mesh, policy,
                abstract_batch=jax.eval_shape(lambda: batch))
            dec_spec = jax.eval_shape(
                lambda: {"tokens": jnp.zeros((1, 1), jnp.int32)})
            self._decode, _ = steps.build_decode_step(
                cfg, mesh, policy, batch=1, cache_len=self.total_len,
                abstract_batch=dec_spec, donate=False)

    # -- the adapter protocol -----------------------------------------------
    def warmup(self) -> None:
        """Compile prefill + decode outside any timed region."""
        req = Request(rid=-1, prompt=0, gen_len=2)
        tok, state = self.prefill(req)
        tok, _ = self.decode(req, state, 1)
        jax.block_until_ready(tok)

    def prefill(self, req: Request) -> Tuple[Any, Any]:
        key = jax.random.PRNGKey(int(req.prompt))
        with self.mesh:
            batch = model_inputs.make_batch(
                self.cfg, batch=1, seq=self.prompt_len, kind="prefill",
                key=key)
            logits, cache = self._prefill(self.params, batch)
            cache = model.pad_cache(self.cfg, cache, self.total_len)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok, (cache, tok)

    def decode(self, req: Request, state: Any,
               step: int) -> Tuple[Any, Any]:
        cache, prev = state
        with self.mesh:
            logits, cache = self._decode(
                self.params, cache, {"tokens": prev[:, None]},
                jnp.int32(self.prompt_len + step - 1))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok, (cache, tok)

    def detok(self, req: Request, step: int, tok: Any) -> int:
        return int(np.asarray(tok)[0])
