"""Synthetic model adapter: async device queue, deterministic tokens.

The bench/test stand-in for the LM adapter
(:class:`repro.serving.lm.LMAdapter`): device micro-steps run on a
**device queue** — a thread pool standing in for the accelerator's
streams — and return :class:`concurrent.futures.Future`\\ s, so the
engine's handles are push-capable
(:class:`repro.core.tac.FutureHandle` → the continuation engine is
notified at completion, zero polls) and the device latency is genuinely
asynchronous: it costs wall-clock but no CPU, exactly like a kernel
executing on an accelerator while the host runs tasks.

That asymmetry is what separates the two completion legs: the
blocking-sentinel leg parks a *worker* inside every device wait, so at
most ``num_workers`` requests make progress; the event-bound leg frees
the worker at dispatch (``tac.iwait``) and every admitted request's
chain advances at device latency.  Host detokenisation is sha256 work
(GIL-releasing, cache-resident).

Tokens are a pure function of ``(prompt seed, step)`` — the two legs
must emit bit-identical streams, and an evicted request re-generates
the same tokens after re-admission.
"""

from __future__ import annotations

import concurrent.futures
import hashlib
import time
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .request import Request

__all__ = ["SyntheticAdapter", "token_at"]


def token_at(seed: int, step: int) -> int:
    """The deterministic token stream: pure in (seed, step)."""
    return (seed + 31 * step + 7) % 997


class SyntheticAdapter:
    """Deterministic adapter with tunable device/host cost.

    ``dev_ms`` is the device latency of one micro-step (slept on the
    device-queue thread — off-CPU, like an accelerator); ``dim`` sizes
    the real jitted computation dispatched with it; ``host_rounds``
    sizes the sha256 host work of one detokenisation; ``streams`` is
    the device queue's concurrency (how many micro-steps the "device"
    overlaps).  ``request.prompt`` is the integer seed.
    """

    def __init__(self, *, dev_ms: float = 4.0, host_rounds: int = 8,
                 dim: int = 64, streams: int = 16) -> None:
        self.dev_ms = dev_ms
        self.host_rounds = host_rounds
        self.dim = dim
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=streams, thread_name_prefix="synth-device")
        w = np.linalg.qr(np.random.default_rng(0)
                         .standard_normal((dim, dim)))[0]
        self._w = jnp.asarray(w, jnp.float32)

        def _step(vec: jax.Array, seed: jax.Array,
                  step: jax.Array) -> Tuple[jax.Array, jax.Array]:
            vec = jnp.tanh(vec @ self._w)
            tok = (seed + 31 * step + 7) % 997
            return tok.astype(jnp.int32), vec

        self._step = jax.jit(_step)
        self._host_buf = bytes(range(256)) * 256    # 64 KiB, L2-resident

    def _device(self, seed: int, vec: jax.Array,
                step: int) -> Tuple[np.ndarray, jax.Array]:
        """One micro-step on the device queue: latency + computation."""
        time.sleep(self.dev_ms * 1e-3)      # accelerator time, off-CPU
        tok, vec = self._step(vec, jnp.int32(seed), jnp.int32(step))
        return np.asarray(tok), vec         # future done == value ready

    # -- the adapter protocol -----------------------------------------------
    def warmup(self) -> None:
        """Compile the step function (outside any timed region)."""
        vec = jnp.zeros((self.dim,), jnp.float32)
        self._step(vec, jnp.int32(0), jnp.int32(0))[0].block_until_ready()

    def prefill(self, req: Request) -> Tuple[Any, Any]:
        """Dispatch the prompt pass; returns (first-token future, state)."""
        seed = int(req.prompt)
        vec = jnp.full((self.dim,), (seed % 13) / 13.0, jnp.float32)
        fut = self._pool.submit(self._device, seed, vec, 0)
        return fut, (seed, fut)

    def decode(self, req: Request, state: Any,
               step: int) -> Tuple[Any, Any]:
        """Dispatch one decode micro-step; returns (token future, state).

        The previous step's future is resolved here — by chain ordering
        it is already complete (the event leg released the chain at
        device completion; the blocking leg waited on it)."""
        seed, prev = state
        _, vec = prev.result()
        fut = self._pool.submit(self._device, seed, vec, step)
        return fut, (seed, fut)

    def detok(self, req: Request, step: int, tok: Any) -> int:
        """Host detokenisation: sha256 host work + the token value."""
        if hasattr(tok, "result"):          # event leg: completed future
            tok = tok.result()
        if isinstance(tok, tuple):          # (token, state-vector) pair
            tok = tok[0]
        h = hashlib.sha256()
        for _ in range(self.host_rounds):
            h.update(self._host_buf)
        assert h.digest()
        return int(np.asarray(tok))

    def close(self) -> None:
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "SyntheticAdapter":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
