"""Continuous-batching inference engine on the task runtime.

The serving tentpole: requests flow through a
:class:`repro.serving.queue.RequestQueue`, an admission/eviction
scheduler keeps at most ``slots`` of them in flight, and EVERY unit of
work — each prefill, each decode micro-step, each host detokenisation —
is one :class:`repro.core.executor.TaskRuntime` task.  Dataflow tokens
(``Request.chain`` / ``Request.detok_chain``) order one request's
micro-steps; requests share nothing, so the runtime interleaves them
freely — continuous batching falls out of task dependencies, there is
no batching loop.

Two completion legs, selected by ``completion=``:

* ``"event"`` (the paper's discipline) — the decode task only
  *dispatches* device work and completes an
  :class:`repro.core.tac.EventHandle`; a separate detok task is bound to
  that event through the unified :class:`repro.core.tac.AsyncHandle`
  protocol (``tac.wait`` → continuation engine), so host
  detokenisation overlaps the next decode steps and the device chain
  never stalls.
* ``"blocking"`` (the sentinel baseline of paper §7.1) — the decode
  task synchronises the device result and detokenises inline, chaining
  host work into the device-step dependency chain exactly like the
  artificial sentinel dependency the paper removes.

Both legs emit identical tokens (asserted by
``tests/test_serving.py``); ``benchmarks/serve_bench.py`` measures the
throughput/latency gap.

Failure handling reuses the ULFM path of :mod:`repro.core.resilience`:
run the engine stepwise (``sync_every=1``) over a
:class:`repro.core.tac.CommWorld` and a tensor-parallel allreduce rides
every micro-step; when a rank dies, the collective surfaces
:class:`~repro.core.tac.RankFailedError` out of ``taskwait``, the
scheduler evicts every in-flight request back to the queue head,
revokes + shrinks the world (:func:`repro.core.resilience.recover`),
rebuilds the collectives over the survivors, and re-admits — each
request restarts from prefill under a fresh incarnation, so its state
machine survives the failure.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core import resilience, tac
from ..core.collectives import Collectives
from ..core.executor import TaskError, TaskRuntime
from ..core.tac import CommRevokedError, RankFailedError
from ..obs import trace as _tr
from ..obs.metrics import MetricSink, TokenRecord
from .metrics import ServeReport
from .queue import RequestQueue
from .request import Request, RequestState

__all__ = ["ServingEngine"]


class ServingEngine:
    """Admission/eviction scheduler + task-graph executor for serving.

    ``adapter`` supplies the model: ``prefill(request) -> (tok, state)``
    dispatches the prompt pass and returns the first generated token
    (device value) plus the decode state (KV cache);
    ``decode(request, state, step) -> (tok, state)`` dispatches one
    decode micro-step; ``detok(request, step, tok) -> value`` is the
    host-side detokenisation of a device-complete token.

    ``slots`` bounds concurrent in-flight requests; ``priority`` decides
    preemption — in stepwise mode a queued request preempts (evicts) a
    strictly lower-priority in-flight one when no slot is free.

    ``world=`` + ``tp_elems>0`` adds a tensor-parallel allreduce over
    the communicator to every micro-step; with ``sync_every=1`` a rank
    failure is recovered ULFM-style (see module docstring).
    """

    def __init__(self, adapter: Any, *, slots: int = 4,
                 completion: str = "event",
                 runtime: Optional[TaskRuntime] = None,
                 num_workers: Optional[int] = None,
                 notify: Optional[str] = None,
                 sync_every: int = 0,
                 world: Any = None, tp_elems: int = 0,
                 on_round: Optional[Callable[["ServingEngine", int],
                                             None]] = None) -> None:
        if completion not in ("event", "blocking"):
            raise ValueError(f"unknown completion leg {completion!r}; "
                             f"one of ['event', 'blocking']")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if sync_every < 0:
            raise ValueError(f"sync_every must be >= 0, got {sync_every}")
        self.adapter = adapter
        self.slots = slots
        self.completion = completion
        if completion == "event":
            # The event leg NEEDS the TASK_MULTIPLE interoperability
            # level: without it tac.iwait degrades to a blocking wait
            # inside the decode task body (the legacy-library fallback
            # of §6.3) and the leg silently becomes the sentinel.
            tac.init(tac.TASK_MULTIPLE)
        self.sync_every = sync_every
        self.on_round = on_round
        self._ext_runtime = runtime
        self._num_workers = num_workers or max(4, slots + 2)
        self._notify = notify

        self._world = world
        self._tp_elems = tp_elems
        self._comm = world
        self._coll = (Collectives(world) if world is not None
                      and tp_elems > 0 else None)

        self._lock = threading.Lock()
        self.queue = RequestQueue()
        self.active: Dict[int, Request] = {}
        self.metrics = MetricSink()
        self.recoveries = 0
        self.admission_log: List[int] = []   # rids, in admission order
        self.eviction_log: List[int] = []    # rids, in eviction order
        self._t0 = 0.0

    # -- task bodies --------------------------------------------------------
    def _tp_allreduce(self, req: Request, step: int) -> None:
        """The tensor-parallel leg of one micro-step (optional)."""
        if self._coll is None:
            return
        n = self._coll.world.size
        val = np.ones(self._tp_elems, np.float32)
        self._coll.run_group(
            "allreduce", [{"value": val} for _ in range(n)],
            op="sum", key=("tp", req.rid, req.incarnation, step))

    def _device_step(self, req: Request, step: int) -> Any:
        """One prefill/decode micro-step on the request's device chain.

        Event leg: dispatch, then ``tac.iwait`` the token's handle — the
        task body returns immediately and its *dependency release* is
        bound to device completion through the continuation engine
        (§6.2), so the detok task's RAW dependency opens exactly when
        the token is ready and nobody ever blocks a worker.

        Blocking-sentinel leg: OS-blocking ``handle.wait()`` (the PMPI
        path) plus host detok INSIDE the device chain — the artificial
        serialisation of paper §7.1.
        """
        t0 = time.monotonic() if _tr.TRACING else 0.0
        self._tp_allreduce(req, step)
        if step == 0:
            tok, state = self.adapter.prefill(req)
            with self._lock:
                req.cache = state
                if req.state is RequestState.PREFILL:
                    req.to(RequestState.DECODE)
        else:
            tok, state = self.adapter.decode(req, req.cache, step)
            req.cache = state
        if self.completion == "event":
            req._toks[step] = tok       # type: ignore[attr-defined]
            tac.iwait(tac.as_handle(tok))
            if _tr.TRACING:
                _tr.TRACER.span("serving", "device_step", t0,
                                time.monotonic(), rid=req.rid, step=step,
                                completion=self.completion)
            return tok
        tok = tac.as_handle(tok).wait()     # blocks this worker
        self._emit(req, step, tok)
        if _tr.TRACING:
            _tr.TRACER.span("serving", "device_step", t0, time.monotonic(),
                            rid=req.rid, step=step,
                            completion=self.completion)
        return tok

    def _detok_task(self, req: Request, step: int) -> None:
        """Event-leg host consumer: runs once its RAW dependency on the
        decode task releases — i.e. once the device value completed —
        so the token is ready and the host work starts immediately."""
        tok = req._toks.pop(step, None)     # type: ignore[attr-defined]
        if tok is None:
            return      # the producing step failed; nothing to emit
        t0 = time.monotonic() if _tr.TRACING else 0.0
        self._emit(req, step, tok)
        if _tr.TRACING:
            _tr.TRACER.span("serving", "detok", t0, time.monotonic(),
                            rid=req.rid, step=step)

    def _emit(self, req: Request, step: int, tok: Any) -> None:
        val = self.adapter.detok(req, step, tok)
        now = time.monotonic() - self._t0
        with self._lock:
            req.tokens.append((step, val))
            self.metrics.emit(TokenRecord(
                rid=req.rid, step=step,
                t_submit=req._t_submit[step],    # type: ignore[attr-defined]
                t_emit=now))
        if _tr.TRACING:
            lat = now - req._t_submit[step]  # type: ignore[attr-defined]
            _tr.TRACER.instant("serving", "token", rid=req.rid, step=step,
                               latency_s=lat)

    def _finish(self, req: Request) -> None:
        """Retire the request — but only if every token actually
        emitted.  A failed micro-step force-releases its dependents
        (so the graph drains instead of hanging), which means this task
        can run on an incomplete request: leave it in flight and let
        the failure sweep of ``_handle_failure`` evict + re-admit it."""
        with self._lock:
            if req.state is RequestState.DECODE \
                    and len(req.tokens) == req.gen_len:
                req.to(RequestState.DONE)
                req.finished_at = time.monotonic() - self._t0
                self.active.pop(req.rid, None)

    # -- scheduling ---------------------------------------------------------
    def _admit(self, req: Request) -> None:
        with self._lock:
            req.to(RequestState.PREFILL)
            req.admitted_at = time.monotonic() - self._t0
            req._t_submit = {}          # type: ignore[attr-defined]
            req._toks = {}              # type: ignore[attr-defined]
            self.active[req.rid] = req
            self.admission_log.append(req.rid)

    def _evict(self, req: Request, *, front: bool) -> None:
        """Drop the request's cache and return it to the queue."""
        with self._lock:
            req.to(RequestState.EVICTED)
            req.reset_for_requeue()
            self.active.pop(req.rid, None)
            self.eviction_log.append(req.rid)
        (self.queue.push_front if front else self.queue.push)(req)

    def evict(self, rid: int) -> None:
        """Explicit preemption hook (stepwise mode: call from
        ``on_round``, between fully-drained rounds)."""
        req = self.active.get(rid)
        if req is None:
            raise KeyError(f"request {rid} is not in flight")
        self._evict(req, front=False)

    def _preempt(self) -> None:
        """Evict the worst in-flight request when the queue head is
        strictly more urgent and no slot is free (stepwise only)."""
        while True:
            head = self.queue.peek()
            if head is None or len(self.active) < self.slots:
                return
            with self._lock:
                victim = max(self.active.values(),
                             key=lambda r: (r.priority, r.rid),
                             default=None)
            if victim is None or victim.priority <= head.priority:
                return
            self._evict(victim, front=False)

    def _submit_step(self, rt: TaskRuntime, req: Request) -> None:
        step = req.submitted_steps
        now = time.monotonic() - self._t0
        req._t_submit[step] = now       # type: ignore[attr-defined]
        kind = "prefill" if step == 0 else "decode"
        if self.completion == "event":
            # The decode task WRITES the step's token slot and iwaits the
            # device handle, so the detok task's READ of that slot opens
            # at device completion; successive decode steps depend only
            # on the chain (WAW) — detok never sits on the device chain.
            slot = (req.chain, "tok", step)
            rt.submit(self._device_step, req, step,
                      inout=[req.chain], out=[slot],
                      name=f"{kind}:{req.rid}@{step}")
            rt.submit(self._detok_task, req, step, in_=[slot],
                      inout=[req.detok_chain],
                      name=f"detok:{req.rid}@{step}")
        else:
            rt.submit(self._device_step, req, step,
                      inout=[req.chain], name=f"{kind}:{req.rid}@{step}")
        req.submitted_steps = step + 1
        if req.submitted_steps == req.gen_len:
            # finish orders after the device chain AND (event leg) the
            # detok chain, so the completeness check in _finish sees
            # every emitted token.
            chains = [req.chain] if self.completion == "blocking" \
                else [req.chain, req.detok_chain]
            rt.submit(self._finish, req, inout=chains,
                      name=f"finish:{req.rid}")

    def _handle_failure(self, err: BaseException) -> None:
        """ULFM recovery: evict in-flight requests, shrink, rebuild."""
        if not isinstance(err, (RankFailedError, CommRevokedError)) \
                or self._world is None:
            raise err
        with self._lock:
            inflight = [r for r in self.active.values()
                        if r.state in (RequestState.PREFILL,
                                       RequestState.DECODE)]
        for req in inflight:
            self._evict(req, front=True)
        group = resilience.recover(self._world)
        self._comm = group
        self._coll = Collectives(group) if self._tp_elems > 0 else None
        self.recoveries += 1

    # -- the driver loop ----------------------------------------------------
    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` (arrival times honoured) to completion."""
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        rt = self._ext_runtime or TaskRuntime(
            num_workers=self._num_workers, notify=self._notify)
        rt.start()
        self._t0 = time.monotonic()
        rounds = 0
        try:
            while pending or self.queue or self.active:
                now = time.monotonic() - self._t0
                while pending and pending[0].arrival_s <= now:
                    self.queue.push(pending.pop(0))
                if not self.queue and not self.active:
                    # idle until the next arrival
                    time.sleep(min(max(pending[0].arrival_s - now, 0.0),
                                   0.005))
                    continue
                if self.on_round is not None:
                    self.on_round(self, rounds)
                if self.sync_every:
                    self._preempt()
                while len(self.active) < self.slots and self.queue:
                    req = self.queue.pop()
                    self._admit(req)
                with self._lock:
                    runnable = [r for r in self.active.values()
                                if r.submitted_steps < r.gen_len]
                for req in sorted(runnable, key=lambda r: r.rid):
                    self._submit_step(rt, req)
                rounds += 1
                if self.sync_every and rounds % self.sync_every == 0:
                    try:
                        rt.taskwait()
                    except TaskError as exc:
                        self._handle_failure(exc.error)
                elif not runnable:
                    # all steps submitted: give finish tasks air
                    time.sleep(0.001)
            rt.taskwait()
        finally:
            if self._ext_runtime is None:
                rt.close()
        wall = time.monotonic() - self._t0
        outputs = {}
        evictions = 0
        for req in requests:
            outputs[req.rid] = [v for _, v in sorted(req.tokens)]
            evictions += req.evictions
        return ServeReport.build(self.completion, self.metrics.records,
                                 wall, outputs, evictions,
                                 self.recoveries)
