"""Per-request state for the continuous-batching engine.

Each request is an explicit state machine — the unit the scheduler in
:mod:`repro.serving.engine` admits, steps, evicts, and re-admits.  The
legal transitions:

.. code-block:: text

    QUEUED --admit--> PREFILL --first token--> DECODE --gen_len--> DONE
      ^                  |                        |
      |                  +----evict/failure-------+
      +---- re-admission (EVICTED -> QUEUED, prefill restarts) ----+

Eviction (scheduler preemption or a rank failure surfacing out of
``taskwait``) drops the request's KV cache and returns it to the queue;
re-admission restarts it from prefill under a fresh *incarnation* —
the chain tokens that order its micro-step tasks are incarnation-keyed,
so tasks of a dead incarnation can never interleave with the retry.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional

__all__ = ["RequestState", "Request"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    EVICTED = "evicted"
    FAILED = "failed"


#: transitions the state machine accepts; anything else is a scheduler bug.
_TRANSITIONS = {
    RequestState.QUEUED: {RequestState.PREFILL, RequestState.FAILED},
    RequestState.PREFILL: {RequestState.DECODE, RequestState.EVICTED,
                           RequestState.FAILED},
    RequestState.DECODE: {RequestState.DONE, RequestState.EVICTED,
                          RequestState.FAILED},
    RequestState.EVICTED: {RequestState.QUEUED},
    RequestState.DONE: set(),
    RequestState.FAILED: set(),
}


@dataclasses.dataclass
class Request:
    """One inference request: prompt in, ``gen_len`` greedy tokens out.

    ``prompt`` is adapter-defined (token ids for the LM adapter, a seed
    payload for the synthetic one).  ``priority`` orders admission and
    preemption — LOWER values are more urgent, matching the queue's
    sort.  Mutable fields below the fold are scheduler state.
    """

    rid: int
    prompt: Any
    gen_len: int
    priority: int = 0
    arrival_s: float = 0.0

    # -- scheduler state -----------------------------------------------------
    state: RequestState = RequestState.QUEUED
    cache: Any = None                   # adapter decode state (KV cache)
    tokens: List[Any] = dataclasses.field(default_factory=list)
    submitted_steps: int = 0            # decode micro-steps handed to the rt
    incarnation: int = 0                # bumped on every re-admission
    evictions: int = 0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None

    def to(self, new: RequestState) -> None:
        """Transition with legality checking (scheduler-bug tripwire)."""
        if new not in _TRANSITIONS[self.state]:
            raise RuntimeError(f"request {self.rid}: illegal transition "
                               f"{self.state.value} -> {new.value}")
        self.state = new

    def reset_for_requeue(self) -> None:
        """EVICTED -> QUEUED: drop the cache, restart from prefill."""
        self.to(RequestState.QUEUED)
        self.cache = None
        self.tokens = []
        self.submitted_steps = 0
        self.incarnation += 1
        self.evictions += 1

    @property
    def chain(self) -> str:
        """Dependency token ordering this incarnation's device steps."""
        return f"req-{self.rid}.{self.incarnation}"

    @property
    def detok_chain(self) -> str:
        """Dependency token ordering this incarnation's host detoks."""
        return f"detok-{self.rid}.{self.incarnation}"
