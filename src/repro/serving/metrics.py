"""Serving metrics: the run report (+ deprecated re-export shims).

``percentile``, :class:`~repro.obs.metrics.TokenRecord` and
:class:`~repro.obs.metrics.MetricSink` moved to :mod:`repro.obs.metrics`
(the unified observability layer).  Importing them from here still works
but warns — matching the ``renamed_kwarg`` deprecation pattern of
:mod:`repro.core.options` — via a module-level ``__getattr__`` shim.
:class:`ServeReport` stays: it is serving-specific.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List

from ..obs.metrics import MetricSink as _MetricSink
from ..obs.metrics import TokenRecord as _TokenRecord
from ..obs.metrics import percentile as _percentile

__all__ = ["TokenRecord", "MetricSink", "ServeReport", "percentile"]

_MOVED = {"percentile": _percentile, "TokenRecord": _TokenRecord,
          "MetricSink": _MetricSink}


def __getattr__(name: str) -> Any:
    moved = _MOVED.get(name)
    if moved is not None:
        warnings.warn(
            f"repro.serving.metrics.{name} moved to repro.obs.metrics "
            f"(the unified observability layer); import it from repro.obs "
            f"instead", DeprecationWarning, stacklevel=2)
        return moved
    raise AttributeError(
        f"module 'repro.serving.metrics' has no attribute {name!r}")


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`repro.serving.engine.ServingEngine.run`."""

    completion: str                     # "event" | "blocking"
    requests: int
    tokens: int
    wall_s: float
    tokens_per_s: float
    p50_ms: float
    p99_ms: float
    evictions: int
    recoveries: int
    outputs: Dict[int, List[Any]]       # rid -> emitted tokens, step order

    @staticmethod
    def build(completion: str, records: List[_TokenRecord], wall_s: float,
              outputs: Dict[int, List[Any]], evictions: int,
              recoveries: int) -> "ServeReport":
        lat = [r.latency_s for r in records]
        return ServeReport(
            completion=completion,
            requests=len(outputs),
            tokens=len(records),
            wall_s=wall_s,
            tokens_per_s=len(records) / wall_s if wall_s > 0 else 0.0,
            p50_ms=_percentile(lat, 50) * 1e3 if lat else 0.0,
            p99_ms=_percentile(lat, 99) * 1e3 if lat else 0.0,
            evictions=evictions,
            recoveries=recoveries,
            outputs=outputs)

    def summary(self) -> str:
        return (f"[{self.completion}] {self.tokens} tok / {self.requests} "
                f"req in {self.wall_s:.3f}s = {self.tokens_per_s:.0f} "
                f"tok/s, p50 {self.p50_ms:.2f} ms, p99 {self.p99_ms:.2f} "
                f"ms, evictions={self.evictions}, "
                f"recoveries={self.recoveries}")
