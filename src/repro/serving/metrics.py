"""Serving metrics: per-token latency records and the run report."""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Tuple

__all__ = ["TokenRecord", "MetricSink", "ServeReport", "percentile"]


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        raise ValueError("percentile of an empty list")
    xs = sorted(values)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


@dataclasses.dataclass(frozen=True)
class TokenRecord:
    """One emitted token: which request/step, and its latency window.

    ``t_submit`` is when the scheduler handed the decode micro-step to
    the runtime, ``t_emit`` when the host detokeniser finished with the
    token — so the latency covers device compute, completion
    notification, and host post-processing, which is exactly the window
    the event-bound vs blocking-sentinel legs differ in.
    """

    rid: int
    step: int
    t_submit: float
    t_emit: float

    @property
    def latency_s(self) -> float:
        return self.t_emit - self.t_submit


class MetricSink:
    """Thread-safe collector the engine's tasks append records to."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: List[TokenRecord] = []

    def emit(self, rec: TokenRecord) -> None:
        with self._lock:
            self._records.append(rec)

    @property
    def records(self) -> List[TokenRecord]:
        with self._lock:
            return list(self._records)


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`repro.serving.engine.ServingEngine.run`."""

    completion: str                     # "event" | "blocking"
    requests: int
    tokens: int
    wall_s: float
    tokens_per_s: float
    p50_ms: float
    p99_ms: float
    evictions: int
    recoveries: int
    outputs: Dict[int, List[Any]]       # rid -> emitted tokens, step order

    @staticmethod
    def build(completion: str, records: List[TokenRecord], wall_s: float,
              outputs: Dict[int, List[Any]], evictions: int,
              recoveries: int) -> "ServeReport":
        lat = [r.latency_s for r in records]
        return ServeReport(
            completion=completion,
            requests=len(outputs),
            tokens=len(records),
            wall_s=wall_s,
            tokens_per_s=len(records) / wall_s if wall_s > 0 else 0.0,
            p50_ms=percentile(lat, 50) * 1e3 if lat else 0.0,
            p99_ms=percentile(lat, 99) * 1e3 if lat else 0.0,
            evictions=evictions,
            recoveries=recoveries,
            outputs=outputs)

    def summary(self) -> str:
        return (f"[{self.completion}] {self.tokens} tok / {self.requests} "
                f"req in {self.wall_s:.3f}s = {self.tokens_per_s:.0f} "
                f"tok/s, p50 {self.p50_ms:.2f} ms, p99 {self.p99_ms:.2f} "
                f"ms, evictions={self.evictions}, "
                f"recoveries={self.recoveries}")
