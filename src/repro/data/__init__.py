"""Data pipeline: deterministic synthetic token stream + async prefetch.

* :class:`SyntheticLMData` — batches are a pure function of (seed, step),
  so a restarted job replays the exact stream from the checkpointed step
  (restart determinism is part of the fault-tolerance story).

* :class:`Prefetcher` — double-buffered host→device prefetch built on the
  paper's machinery: batch k+1 is produced by a task on the host
  :class:`~repro.core.TaskRuntime` while step k runs on device; the
  training loop *waits task-aware* (``tac.wait``) on the prefetch handle
  instead of blocking a worker.  This is the Fig. 1 pattern applied to
  input pipelines.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from ..core import TaskRuntime, tac
from ..models.config import ModelConfig


class SyntheticLMData:
    """Deterministic synthetic LM batches (token ids + next-token labels)."""

    def __init__(self, cfg: ModelConfig, *, batch: int, seq: int,
                 seed: int = 0) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {}
        if cfg.frontend == "audio":
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model), dtype=np.float32
            ).astype(np.dtype(cfg.dtype) if cfg.dtype != "bfloat16"
                     else np.float32)
            out["labels"] = rng.integers(
                0, cfg.vocab, (self.batch, self.seq), dtype=np.int32)
            return out
        # token stream with a learnable structure (repeat-shift pattern) so
        # small models can actually reduce loss on it
        toks = rng.integers(0, cfg.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        toks[:, 2::2] = toks[:, 1:-1:2]  # every even position repeats
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        if cfg.frontend == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (self.batch, cfg.n_patches, cfg.d_model),
                dtype=np.float32)
        return out


class Prefetcher:
    """Double-buffered prefetch driven by the host task runtime."""

    def __init__(self, data: SyntheticLMData, *, start_step: int = 0,
                 device_put_fn=None, depth: int = 2) -> None:
        self.data = data
        self.device_put_fn = device_put_fn or (lambda x: x)
        self.runtime = TaskRuntime(num_workers=max(1, depth))
        self.runtime.start()
        self._pending: Dict[int, tac.EventHandle] = {}
        self._next = start_step
        self.depth = depth
        for s in range(start_step, start_step + depth):
            self._issue(s)

    def _issue(self, step: int) -> None:
        handle = tac.EventHandle()

        def produce():
            batch = self.data.batch_at(step)
            handle.complete(self.device_put_fn(batch))

        self.runtime.submit(produce, name=f"prefetch@{step}")
        self._pending[step] = handle

    def get(self, step: int) -> Any:
        """Batch for ``step`` (task-aware wait), prefetching step+depth."""
        if step not in self._pending:
            self._issue(step)
        handle = self._pending.pop(step)
        self._issue(step + self.depth)
        return tac.wait(handle)

    def close(self) -> None:
        self.runtime.close()
