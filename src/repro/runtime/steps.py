"""Train / serve step builders with explicit shardings.

Two distribution modes:

* ``auto`` (production default): one ``jax.jit`` with NamedShardings on
  params/optimizer/batch; GSPMD inserts TP/FSDP collectives; XLA's
  latency-hiding scheduler overlaps them.  The §Roofline baselines lower
  through this path.

* ``manual`` DP: ``jax.shard_map`` manual over the DP axes (model axis
  stays auto) with the gradient-synchronisation schedule chosen explicitly
  (fused / bucketed / sentinel — core/overlap.py).  This is the Level-B
  reproduction of the paper's communication-task scheduling and the surface
  the overlap benchmarks compare.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import compat, optim
from ..models import model
from ..models.config import ModelConfig
from ..launch.mesh import dp_axes
from .sharding import (ShardingPolicy, Constrainer, param_shardings,
                       batch_shardings, cache_shardings)

AUX_COEF = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: optim.OptState


def init_train_state(cfg: ModelConfig, opt_cfg: optim.OptimConfig,
                     key) -> TrainState:
    params = model.init(cfg, key)
    return TrainState(params=params, opt=optim.init(params))


def abstract_train_state(cfg: ModelConfig, opt_cfg: optim.OptimConfig):
    return jax.eval_shape(
        lambda k: init_train_state(cfg, opt_cfg, k), jax.random.PRNGKey(0))


def state_shardings(mesh, abstract_state: TrainState,
                    policy: ShardingPolicy) -> TrainState:
    return TrainState(
        params=param_shardings(mesh, abstract_state.params, policy),
        opt=optim.OptState(
            step=NamedSharding(mesh, P()),
            m=param_shardings(mesh, abstract_state.opt.m, policy),
            v=param_shardings(mesh, abstract_state.opt.v, policy),
            master=param_shardings(mesh, abstract_state.opt.master, policy),
        ))


def _loss_fn(params, batch, cfg: ModelConfig, constrain, remat):
    logits, _, aux = model.apply(params, cfg, batch, mode="train",
                                 constrain=constrain, remat=remat)
    loss = model.lm_loss(logits, batch["labels"])
    return loss + AUX_COEF * aux, loss


# ---------------------------------------------------------------------------
# auto mode
# ---------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, mesh, policy: ShardingPolicy,
                     opt_cfg: optim.OptimConfig, *, abstract_batch=None,
                     donate: bool = True):
    """jit'd (state, batch) -> (state, metrics) with NamedShardings."""
    constrain = Constrainer(mesh, policy)
    M = max(1, policy.microbatches)

    def grad_fn(params, batch):
        return jax.value_and_grad(_loss_fn, has_aux=True)(
            params, batch, cfg, constrain, policy.remat)

    def train_step(state: TrainState, batch):
        if M == 1:
            (total, loss), grads = grad_fn(state.params, batch)
        else:
            # Gradient accumulation: scan over microbatches; the live
            # activation set shrinks by M while tokens/step (and the
            # gradient reduction) are unchanged.
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                acc, tot_a, loss_a = carry
                (total, loss), g = grad_fn(state.params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, tot_a + total, loss_a + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, total, loss), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
            total, loss = total / M, loss / M
        new_params, new_opt, metrics = optim.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, total_loss=total)
        return TrainState(new_params, new_opt), metrics

    abstract = abstract_train_state(cfg, opt_cfg)
    sshard = state_shardings(mesh, abstract, policy)
    metrics_shard = {k: NamedSharding(mesh, P())
                     for k in ("lr", "grad_norm", "loss", "total_loss")}
    bshard = batch_shardings(mesh, abstract_batch, policy) \
        if abstract_batch is not None else None

    jitted = jax.jit(
        train_step,
        in_shardings=(sshard, bshard),
        out_shardings=(sshard, metrics_shard),
        donate_argnums=(0,) if donate else ())
    return jitted, sshard


# ---------------------------------------------------------------------------
# manual-DP mode (explicit grad-sync schedule; the paper's Level-B surface)
# ---------------------------------------------------------------------------
def build_train_step_manual(cfg: ModelConfig, mesh, policy: ShardingPolicy,
                            opt_cfg: optim.OptimConfig, *,
                            grad_sync: Optional[str] = None,
                            bucket_bytes: int = 4 << 20,
                            compress: Optional[str] = None):
    """shard_map-manual over DP axes; grad sync schedule is explicit.

    Requires ``policy.fsdp == False`` (params replicated over DP; TP still
    applies through the auto model axis).
    """
    from ..core import overlap

    assert not policy.fsdp, "manual grad-sync mode implies fsdp=False"
    mode = grad_sync or policy.grad_sync
    assert mode in ("fused", "bucketed", "sentinel"), mode
    D = dp_axes(mesh)
    constrain = None  # inside manual DP, batch dims are local; TP via auto

    def step_local(state: TrainState, batch):
        (total, loss), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(state.params, batch, cfg, None,
                                    policy.remat)
        grads = overlap.sync_grads(grads, axes=D, mode=mode,
                                   bucket_bytes=bucket_bytes,
                                   compress=compress)
        new_params, new_opt, metrics = optim.update(
            opt_cfg, grads, state.opt, state.params)
        loss = jax.lax.pmean(loss, D)
        metrics = dict(metrics, loss=loss, total_loss=total)
        return TrainState(new_params, new_opt), metrics

    replicated = P()

    def specs_for_state(abstract_state):
        return jax.tree_util.tree_map(lambda _: replicated, abstract_state)

    def specs_for_batch(abstract_batch):
        return jax.tree_util.tree_map(
            lambda leaf: P(D, *([None] * (leaf.ndim - 1))), abstract_batch)

    def make(abstract_state, abstract_batch):
        in_specs = (specs_for_state(abstract_state),
                    specs_for_batch(abstract_batch))
        out_specs = (specs_for_state(abstract_state),
                     {k: replicated for k in
                      ("lr", "grad_norm", "loss", "total_loss")})
        f = compat.shard_map(step_local, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(D),
                             check_vma=False)
        # NOTE: no donation here — donating replicated shard_map inputs
        # deadlocks the CPU backend's collective rendezvous (the donated
        # buffer lives on one device; the implicit broadcast and the psum
        # schedule cross).  On TPU, re-enable donation after placing the
        # state with device_put(state, shardings).
        return jax.jit(f)

    return make


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: model.init(cfg, k),
                          jax.random.PRNGKey(0))


def build_prefill_step(cfg: ModelConfig, mesh, policy: ShardingPolicy, *,
                       abstract_batch=None):
    constrain = Constrainer(mesh, policy)

    def prefill(params, batch):
        logits, cache, _ = model.apply(params, cfg, batch, mode="prefill",
                                       constrain=constrain)
        return logits[:, -1:], cache

    ps = param_shardings(mesh, abstract_params(cfg), policy)
    bs = batch_shardings(mesh, abstract_batch, policy) \
        if abstract_batch is not None else None
    return jax.jit(prefill, in_shardings=(ps, bs))


def build_decode_step(cfg: ModelConfig, mesh, policy: ShardingPolicy, *,
                      batch: int, cache_len: int, abstract_batch=None,
                      donate: bool = True):
    constrain = Constrainer(mesh, policy, decode=True)

    def decode(params, cache, batch_, cache_index):
        logits, new_cache, _ = model.apply(
            params, cfg, batch_, mode="decode", cache=cache,
            cache_index=cache_index, constrain=constrain)
        return logits, new_cache

    ps = param_shardings(mesh, abstract_params(cfg), policy)
    a_cache = jax.eval_shape(
        functools.partial(model.init_cache, cfg, batch, cache_len))
    cs = cache_shardings(mesh, a_cache, policy)
    bs = batch_shardings(mesh, abstract_batch, policy) \
        if abstract_batch is not None else None
    jitted = jax.jit(decode, in_shardings=(ps, cs, bs, None),
                     out_shardings=(None, cs),
                     donate_argnums=(1,) if donate else ())
    return jitted, a_cache
