"""Sharding policy: parameter rules, activation constraints, batch specs.

The policy implements DP (+hierarchical pod-DP), FSDP (params/optimizer
sharded over the data axes), TP (heads / ffn / experts over the model axis),
SP (residual-stream sequence sharding over the model axis between blocks)
and EP (MoE experts over the model axis).

Every preferred PartitionSpec is validated against the actual dimension
sizes — axes that do not divide a dimension are dropped (never silently
padded), so e.g. 4 kv heads on a 16-way model axis fall back cleanly.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import dp_axes, tp_axis, axis_size


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Knobs for the distribution strategy (the §Perf hillclimb surface)."""
    fsdp: bool = True              # shard params over the data axes
    tp: bool = True                # tensor parallelism over 'model'
    sp: bool = True                # sequence-shard residuals over 'model'
    ep: bool = True                # experts over 'model'
    remat: Optional[str] = "dots"  # None | "full" | "dots"
    grad_sync: str = "auto"        # auto | fused | bucketed | sentinel
    shard_embed_vocab: bool = True
    microbatches: int = 1          # gradient accumulation (activation mem ÷M)
    # "data": classic FSDP over the data axes (model axis = TP).
    # "all":  pure-FSDP — params AND batch shard over every mesh axis; no
    #         tensor parallelism (beyond-paper sharding-scheme change for
    #         models whose layers fit one chip).
    fsdp_axes: str = "data"
    fsdp_experts: bool = True      # False: expert weights skip FSDP (keeps
    #         contractions unsharded on d/ff -> no activation all-reduce
    #         over the data axes at the cost of replicated expert storage)
    gather_expert_weights: bool = False  # reshard expert weights at use
    #         (storage stays FSDP; the matmul sees gathered weights)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
# (path regex, candidate specs) — first matching rule wins; within a rule the
# first candidate whose partitioned dims all divide is used, else the first
# candidate with non-dividing axes dropped.  `F` is the FSDP axes tuple (or
# None), `T` the tensor axis (or None).
def _param_rules(F, T, policy: ShardingPolicy):
    E = T if policy.ep else None   # expert axis
    V = T if policy.shard_embed_vocab else None
    FE = F if policy.fsdp_experts else None   # FSDP axes for expert weights
    return [
        (r"embed$",                [P(V, F)]),
        (r"lm_head$",              [P(F, T)]),
        # moe (leading expert dim) — must precede the generic mlp rules.
        # EP proper when E divides the model axis (olmoe: 64 experts);
        # otherwise tensor-parallel experts (mixtral: 8 experts on a 16-way
        # axis -> shard the ffn dim instead).
        (r"router$",               [P(F, None)]),
        (r"_moe/w[gu]$",           [P(E, FE, None), P(None, FE, T)]),
        (r"_moe/wd$",              [P(E, None, FE), P(None, T, FE)]),
        # attention
        (r"w[qkv]$",               [P(F, T)]),
        (r"wo$",                   [P(T, F)]),
        # mlp
        (r"w[gu]$",                [P(F, T)]),
        (r"wd$",                   [P(T, F)]),
        # mamba2
        (r"w[zx]$",                [P(F, T)]),
        (r"w[BC]$",                [P(F, None)]),
        (r"wdt$",                  [P(F, None)]),
        (r"out$",                  [P(T, F)]),
        (r"conv_[wb]$",            [P()]),
        # xlstm
        (r"wup$",                  [P(F, T)]),
        (r"r[ifzo]$",              [P(T, None, None)]),
        (r"w[ifzo]$",              [P(F, T)]),
        (r"wd2$",                  [P(T, F)]),
        (r"b[ifzo]$",              [P()]),
        # norms / scalars / everything small
        (r".*",                    [P()]),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divides(spec: P, shape: Tuple[int, ...], mesh) -> bool:
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        if i >= len(shape) or shape[i] <= 0 \
                or shape[i] % axis_size(mesh, entry) != 0:
            return False
    return True


def _fit_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop partition axes that don't divide their dimension."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        size = axis_size(mesh, entry)
        if i < len(shape) and shape[i] % size == 0 and shape[i] > 0:
            out.append(entry)
        else:
            out.append(None)
    # trailing dims unspecified -> replicated
    return P(*out)


def _fit_candidates(specs, shape: Tuple[int, ...], mesh) -> P:
    """First candidate that divides cleanly; else first candidate fitted."""
    for spec in specs:
        full = P(*(tuple(spec) + (None,) * (len(shape) - len(spec))))
        if _divides(full, shape, mesh):
            return full
    spec = specs[0]
    full = P(*(tuple(spec) + (None,) * (len(shape) - len(spec))))
    return _fit_spec(full, shape, mesh)


def batch_axes(mesh, policy: Optional[ShardingPolicy] = None):
    """Axes carrying the batch dim (all axes under pure-FSDP)."""
    D = dp_axes(mesh)
    if policy is not None and policy.fsdp_axes == "all" and tp_axis(mesh):
        D = D + (tp_axis(mesh),)
    return D


def _policy_axes(mesh, policy: ShardingPolicy):
    F = dp_axes(mesh)
    T = tp_axis(mesh)
    if policy.fsdp_axes == "all" and T is not None:
        F = F + (T,)
        T = None            # pure-FSDP: the model axis carries data
    if not policy.fsdp:
        F = None
    if not policy.tp:
        T = None
    return F, T


def param_shardings(mesh, abstract_params, policy: ShardingPolicy):
    """NamedShardings for a (possibly stacked) parameter tree."""
    F, T = _policy_axes(mesh, policy)
    rules = _param_rules(F, T, policy)

    def assign(path, leaf):
        s = _path_str(path)
        stacked = "layers/" in s or s.startswith("layers")
        for pat, candidates in rules:
            if re.search(pat, s):
                break
        shape = leaf.shape
        if stacked:
            candidates = [P(None, *c) for c in candidates]  # unit-repeat dim
        return NamedSharding(mesh, _fit_candidates(candidates, shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


# ---------------------------------------------------------------------------
# batch / activation / cache shardings
# ---------------------------------------------------------------------------
def batch_shardings(mesh, abstract_batch,
                    policy: Optional[ShardingPolicy] = None):
    """Tokens & friends: batch dim over the DP axes (all axes for
    pure-FSDP policies)."""
    D = batch_axes(mesh, policy)

    def assign(path, leaf):
        spec = P(D, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, abstract_batch)


def cache_shardings(mesh, abstract_cache, policy: ShardingPolicy):
    """Decode caches: batch over DP where divisible, heads over TP.

    Leaf layouts (leading dim = unit repeats):
      attn k/v      (R, B, T, Hkv, dh) -> P(None, D, None, T, None)
      mamba2 state  (R, B, H, P, N)    -> P(None, D, T, None, None)
      mamba2 conv   (R, B, K, C)       -> P(None, D, None, T)
      mlstm C       (R, B, H, d, d)    -> P(None, D, T, None, None)
      mlstm n       (R, B, H, d)       -> P(None, D, T, None)
      mlstm m       (R, B, H)          -> P(None, D, T)
      slstm c/n/h/m (R, B, d)          -> P(None, D, T)
    """
    D = dp_axes(mesh)
    T = tp_axis(mesh) if policy.tp else None

    def assign(path, leaf):
        nd = leaf.ndim
        if nd >= 3:
            spec = [None, D] + [None] * (nd - 2)
            if nd >= 4:
                s = _path_str(path)
                if "_attn" in s or "_swa" in s or "_shared" in s:
                    # KV cache (R, B, T_time, Hkv, dh): heads over the model
                    # axis when they divide; otherwise shard the TIME dim —
                    # decode attention over a time-sharded cache is the
                    # flash-decode pattern (partial max/sum + tiny
                    # all-reduces) and GSPMD lowers it directly.  A
                    # replicated 32k cache is 10s of GiB per device.
                    if leaf.shape[nd - 2] % axis_size(mesh, T or ()) == 0 \
                            and T is not None:
                        spec[nd - 2] = T
                    else:
                        spec[2] = T
                else:
                    spec[2] = T
            else:
                spec[2] = T
        else:
            spec = [None] * nd
        return NamedSharding(mesh, _fit_spec(P(*spec), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)


# ---------------------------------------------------------------------------
# activation constraints (installed into the model's `constrain` hooks)
# ---------------------------------------------------------------------------
class Constrainer:
    """with_sharding_constraint hooks threaded through the model.

    ``residual`` implements sequence parallelism: between blocks the
    residual stream (B, S, d) is sharded (DP, TP, None) so stored
    activations never materialise replicated copies over the model axis.
    """

    def __init__(self, mesh, policy: ShardingPolicy, *,
                 decode: bool = False):
        self.mesh = mesh
        self.policy = policy
        self.D = batch_axes(mesh, policy)
        _, self.T = _policy_axes(mesh, policy)
        self.decode = decode

    def _c(self, x, *spec):
        fitted = _fit_spec(P(*spec), x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, fitted))

    def residual(self, x):
        if x.ndim != 3:
            return x
        if self.policy.sp and not self.decode:
            return self._c(x, self.D, self.T, None)
        return self._c(x, self.D, None, None)

    def heads(self, x):      # (B, S, H, dh)
        return self._c(x, self.D, None, self.T, None)

    def attn_acc(self, x):   # (B, H, S, dh) — flash scan carry
        return self._c(x, self.D, self.T, None, None)

    def attn_stats(self, x):  # (B, H, S) — flash m/l carries
        return self._c(x, self.D, self.T, None)

    def ffn(self, x):        # (B, S, ff)
        return self._c(x, self.D, None, self.T)

    def experts(self, x):    # (G, E, cap, d) — groups over DP, experts over
        # the model axis when they divide it (EP); otherwise experts stay
        # local and the ffn dim carries the model axis (TP-experts).
        if self.policy.ep and x.ndim == 4:
            fitted = _fit_spec(P(self.D, self.T, None, None), x.shape,
                               self.mesh)
            if fitted[1] is not None:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh, fitted))
        return self._c(x, self.D, *([None] * (x.ndim - 1)))

    def expert_weights(self, w):  # (E, d, ff) / (E, ff, d)
        if not self.policy.gather_expert_weights:
            return w
        spec = P(self.T, None, None) if w.shape[0] % \
            axis_size(self.mesh, self.T or ()) == 0 and self.T else \
            P(None, None, self.T) if w.shape[2] % \
            axis_size(self.mesh, self.T or ()) == 0 and self.T else P()
        return self._c(w, *spec)

    def ssm_heads(self, x):  # (B, S, H, P)
        return self._c(x, self.D, None, self.T, None)

    def logits(self, x):     # (B, S, V)
        if self.decode:
            # decode: S == 1 — shard the vocab if it divides, else batch only
            return self._c(x, self.D, None, self.T)
        # Sequence-sharded logits (SP-consistent): vocab sizes are often not
        # divisible by the model axis (e.g. 49155 on 16) but power-of-two
        # sequence lengths always are — this is what keeps the fp32 loss
        # intermediates from replicating.
        return self._c(x, self.D, self.T, None)
