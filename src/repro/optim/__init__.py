"""Optimizer substrate: AdamW with fp32 master weights, clipping, schedules.

Implemented from scratch in JAX (no optax dependency).  The optimizer state
is sharded like the parameters (FSDP — the sharding rules apply to ``m``,
``v`` and ``master`` because they mirror the param tree structure).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array          # ()
    m: Any                   # fp32, like params
    v: Any                   # fp32, like params
    master: Any              # fp32 master copy of params


def lr_at(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params) -> OptState:
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    master = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=f32(params),
                    v=f32(params), master=master)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: OptimConfig, grads, state: OptState, params
           ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else jnp.float32(1.0)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * master
        master = master - lr * delta
        return m, v, master, master.astype(p.dtype)

    flat, treedef = jax.tree_util.tree_flatten(params)
    gs = treedef.flatten_up_to(grads)
    ms = treedef.flatten_up_to(state.m)
    vs = treedef.flatten_up_to(state.v)
    mas = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, ma, p) for g, m, v, ma, p in
           zip(gs, ms, vs, mas, flat)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = treedef.unflatten([o[3] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step, new_m, new_v, new_master), metrics
