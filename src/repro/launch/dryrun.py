import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without real hardware:
``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed on the
single-pod (16×16) and multi-pod (2×16×16) production meshes for every
assigned architecture × input shape.  Records, per cell:

* ``memory_analysis``  — bytes per device (proves it fits HBM);
* ``cost_analysis``    — per-device HLO FLOPs / bytes accessed;
* the collective schedule — op kind, count and bytes parsed from the
  post-SPMD-partitioning HLO (``compiled.as_text()``), the input to the
  §Roofline collective term.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .. import configs, optim
from ..models import model, inputs
from ..models.config import applicable_shapes, shape_by_name
from ..runtime.sharding import ShardingPolicy
from ..runtime import steps
from . import mesh as meshlib

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8\w*|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt = m.group(1)
    dims = m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    base = next((v for k, v in _DTYPE_BYTES.items() if dt.startswith(k)), 4)
    return n * base


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, Any]]:
    """Sum operand bytes of every collective op in (partitioned) HLO text."""
    out: Dict[str, Dict[str, Any]] = {
        k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|\S+)\s+"
                     r"([a-z0-9\-]+)", s)
        if not m:
            continue
        op = m.group(1)
        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + "-")), None)
        if kind is None:
            continue
        # operand section: everything inside the first (...) after op name
        try:
            args = s.split(op, 1)[1]
            args = args[args.index("("):]
        except (IndexError, ValueError):
            continue
        depth = 0
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = args[:end + 1]
        nbytes = sum(_shape_bytes(mm)
                     for mm in _SHAPE_RE.finditer(operand_text))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values()
                             if isinstance(v, dict))
    return out


# Per-arch gradient-accumulation defaults for the train_4k cells: chosen so
# the activation live-set fits 16 GiB v5e HBM (tokens/step unchanged).
TRAIN_MICROBATCHES = {
    "mixtral-8x22b": 16,
    "deepseek-coder-33b": 8,
    "phi3-medium-14b": 4,
    "starcoder2-15b": 4,
    "zamba2-2.7b": 4,
    "xlstm-350m": 4,
    "olmoe-1b-7b": 2,
    "hubert-xlarge": 2,
}


def _policy_from_args(args) -> ShardingPolicy:
    return ShardingPolicy(
        fsdp=not args.no_fsdp, tp=not args.no_tp, sp=not args.no_sp,
        ep=not args.no_ep, remat=args.remat,
        shard_embed_vocab=not args.no_vocab_shard,
        microbatches=args.microbatches,
        fsdp_axes="all" if args.fsdp_all else "data",
        fsdp_experts=not args.no_fsdp_experts,
        gather_expert_weights=args.gather_expert_weights)


def default_policy_for(arch: str, shape_name: str,
                       base: ShardingPolicy) -> ShardingPolicy:
    import dataclasses
    if shape_by_name(shape_name).kind == "train":
        canonical = configs.get(arch).name   # dashed form
        mb = TRAIN_MICROBATCHES.get(canonical, 1)
        if base.microbatches == 1 and mb > 1:
            return dataclasses.replace(base, microbatches=mb)
    return base


def lower_cell(arch: str, shape_name: str, mesh, policy: ShardingPolicy,
               opt_cfg: Optional[optim.OptimConfig] = None):
    """Build + lower one (arch, shape, mesh) cell.  Returns (lowered, meta)."""
    cfg = configs.get(arch)
    shape = shape_by_name(shape_name)
    opt_cfg = opt_cfg or optim.OptimConfig()
    abstract_batch = inputs.batch_spec(cfg, shape)

    with mesh:
        if shape.kind == "train":
            jitted, _ = steps.build_train_step(
                cfg, mesh, policy, opt_cfg, abstract_batch=abstract_batch)
            a_state = steps.abstract_train_state(cfg, opt_cfg)
            lowered = jitted.lower(a_state, abstract_batch)
        elif shape.kind == "prefill":
            jitted = steps.build_prefill_step(
                cfg, mesh, policy, abstract_batch=abstract_batch)
            lowered = jitted.lower(steps.abstract_params(cfg),
                                   abstract_batch)
        else:  # decode
            jitted, a_cache = steps.build_decode_step(
                cfg, mesh, policy, batch=shape.global_batch,
                cache_len=shape.seq_len, abstract_batch=abstract_batch,
                donate=False)
            lowered = jitted.lower(
                steps.abstract_params(cfg), a_cache, abstract_batch,
                jax.ShapeDtypeStruct((), jnp.int32))

    cfg_params = jax.eval_shape(lambda k: model.init(cfg, k),
                                jax.random.PRNGKey(0))
    meta = {
        "arch": arch, "shape": shape_name,
        "params": model.param_count(cfg_params),
        "active_params": model.active_param_count(cfg_params, cfg),
        "kind": shape.kind,
        "tokens": shape.global_batch * (1 if shape.kind == "decode"
                                        else shape.seq_len),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             policy: ShardingPolicy,
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    policy = default_policy_for(arch, shape_name, policy)
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, policy)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    if save_hlo:
        import gzip
        os.makedirs(save_hlo, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        fn = os.path.join(save_hlo, f"{arch}_{shape_name}_{tag}.hlo.gz")
        with gzip.open(fn, "wt") as f:
            f.write(hlo_text)
    # Loop-aware per-device cost model (XLA's cost_analysis counts while
    # bodies once — see analysis/hlo_cost.py; validated in tests).
    from ..analysis.hlo_cost import module_cost
    mc = module_cost(hlo_text, n_devices=int(mesh.devices.size))
    t3 = time.time()

    rec = dict(meta)
    rec.update({
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "analyze_s": round(t3 - t2, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes),
        },
        "cost": {
            # per-device, loop-aware
            "flops": mc.flops,
            "bytes_accessed": mc.bytes,
            # bytes in named_scope-tagged kernel-resident regions (VMEM on
            # the TPU Pallas kernels; HBM only on this jnp path)
            "vmem_resident_bytes": mc.vmem_bytes,
            # raw XLA numbers for reference (loop bodies counted once)
            "xla_flops": xla_cost.get("flops", 0.0),
            "xla_bytes": xla_cost.get("bytes accessed", 0.0),
        },
        "collectives": {
            "bytes": dict(mc.coll_bytes),
            "wire_bytes": dict(mc.coll_wire_bytes),
            "counts": dict(mc.coll_counts),
            "total_bytes": mc.total_coll_bytes,
            "total_wire_bytes": mc.total_wire_bytes,
        },
        "ok": True,
    })
    print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
          f"compile {rec['compile_s']}s, "
          f"temp {mem.temp_size_in_bytes / 2**30:.2f} GiB/dev, "
          f"flops/dev {mc.flops:.3e}, "
          f"coll {mc.total_wire_bytes / 2**20:.1f} MiB wire/dev "
          f"({int(sum(mc.coll_counts.values()))} ops)")
    # Required artifacts: prove it fits + expose FLOPs/bytes for §Roofline.
    print("  memory_analysis:", mem)
    return rec


def iter_cells():
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", default=None)
    p.add_argument("--remat", default="dots", choices=["none", "full",
                                                       "dots"])
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--no-tp", action="store_true")
    p.add_argument("--no-sp", action="store_true")
    p.add_argument("--no-ep", action="store_true")
    p.add_argument("--no-vocab-shard", action="store_true")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--fsdp-all", action="store_true",
                   help="pure-FSDP: params+batch over every mesh axis")
    p.add_argument("--no-fsdp-experts", action="store_true",
                   help="expert weights skip FSDP (replicated over data)")
    p.add_argument("--gather-expert-weights", action="store_true")
    p.add_argument("--save-hlo", default=None,
                   help="directory for gzipped post-SPMD HLO per cell")
    args = p.parse_args(argv)
    if args.remat == "none":
        args.remat = None
    policy = _policy_from_args(args)

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        policy=policy,
                                        save_hlo=args.save_hlo))
            except Exception as e:  # noqa: BLE001 - report and continue
                failures += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": False, "error": repr(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} records to {args.out}")
    print(f"[dryrun] {len(results) - failures}/{len(results)} cells OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
