"""Mesh construction for the production pods and local test meshes.

All constructors are FUNCTIONS (never module-level constants) so importing
this module never touches jax device state — required because the dry-run
must set ``XLA_FLAGS`` before the first jax initialisation.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from ..compat import mesh_axis_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh.

    Single pod: (16, 16) over ("data", "model") — 256 chips.
    Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.
    """
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """A custom mesh (tests, PP demos, elastic restore targets)."""
    import jax
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **mesh_axis_kwargs(len(shape)))


def local_mesh(data: Optional[int] = None, model: int = 1):
    """A ("data", "model") mesh over the locally visible devices."""
    import jax
    n = jax.device_count()
    data = data if data is not None else n // model
    assert data * model <= n, (data, model, n)
    return make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes carrying the batch (hierarchical DP: pod composes with data)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def tp_axis(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))
