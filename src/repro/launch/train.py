"""End-to-end training driver.

Wires together every substrate: config registry, synthetic data with
async prefetch (host task runtime), sharded train step (auto mode or
manual grad-sync schedules), asynchronous checkpointing bound to external
events, preemption handling, and step-granular restart.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --scale smoke --steps 200 --batch 16 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
      --scale smoke --steps 50 --grad-sync bucketed --mesh 4x2
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import numpy as np

from .. import configs, optim, checkpoint as ckpt
from ..data import SyntheticLMData, Prefetcher
from ..models import inputs as model_inputs
from ..runtime import steps
from ..runtime.sharding import ShardingPolicy, batch_shardings
from . import mesh as meshlib


def parse_mesh(spec: Optional[str]):
    if not spec:
        return meshlib.local_mesh()
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
        ("pod", "data", "model")
    return meshlib.make_mesh(dims, axes)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-2b")
    p.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--mesh", default=None, help="e.g. 4x2 (data x model)")
    p.add_argument("--grad-sync", default="auto",
                   choices=["auto", "fused", "bucketed", "sentinel"])
    p.add_argument("--remat", default=None, choices=[None, "full", "dots"])
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--dtype", default=None)
    args = p.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.scale == "smoke" \
        else configs.get(args.arch)
    if args.dtype:
        cfg = cfg.scaled(dtype=args.dtype)
    opt_cfg = optim.OptimConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                                total_steps=args.steps)
    mesh = parse_mesh(args.mesh)
    print(f"[train] arch={cfg.name} scale={args.scale} mesh={dict(mesh.shape)}"
          f" devices={mesh.devices.size}")

    manual = args.grad_sync != "auto"
    policy = ShardingPolicy(
        fsdp=not manual, tp=not manual, sp=not manual, remat=args.remat,
        grad_sync=args.grad_sync)

    key = jax.random.PRNGKey(args.seed)
    state = steps.init_train_state(cfg, opt_cfg, key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"[train] params: {n_params/1e6:.2f}M")

    data = SyntheticLMData(cfg, batch=args.batch, seq=args.seq,
                           seed=args.seed)
    abatch = jax.eval_shape(lambda: data.batch_at(0))

    start_step = 0
    saver = None
    if args.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            a_state = jax.eval_shape(lambda: state)
            state, start_step = ckpt.restore_checkpoint(
                args.ckpt_dir, a_state)
            print(f"[train] restored checkpoint at step {start_step}")

    with mesh:
        if manual:
            make = steps.build_train_step_manual(cfg, mesh, policy, opt_cfg)
            step_fn = make(jax.eval_shape(lambda: state), abatch)
            state_shard = None
        else:
            step_fn, sshard = steps.build_train_step(
                cfg, mesh, policy, opt_cfg, abstract_batch=abatch,
                donate=False)
            state = jax.device_put(state, sshard)
            state_shard = sshard

        bshard = batch_shardings(mesh, abatch)
        prefetch = Prefetcher(
            data, start_step=start_step,
            device_put_fn=lambda b: jax.device_put(b, bshard))

        if saver is not None:
            ckpt.install_preemption_handler(
                lambda: (saver.save(state, cur_step), saver.wait_all()))

        losses = []
        t0 = time.time()
        cur_step = start_step
        for cur_step in range(start_step, args.steps):
            batch = prefetch.get(cur_step)
            state, metrics = step_fn(state, batch)
            if (cur_step + 1) % args.log_every == 0 or cur_step == start_step:
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                print(f"[train] step {cur_step + 1}/{args.steps} "
                      f"loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)")
            if saver is not None and (cur_step + 1) % args.ckpt_every == 0:
                saver.save(state, cur_step + 1)  # async — does not block

        jax.block_until_ready(state)
        prefetch.close()
        if saver is not None:
            saver.save(state, args.steps)
            saver.close()

    if len(losses) >= 2 and losses[-1] >= losses[0]:
        print("[train] WARNING: loss did not improve "
              f"({losses[0]:.4f} -> {losses[-1]:.4f}) — short runs on the "
              "synthetic stream are noisy; see examples/train_lm.py")
    else:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
