"""Serving CLI: continuous batching on the task runtime.

Thin front-end over :class:`repro.serving.engine.ServingEngine` with the
real model path (:class:`repro.serving.lm.LMAdapter`): ``--batch``
requests are admitted through the engine's queue and decoded greedily,
each prefill/decode micro-step and host detokenisation a runtime task,
with device completion bound through the AsyncHandle protocol
(``--completion event``) or synchronised in-task (``--completion
blocking``, the sentinel baseline).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import sys

import jax

from .. import configs
from ..models import model
from ..runtime.sharding import ShardingPolicy
from ..serving import Request, ServingEngine
from ..serving.lm import LMAdapter
from . import mesh as meshlib


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-2b")
    p.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    p.add_argument("--batch", type=int, default=4,
                   help="number of requests to serve")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--slots", type=int, default=4,
                   help="max in-flight requests (continuous batching)")
    p.add_argument("--completion", default="event",
                   choices=["event", "blocking"])
    p.add_argument("--workers", type=int, default=4)
    args = p.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.scale == "smoke" \
        else configs.get(args.arch)
    if not cfg.causal:
        print(f"[serve] {cfg.name} is encoder-only: no decode step")
        return 0
    mesh = meshlib.local_mesh(model=1)
    policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None)
    params = model.init(cfg, jax.random.PRNGKey(args.seed))

    adapter = LMAdapter(cfg, mesh, policy, params,
                        prompt_len=args.prompt_len, gen_len=args.gen)
    adapter.warmup()

    engine = ServingEngine(adapter, slots=args.slots,
                           completion=args.completion,
                           num_workers=args.workers)
    requests = [Request(rid=i, prompt=args.seed * 1000 + i,
                        gen_len=args.gen) for i in range(args.batch)]
    report = engine.run(requests)

    print(f"[serve] arch={cfg.name} requests={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} slots={args.slots} "
          f"completion={args.completion}")
    print(f"[serve] {report.summary()}")
    sample = report.outputs[0][:16]
    print(f"[serve] sample continuation (req 0): {sample}")
    assert all(len(report.outputs[r.rid]) == args.gen for r in requests)
    return 0


if __name__ == "__main__":
    sys.exit(main())
