"""Batched serving driver: prefill + decode loop with a fixed-size cache.

Demonstrates the inference path end-to-end on CPU at smoke scale:
continuous batched greedy decoding with the framework's sharded prefill
and decode steps, prefill→decode cache handoff (pad_cache), and async
host-side detokenisation through the task runtime (the external-events
pattern applied to serving: the device decode loop never waits for the
host consumer).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..core import TaskRuntime, tac
from ..models import model, inputs as model_inputs
from ..runtime import steps
from ..runtime.sharding import ShardingPolicy
from . import mesh as meshlib


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-2b")
    p.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.scale == "smoke" \
        else configs.get(args.arch)
    if not cfg.causal:
        print(f"[serve] {cfg.name} is encoder-only: no decode step")
        return 0
    mesh = meshlib.local_mesh(model=1)
    policy = ShardingPolicy(fsdp=False, tp=False, sp=False, remat=None)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(cfg, key)

    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    batch = model_inputs.make_batch(cfg, batch=B, seq=P, kind="prefill",
                                    key=key)

    with mesh:
        prefill = steps.build_prefill_step(
            cfg, mesh, policy,
            abstract_batch=jax.eval_shape(lambda: batch))
        dec_batch_spec = jax.eval_shape(
            lambda: {"tokens": jnp.zeros((B, 1), jnp.int32)})
        decode, _ = steps.build_decode_step(
            cfg, mesh, policy, batch=B, cache_len=total,
            abstract_batch=dec_batch_spec, donate=False)

        t0 = time.monotonic()
        logits, cache = prefill(params, batch)
        cache = model.pad_cache(cfg, cache, total)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        t_prefill = time.monotonic() - t0

        # async host consumer: the decode loop binds each emitted token to
        # an external event; a host task drains them without ever stalling
        # the device loop (paper Fig. 2 applied to serving)
        emitted = []
        rt = TaskRuntime(num_workers=1)
        rt.start()

        def consume(step, handle):
            def fn():
                tok = np.asarray(tac.wait(handle))
                emitted.append((step, tok))
            rt.submit(fn, inout=["emit-order"], name=f"emit@{step}")

        t0 = time.monotonic()
        for i in range(G):
            dec_in = {"tokens": next_tok[:, None]}
            logits, cache = decode(params, cache, dec_in,
                                   jnp.int32(P + i))
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            consume(i, tac.ArrayHandle(next_tok))
        rt.taskwait()
        rt.close()
        t_decode = time.monotonic() - t0

    toks = np.stack([t for _, t in sorted(emitted)], axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill: {t_prefill * 1e3:.1f} ms "
          f"({B * P / t_prefill:.0f} tok/s)")
    print(f"[serve] decode:  {t_decode / G * 1e3:.2f} ms/step "
          f"({B * G / t_decode:.0f} tok/s)")
    print(f"[serve] sample continuation (seq 0): {toks[0][:16].tolist()}")
    assert toks.shape == (B, G)
    return 0


if __name__ == "__main__":
    sys.exit(main())
