"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published config; ``smoke(name)`` returns the
reduced same-family config used by the CPU smoke tests (small widths, few
layers/experts, tiny vocab — full configs are exercised only via the
dry-run).
"""

from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCHS = (
    "zamba2_2p7b",
    "xlstm_350m",
    "phi3_medium_14b",
    "granite_3_2b",
    "deepseek_coder_33b",
    "starcoder2_15b",
    "internvl2_2b",
    "olmoe_1b_7b",
    "mixtral_8x22b",
    "hubert_xlarge",
)

_ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-350m": "xlstm_350m",
    "phi3-medium-14b": "phi3_medium_14b",
    "granite-3-2b": "granite_3_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "starcoder2-15b": "starcoder2_15b",
    "internvl2-2b": "internvl2_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "hubert-xlarge": "hubert_xlarge",
}


def _module(name: str):
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f".{mod}", __package__)


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ModelConfig:
    return _module(name).smoke()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCHS}
