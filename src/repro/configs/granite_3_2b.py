"""Granite-3.0-2B base — dense decoder with GQA, tied embeddings
[hf:ibm-granite/granite-3.0-2b-base]."""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    pattern=((ATTN, MLP),),
    rope_theta=1e4,
    act="swiglu",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=128)
