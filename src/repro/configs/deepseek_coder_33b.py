"""DeepSeek-Coder 33B — llama-arch dense decoder [arXiv:2401.14196]."""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    pattern=((ATTN, MLP),),
    rope_theta=1e5,
    act="swiglu",
    source="arXiv:2401.14196; hf:deepseek-ai/deepseek-coder-33b-base",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                         d_ff=128, vocab=128)
