"""StarCoder2-15B — GQA + RoPE, LayerNorm, GeLU MLP [arXiv:2402.19173]."""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    pattern=((ATTN, MLP),),
    rope_theta=1e5,
    act="gelu",
    norm="layernorm",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-15b",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=128)
