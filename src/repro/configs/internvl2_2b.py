"""InternVL2-2B — InternViT frontend (stub) + InternLM2-1.8B decoder
[arXiv:2404.16821].

The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings which overwrite the first
``n_patches`` token positions.
"""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    pattern=((ATTN, MLP),),
    rope_theta=1e6,
    act="swiglu",
    frontend="vlm",
    n_patches=256,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=128, n_patches=8)
