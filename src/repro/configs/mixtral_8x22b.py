"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""

from ..models.config import ModelConfig, SWA, MOE

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    pattern=((SWA, MOE),),
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    sliding_window=4096,
    rope_theta=1e6,
    act="swiglu",
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, moe_d_ff=128, n_experts=4, top_k=2,
                         vocab=128, sliding_window=16)
