"""Phi-3-medium 14B — dense decoder, RoPE + SwiGLU + GQA [arXiv:2404.14219]."""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    pattern=((ATTN, MLP),),
    rope_theta=1e4,
    act="swiglu",
    source="arXiv:2404.14219 (unverified tier)",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=128)
