"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, shared transformer block (32 MHA heads,
d_ff=10240) invoked after every 6th Mamba2 block with re-concatenated
embeddings (Zamba2 style).  ssm_state=64.

Simplifications vs. the released checkpoints (noted in DESIGN.md): the
per-invocation LoRA adapters on the shared block are omitted (one truly
shared weight set) and rotary embeddings are used in the shared block.
"""

from ..models.config import ModelConfig, MAMBA2, SHARED_ATTN

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    pattern=((MAMBA2,),) * 5 + ((MAMBA2, SHARED_ATTN),),
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=256,
    rope_theta=1e4,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, ssm_state=16, ssm_chunk=16)
