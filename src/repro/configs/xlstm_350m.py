"""xLSTM-350M — sLSTM + mLSTM blocks [arXiv:2405.04517].

24 blocks, d_model=1024, 4 heads, vocab=50304, d_ff=0 (the xLSTM blocks
carry their own projection FFNs: mLSTM proj factor 2, sLSTM 4/3).
Interleave: one sLSTM block per 4 (xLSTM[7:1]-style mix rounded to the
pattern unit).
"""

from ..models.config import ModelConfig, SLSTM, MLSTM

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=((MLSTM,), (MLSTM,), (MLSTM,), (SLSTM,)),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    ssm_chunk=256,
    source="arXiv:2405.04517 (unverified tier)",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         vocab=128, ssm_chunk=16)
