"""OLMoE-1B-7B — 64-expert top-8 MoE decoder [arXiv:2409.02060]."""

from ..models.config import ModelConfig, ATTN, MOE

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    pattern=((ATTN, MOE),),
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    rope_theta=1e4,
    act="swiglu",
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=64, moe_d_ff=64, n_experts=8, top_k=2,
                         vocab=128)
