"""HuBERT X-Large — encoder-only audio transformer backbone
[arXiv:2106.07447].

The CNN feature extractor is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S, d_model).  Encoder-only ⇒ no
decode shapes.
"""

from ..models.config import ModelConfig, ATTN, MLP

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    pattern=((ATTN, MLP),),
    causal=False,
    act="gelu",
    norm="layernorm",
    frontend="audio",
    source="arXiv:2106.07447 (unverified tier)",
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab=64)
