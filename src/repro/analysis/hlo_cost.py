"""Loop-aware cost model over compiled (post-SPMD-partitioning) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body exactly once
(verified empirically: a scan of length 4 and 8 report identical FLOPs), so
for scan-over-layers programs it under-reports by ~n_layers.  This module
re-derives per-device costs from ``compiled.as_text()`` with loop
multiplication:

* **flops** — ``dot``/``convolution`` from shapes × contracting dims;
  elementwise arithmetic at 1 flop/element; reduces at 1 flop/input element.
* **bytes** — HBM traffic approximation: Σ (operand + result bytes) of every
  *top-level* op in each computation.  Fusion internals are excluded (they
  live in registers/VMEM); fusion boundaries count.
* **collectives** — operand bytes, counts, and ring-model *wire bytes* per
  kind (all-reduce 2(g−1)/g·size, all-gather/reduce-scatter (g−1)/g·size,
  all-to-all (g−1)/g·size, collective-permute 1·size), multiplied by loop
  trip counts.

Trip-count recovery: for each ``while``, the candidates are the s32[]
scalar constants referenced by its condition computation and by its init
tuple (forward scans keep the bound in the condition, reversed/remat scans
in the init); the maximum wins.  Validated against known-depth models in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "atan2",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "clamp",
    "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "is-finite", "erf", "expm1", "log1p",
}

_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elements * _DTYPE_BYTES.get(self.dtype, 4)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")


def parse_shapes(type_text: str) -> List[Shape]:
    """All array shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(type_text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append(Shape(dt, dims))
    return out


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: List[Shape]
    operands: List[str]            # %refs (resolved via the symbol table)
    attrs: str                      # raw remainder of the line
    value: Optional[int] = None     # scalar integer constants
    vmem_tag: bool = False          # op_name metadata marks kernel-resident

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shape_table: Dict[str, List[Shape]]


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*{\s*$")
# op def:  [ROOT] %name = <type> opcode(...), attrs
# Tuple types may contain /*index=N*/ comments; they never nest parens.
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")


def _operand_refs(args_text: str) -> List[str]:
    """%refs appearing in the operand section (up to matching paren)."""
    depth = 1
    end = len(args_text)
    for i, ch in enumerate(args_text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    section = args_text[:end]
    return re.findall(r"%([\w.\-]+)", section), args_text[end + 1:]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        hm = _COMP_HEADER.match(line)
        if hm and ("=" not in line.split("(")[0]) and " -> " in line:
            cur = Computation(hm.group(1), [], {})
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if not om:
            # parameter lines: %p = f32[..] parameter(0)
            pm = re.match(
                r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+parameter\(", line)
            if pm:
                cur.shape_table[pm.group(1)] = parse_shapes(pm.group(2))
                cur.ops.append(Op(pm.group(1), "parameter",
                                  parse_shapes(pm.group(2)), [], ""))
            continue
        name, rtype, opcode, rest = om.groups()
        operands, attrs = _operand_refs(rest)
        op = Op(name, opcode, parse_shapes(rtype), operands, attrs)
        op.vmem_tag = "vmem_resident" in attrs
        if opcode == "constant":
            vm = re.match(r"\s*(-?\d+)\s*\)?", rest)
            if vm:
                op.value = int(vm.group(1))
        cur.shape_table[name] = op.result
        cur.ops.append(op)
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    # bytes of ops tagged kernel-resident (jax.named_scope "vmem_resident_*"
    # regions — tiles the Pallas kernels keep in VMEM on TPU)
    vmem_bytes: float = 0.0

    def _tally(self, opcode: str, nbytes: float, vmem: bool = False) -> None:
        self.bytes += nbytes
        self.bytes_by_op[opcode] = self.bytes_by_op.get(opcode, 0.0) + nbytes
        if vmem:
            self.vmem_bytes += nbytes

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.vmem_bytes += other.vmem_bytes * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult
        for k in COLLECTIVE_KINDS:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_wire_bytes[k] += other.coll_wire_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.coll_wire_bytes.values())


def _dot_flops(op: Op, table: Dict[str, List[Shape]]) -> float:
    out_elems = sum(s.elements for s in op.result)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.attrs)
    lhs_shapes = table.get(op.operands[0]) if op.operands else None
    if not m or not lhs_shapes:
        return 2.0 * out_elems  # fallback
    contract = 1
    dims = lhs_shapes[0].dims
    for d in m.group(1).split(","):
        if d:
            contract *= dims[int(d)]
    return 2.0 * out_elems * contract


def _group_size(op: Op, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups={{([0-9,]+)}", op.attrs)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _wire_factor(kind: str, g: int) -> float:
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0  # collective-permute


def _trip_count(op: Op, comps: Dict[str, Computation],
                comp: Computation) -> float:
    """Loop bound candidates: s32[] scalar constants in the condition
    computation (forward scans) and in the init tuple (reversed scans)."""
    cands = [1]
    cond_name = op.attr("condition")
    body_init = op.operands[0] if op.operands else None
    if cond_name and cond_name in comps:
        for o in comps[cond_name].ops:
            if o.opcode == "constant" and o.value is not None \
                    and o.result and o.result[0].dtype == "s32" \
                    and not o.result[0].dims:
                cands.append(o.value)
    if body_init:
        byname = {o.name: o for o in comp.ops}
        init = byname.get(body_init)
        if init is not None and init.opcode == "tuple":
            for ref in init.operands:
                tgt = byname.get(ref)
                if tgt is not None and tgt.opcode == "copy" and tgt.operands:
                    tgt = byname.get(tgt.operands[0])
                if tgt is not None and tgt.opcode == "constant" \
                        and tgt.value is not None and tgt.result \
                        and tgt.result[0].dtype == "s32" \
                        and not tgt.result[0].dims:
                    cands.append(tgt.value)
    return float(max(cands))


class ModuleCost:
    def __init__(self, text: str, *, n_devices: int = 1):
        self.comps = parse_module(text)
        self.n_devices = n_devices
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m:
            return m.group(1)
        return next(iter(self.comps))

    def cost(self, comp_name: Optional[str] = None) -> Cost:
        name = comp_name or self.entry
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total  # guard cycles
        comp = self.comps.get(name)
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            # --- nested computations -------------------------------------
            if oc == "while":
                body = op.attr("body")
                cond = op.attr("condition")
                trips = _trip_count(op, self.comps, comp)
                if body in self.comps:
                    total.add(self.cost(body), trips)
                if cond in self.comps:
                    total.add(self.cost(cond), trips)
                continue
            if oc in ("call", "conditional", "async-start"):
                for key in ("to_apply", "true_computation",
                            "false_computation", "called_computation"):
                    sub = op.attr(key)
                    if sub in self.comps:
                        total.add(self.cost(sub))
                continue
            if oc == "fusion":
                sub = op.attr("calls")
                if sub in self.comps:
                    c = self.cost(sub)
                    total.flops += c.flops         # compute inside fusion
                    # bytes: boundary only (fall through to byte counting)
            # --- flops ----------------------------------------------------
            if oc == "dot":
                total.flops += _dot_flops(op, comp.shape_table)
            elif oc == "convolution":
                total.flops += 2.0 * sum(s.elements for s in op.result) * 128
            elif oc in _ELEMENTWISE:
                total.flops += sum(s.elements for s in op.result)
            elif oc in ("reduce", "reduce-window"):
                ins = sum(s.elements
                          for ref in op.operands[:max(1, len(op.operands) // 2)]
                          for s in comp.shape_table.get(ref, []))
                total.flops += ins
            # --- collectives ------------------------------------------------
            kind = next((k for k in COLLECTIVE_KINDS
                         if oc == k or oc.startswith(k + "-")), None)
            if kind and not oc.endswith("-done"):
                nbytes = sum(s.bytes for ref in op.operands
                             for s in comp.shape_table.get(ref, []))
                g = _group_size(op, self.n_devices)
                total.coll_bytes[kind] += nbytes
                total.coll_wire_bytes[kind] += nbytes * _wire_factor(kind, g)
                total.coll_counts[kind] += 1
            # --- bytes ------------------------------------------------------
            if oc not in _NO_BYTES:
                if oc == "dynamic-update-slice":
                    # in-place buffer update: traffic = the written slice
                    # (read-modify-write), NOT the whole carried buffer —
                    # counting the full operand makes scan stacking look
                    # O(L²) in HBM bytes.
                    upd = (sum(s.bytes
                               for s in comp.shape_table.get(
                                   op.operands[1], []))
                           if len(op.operands) > 1 else 0)
                    total._tally(oc, 2 * upd, op.vmem_tag)
                elif oc == "dynamic-slice":
                    total._tally(oc, 2 * sum(s.bytes for s in op.result),
                                 op.vmem_tag)
                elif oc in ("gather", "scatter"):
                    # result/updates + index traffic; the addressed buffer
                    # is touched sparsely
                    nbytes = sum(s.bytes for s in op.result)
                    for ref in op.operands[1:]:
                        nbytes += sum(s.bytes
                                      for s in comp.shape_table.get(ref, []))
                    total._tally(oc, nbytes, op.vmem_tag)
                elif oc in ("broadcast", "reshape", "transpose", "copy",
                            "slice", "reverse", "pad"):
                    total._tally(oc, 2 * sum(s.bytes for s in op.result),
                                 op.vmem_tag)
                else:
                    nbytes = sum(s.bytes for s in op.result)
                    for ref in op.operands:
                        nbytes += sum(s.bytes
                                      for s in comp.shape_table.get(ref, []))
                    total._tally(oc, nbytes, op.vmem_tag)
        self._memo[name] = total
        return total


def module_cost(text: str, *, n_devices: int = 1) -> Cost:
    return ModuleCost(text, n_devices=n_devices).cost()
