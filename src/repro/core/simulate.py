"""Deterministic discrete-event makespan simulator for task schedules.

The paper evaluates TAMPI on a 64-node cluster with wall-clock traces
(Figs. 9–14).  This container has one CPU, so wall-clock scaling curves are
not reproducible directly; instead the benchmarks pair *real* executions of
the host task runtime (small scale) with this simulator, which replays the
exact task graphs of each benchmark version under a machine model
(ranks × workers, per-message latency, task overheads) and reports the
makespan.  The simulator models the four execution disciplines that
distinguish the paper's versions:

* ``compute``        — plain task: worker busy for ``compute`` seconds.
* ``comm-held``      — blocking communication *without* TAMPI: the worker is
                       held until the matching remote event arrives (this is
                       what makes unconstrained blocking calls deadlock-prone
                       and what the Sentinel pattern works around).
* ``comm-paused``    — TAMPI blocking mode (§6.1): worker is released during
                       the wait; on completion the task pays a scheduler
                       round-trip (``resume_overhead``) on a worker to finish.
* ``comm-events``    — TAMPI non-blocking mode (§6.2): the task finishes
                       immediately after its body; its *release* (and hence
                       its successors' readiness) is deferred to the event
                       arrival.  No worker re-acquisition, no held stack.

Dependencies are split accordingly: ``start_deps`` gate the task's start
(regular data flow), ``event_deps`` are bound external events that gate only
its release.  Cross-rank edges carry a latency.

**Collective nodes**: tasks sharing a ``group`` label model one collective
operation — every member must *enter* (finish its body) before any member
completes, plus ``group_latency`` (≈ rounds × per-message latency, see
``collectives.n_rounds``).  The waiting discipline is the member's ``kind``:
``comm-held`` members hold their worker until the last rank arrives (the
sentinel/serialized collective), ``comm-paused`` pause and pay a resume,
``comm-events`` finish immediately and defer their release to collective
completion (the event-bound collective).  Internally a group is expanded
into pairwise event edges, so all four disciplines compose unchanged.

**Neighbourhood nodes**: a comm task with ``neighbors=[(peer id, latency),
...]`` models one rank's round of a *neighbourhood* collective (halo
exchange): it completes once every listed peer has entered (peer body done
+ that edge's latency) — no all-ranks barrier, only the declared halo
edges.  Unlike raw ``event_deps``, neighbour edges are validated (peers
must be comm-kind tasks, so a ``compute`` node cannot silently become a
message source) and declared symmetrically by each member of the exchange.
The waiting discipline is again the task's ``kind``.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

COMPUTE = "compute"
COMM_HELD = "comm-held"
COMM_PAUSED = "comm-paused"
COMM_EVENTS = "comm-events"

Dep = Tuple[int, float]  # (task id, edge latency)


@dataclass
class SimTask:
    id: int
    rank: int
    compute: float
    kind: str = COMPUTE
    start_deps: List[Dep] = field(default_factory=list)
    event_deps: List[Dep] = field(default_factory=list)
    name: str = ""
    group: Optional[str] = None      # collective membership label
    group_latency: float = 0.0       # arrival→completion lag of the group
    neighbors: List[Dep] = field(default_factory=list)  # halo peer edges

    # runtime state
    _pending_start: int = 0
    _pending_events: int = 0
    _had_events: bool = False
    _body_done_at: Optional[float] = None
    _holding_worker: bool = False
    done_time: Optional[float] = None
    start_time: Optional[float] = None


@dataclass
class SimResult:
    makespan: float
    done_times: Dict[int, float]
    busy_time: Dict[int, float]           # per rank
    held_wait_time: Dict[int, float]      # worker-seconds wasted holding
    max_paused: int                        # peak #paused tasks (live stacks)
    resumes: int                           # scheduler round trips paid
    failed: Set[int] = field(default_factory=set)  # tasks lost to rank death

    def utilization(self, workers_per_rank: int, n_ranks: int) -> float:
        total = self.makespan * workers_per_rank * n_ranks
        return sum(self.busy_time.values()) / total if total else 0.0


class Simulator:
    """List-scheduling discrete-event simulator over per-rank worker pools.

    ``dispatch_overhead`` models the completion-notification cost of the
    continuation backend (:mod:`repro.core.continuations`): when the
    *last* pending event of a task arrives, its completion callback is
    dispatched from the engine's queue ``dispatch_overhead`` seconds
    later — the per-completion term of :func:`progress_cost`.  The
    polling backend's per-tick re-test cost is not an event in this DAG
    model (it scales with wall time, not with the graph); use
    :func:`progress_cost` to account for it analytically.
    """

    def __init__(self, n_ranks: int, workers_per_rank: int, *,
                 task_overhead: float = 0.0,
                 resume_overhead: float = 0.0,
                 dispatch_overhead: float = 0.0) -> None:
        self.n_ranks = n_ranks
        self.workers = workers_per_rank
        self.task_overhead = task_overhead
        self.resume_overhead = resume_overhead
        self.dispatch_overhead = dispatch_overhead

    def run(self, tasks: List[SimTask],
            fail: Optional[Tuple[int, float]] = None) -> SimResult:
        """Replay the task graph; ``fail=(rank, time)`` injects rank death.

        Simulated ULFM semantics (deterministic large-n replay of what
        :class:`repro.core.resilience.FaultInjector` does to the real
        runtime): at ``time`` the rank's workers stop — its queued tasks
        never dispatch, its in-flight bodies never complete — while
        anything the dead rank finished *before* the failure stays
        delivered (messages in flight arrive).  Tasks that consequently
        never complete (the dead rank's remainder plus its transitive
        dependency cone, through start, event, neighbour, and collective
        edges alike) are reported in :attr:`SimResult.failed` instead of
        raising the deadlock error; the makespan covers the survivors.
        """
        byid = {t.id: t for t in tasks}
        succ_start: Dict[int, List[Dep]] = {t.id: [] for t in tasks}
        succ_event: Dict[int, List[Dep]] = {t.id: [] for t in tasks}
        for t in tasks:
            t._pending_start = len(t.start_deps)
            t._pending_events = len(t.event_deps)
            t._had_events = bool(t.event_deps)
            t._body_done_at = None
            t._holding_worker = False
            t.done_time = None
            t.start_time = None
            for dep, lat in t.start_deps:
                succ_start[dep].append((t.id, lat))
            for dep, lat in t.event_deps:
                succ_event[dep].append((t.id, lat))

        # Neighbourhood nodes: halo edges from the declared peers only —
        # completion is max(own body done, peer arrival + edge latency).
        # Expanded into event edges (non-destructively, per run).
        for t in tasks:
            if not t.neighbors:
                continue
            if t.kind == COMPUTE:
                raise ValueError(
                    f"neighbourhood node {t.name or t.id} must use a comm "
                    f"kind (held/paused/events), not {COMPUTE!r}")
            for pid, lat in t.neighbors:
                peer = byid.get(pid)
                if peer is None:
                    raise ValueError(f"neighbourhood node {t.name or t.id} "
                                     f"references unknown task {pid}")
                if peer.kind == COMPUTE:
                    raise ValueError(
                        f"neighbour peer {peer.name or pid} of "
                        f"{t.name or t.id} must be a comm-kind task")
                t._pending_events += 1
                t._had_events = True
                succ_event[pid].append((t.id, lat))

        # Collective groups: each member waits (per its kind's discipline)
        # for every other member's arrival + the group's round latency —
        # expanded into pairwise event edges (non-destructively, per run).
        groups: Dict[str, List[SimTask]] = {}
        for t in tasks:
            if t.group is not None:
                if t.kind == COMPUTE:
                    raise ValueError(
                        f"collective member {t.name or t.id} must use a "
                        f"comm kind (held/paused/events), not {COMPUTE!r}")
                groups.setdefault(t.group, []).append(t)
        for members in groups.values():
            for t in members:
                # Edges from every member INCLUDING itself: completion is
                # last-arrival + group_latency for all members (the last
                # arriver still pays the rounds after it enters).
                if len(members) > 1 or t.group_latency > 0.0:
                    t._pending_events += len(members)
                    t._had_events = True
                    for m in members:
                        succ_event[m.id].append((t.id, t.group_latency))

        free = {r: self.workers for r in range(self.n_ranks)}
        ready: Dict[int, List[Tuple[int, SimTask]]] = {
            r: [] for r in range(self.n_ranks)}  # (priority, task) FIFO-ish
        resume_q: Dict[int, List[SimTask]] = {r: [] for r in range(self.n_ranks)}
        busy = {r: 0.0 for r in range(self.n_ranks)}
        held = {r: 0.0 for r in range(self.n_ranks)}
        paused = 0
        max_paused = 0
        resumes = 0

        seq = itertools.count()
        heap: List[Tuple[float, int, str, int]] = []  # (t, seq, kind, task id)

        def push(t: float, kind: str, tid: int) -> None:
            heapq.heappush(heap, (t, next(seq), kind, tid))

        dead_ranks: Set[int] = set()
        if fail is not None:
            fail_rank, fail_time = fail
            if not 0 <= fail_rank < self.n_ranks:
                raise ValueError(f"fail rank {fail_rank} out of range for "
                                 f"{self.n_ranks} ranks")
            push(float(fail_time), "rank-fail", fail_rank)

        now = 0.0
        for t in tasks:
            if t._pending_start == 0:
                ready[t.rank].append((next(seq), t))

        def finish(task: SimTask, t: float) -> None:
            # Full completion (= dependency release): gates start_deps.
            task.done_time = t
            for sid, lat in succ_start[task.id]:
                push(t + lat, "start-arr", sid)

        def dispatch(rank: int, t: float) -> None:
            nonlocal paused, resumes
            if rank in dead_ranks:
                return          # dead workers dispatch nothing
            while free[rank] > 0 and (resume_q[rank] or ready[rank]):
                if resume_q[rank]:
                    task = resume_q[rank].pop(0)
                    free[rank] -= 1
                    paused -= 1
                    resumes += 1
                    dur = self.resume_overhead
                    busy[rank] += dur
                    push(t + dur, "resume-done", task.id)
                    continue
                _, task = ready[rank].pop(0)
                free[rank] -= 1
                task.start_time = t
                dur = task.compute + self.task_overhead
                busy[rank] += dur
                push(t + dur, "body-done", task.id)

        dirty = set(range(self.n_ranks))

        def flush(t: float) -> None:
            for r in list(dirty):
                dirty.discard(r)
                dispatch(r, t)

        flush(now)
        while heap:
            now, _, kind, tid = heapq.heappop(heap)
            if kind == "rank-fail":
                dead_ranks.add(tid)
                continue
            task = byid[tid]
            r = task.rank
            if r in dead_ranks:
                # The dead rank's pending events evaporate: a body that
                # was mid-flight at the failure never completes, so none
                # of its outgoing message/collective edges ever fire —
                # while everything it finished earlier stays delivered.
                continue
            if kind == "start-arr":
                task._pending_start -= 1
                if task._pending_start == 0:
                    ready[r].append((next(seq), task))
                    dirty.add(r)
            elif kind == "body-done":
                task._body_done_at = now
                # Matching/posting semantics: bound external events of *other*
                # tasks fire when this task's body completes (an MPI ssend
                # matches when the recv is posted, not when the recv *task*
                # releases its dependencies).  Start deps, by contrast, wait
                # for the full release — that distinction is the essence of
                # the non-blocking mode's dependency graph.
                for sid, lat in succ_event[task.id]:
                    push(now + lat, "event-arr", sid)
                if task.kind == COMPUTE or task._pending_events == 0:
                    if task.kind == COMM_PAUSED and task._had_events:
                        # events already arrived: still pay the round trip
                        free[r] += 1
                        paused += 1
                        max_paused = max(max_paused, paused)
                        resume_q[r].append(task)
                    else:
                        free[r] += 1
                        finish(task, now)
                    dirty.add(r)
                elif task.kind == COMM_HELD:
                    task._holding_worker = True  # worker NOT released
                elif task.kind == COMM_PAUSED:
                    free[r] += 1
                    paused += 1
                    max_paused = max(max_paused, paused)
                    dirty.add(r)
                elif task.kind == COMM_EVENTS:
                    free[r] += 1  # worker moves on; release deferred
                    dirty.add(r)
                else:
                    raise ValueError(task.kind)
            elif kind == "event-arr":
                task._pending_events -= 1
                if task._pending_events == 0 and task._body_done_at is not None:
                    if self.dispatch_overhead > 0.0:
                        # Continuation backend: the completion callback is
                        # dispatched from the queue, one overhead later.
                        push(now + self.dispatch_overhead, "event-fire",
                             task.id)
                    else:
                        push(now, "event-fire", task.id)
            elif kind == "event-fire":
                if task.kind == COMM_HELD:
                    held[r] += now - task._body_done_at
                    busy[r] += now - task._body_done_at
                    free[r] += 1
                    finish(task, now)
                elif task.kind == COMM_PAUSED:
                    resume_q[r].append(task)
                elif task.kind == COMM_EVENTS:
                    finish(task, now)
                dirty.add(r)
            elif kind == "resume-done":
                free[r] += 1
                finish(task, now)
                dirty.add(r)
            # Dispatch after draining simultaneous events at this timestamp.
            if not heap or heap[0][0] > now:
                flush(now)

        unfinished = [t for t in tasks if t.done_time is None]
        if unfinished and fail is None:
            names = [t.name or str(t.id) for t in unfinished[:5]]
            raise RuntimeError(
                f"simulation deadlock: {len(unfinished)} tasks never "
                f"completed (e.g. {names}) — exactly the §5 scenario")
        finished = [t for t in tasks if t.done_time is not None]
        makespan = max((t.done_time for t in finished), default=0.0)
        return SimResult(makespan=makespan,
                         done_times={t.id: t.done_time for t in finished},
                         busy_time=busy, held_wait_time=held,
                         max_paused=max_paused, resumes=resumes,
                         failed={t.id for t in unfinished})


# ---------------------------------------------------------------------------
# Trace replay: SimTask timelines -> repro.obs span events
# ---------------------------------------------------------------------------
def trace_events(tasks: List[SimTask]) -> List[dict]:
    """Chrome-trace events for a simulated run — same schema as the host.

    Call after :meth:`Simulator.run` (the tasks carry their timestamps).
    Each task body becomes a ``task/run`` span labelled ``compute`` or
    ``comm``; each comm-kind task's wait window (body done → completion)
    becomes a ``handle/inflight`` span, and :data:`COMM_PAUSED` waits
    additionally emit the ``task/pause`` span the host runtime's
    spare-thread block would.  Events carry ``source="sim"`` and validate
    against :func:`repro.obs.trace.SPAN_SCHEMA`, so
    :func:`repro.obs.analysis.overlap_fraction` computes the *same*
    number from a simulated replay as from a host trace — the oracle
    ``tests/test_obs.py`` exploits.
    """
    from ..obs.trace import span_event

    events: List[dict] = []
    for t in tasks:
        if t.start_time is None or t._body_done_at is None:
            continue                      # never ran (failed rank)
        t0 = t.start_time * 1e6
        t1 = t._body_done_at * 1e6
        label = "compute" if t.kind == COMPUTE else "comm"
        if t1 > t0:                       # zero-compute proxies add noise
            events.append(span_event(
                "task", "run", t0, t1 - t0, rank=t.rank,
                task=t.name or str(t.id), label=label, source="sim"))
        if t.kind == COMPUTE or t.done_time is None:
            continue
        t2 = t.done_time * 1e6
        if t2 > t1:
            events.append(span_event(
                "handle", "inflight", t1, t2 - t1, rank=t.rank,
                kind=t.kind, task=t.name or str(t.id), source="sim"))
            if t.kind == COMM_PAUSED:
                events.append(span_event(
                    "task", "pause", t1, t2 - t1, rank=t.rank,
                    task=t.name or str(t.id), mode="sim", source="sim"))
    events.sort(key=lambda e: e["ts"])
    return events


# ---------------------------------------------------------------------------
# Progress-path cost: the α-β term of the two notification backends
# ---------------------------------------------------------------------------
def progress_cost(backend: str, *, in_flight: float, ticks: float,
                  completions: float, test_s: float,
                  dispatch_s: float) -> float:
    """Analytic progress-engine cost of one notification backend.

    The α-β model's missing term: moving bytes is only part of a
    communication task's cost — somebody must also *notice* completions.

    * ``"polling"`` — the registry re-tests every in-flight operation
      each tick and pays a dispatch per completion:
      ``test_s·in_flight·ticks + dispatch_s·completions``.  Per tick the
      cost is **linear in the number of in-flight operations**, even
      when nothing completed.
    * ``"continuation"`` — completions are pushed at match time and only
      ready callbacks are dispatched: ``dispatch_s·completions``.  Per
      tick the cost is **flat** (zero when nothing completed), total
      work O(completions) regardless of how many operations are parked.

    The discrete-event counterpart of the dispatch term is
    ``Simulator(dispatch_overhead=dispatch_s)``;
    ``benchmarks/overlap_bench.py`` measures both backends against this
    model over an in-flight sweep.
    """
    if backend == "continuation":
        return dispatch_s * completions
    if backend == "polling":
        return test_s * in_flight * ticks + dispatch_s * completions
    raise ValueError(f"unknown backend {backend!r}; "
                     f"one of ('polling', 'continuation')")


# ---------------------------------------------------------------------------
# Schedule-IR replay: one Schedule -> a SimTask graph
# ---------------------------------------------------------------------------
def two_tier_link(intra: int, *, alpha: float, beta: float,
                  inter_alpha: float, inter_beta: float):
    """Per-transfer (α, β) for a two-tier (pod) machine.

    Ranks are numbered ``r = pod·intra + local`` (the
    :func:`repro.core.schedule.build_hierarchical` layout); transfers
    between ranks of different pods pay the inter-pod constants.  Pass
    the result as ``link=`` to :func:`schedule_tasks` /
    :func:`schedule_makespan` — it applies to ANY schedule over that rank
    layout, which is what makes the hierarchical-vs-flat-ring replay an
    apples-to-apples comparison on the same machine model.
    """
    def link(src: int, dst: int):
        if src // intra != dst // intra:
            return inter_alpha, inter_beta
        return alpha, beta
    return link


def schedule_tasks(sched, *, size: float, alpha: float, beta: float,
                   gamma: float = 0.0, kind: str = COMM_EVENTS,
                   base_id: int = 0, name_prefix: str = "",
                   link=None) -> List[SimTask]:
    """Expand a :class:`repro.core.schedule.Schedule` into a SimTask graph.

    The discrete-event counterpart of :meth:`Schedule.cost`: each matched
    Send/Recv pair becomes a comm task on the destination rank whose
    external event arrives ``α + β·frac·size`` after the payload's
    producer finishes; each ``Combine`` becomes a compute task of
    ``γ·frac·size`` seconds occupying a worker — so with one worker per
    rank, combines serialise per rank while independent transfers fly,
    which is exactly what lets a segmented schedule's transport overlap
    its combines.  Marshalling ops (Copy/Pack/Unpack/Slice/Const) carry
    dependencies but no tasks.

    ``kind`` picks the transfer tasks' waiting discipline (``comm-events``
    by default — the event-bound collective; ``comm-paused`` /
    ``comm-held`` model the blocking and sentinel-serialised runs).
    ``link`` optionally maps ``(src rank, dst rank)`` to that transfer's
    ``(α, β)`` — a heterogeneous machine model; :func:`two_tier_link`
    builds the pod-aware one that makes hierarchical schedules pay cheap
    intra-pod and expensive inter-pod constants.  Returns tasks with ids
    starting at ``base_id``; feed them to :class:`Simulator`
    (``n_ranks=sched.n``), possibly merged with other graphs.
    """
    from .schedule import Combine, Concat, Const, Copy, Pack, Recv, Send, \
        Slice, Unpack

    tasks: List[SimTask] = []
    ids = itertools.count(base_id)

    def new_task(rank, compute, kind_, name, start=(), events=()):
        t = SimTask(next(ids), rank, compute, kind=kind_,
                    start_deps=[(d, 0.0) for d in start],
                    event_deps=list(events),
                    name=f"{name_prefix}{name}")
        tasks.append(t)
        return t.id

    # producers[r][buf] -> set of task ids whose completion makes buf ready
    producers: List[Dict] = []
    entry = []
    for r in range(sched.n):
        eid = new_task(r, 0.0, COMPUTE, f"in[{r}]")
        entry.append(eid)
        producers.append({b: {eid} for b in sched._initial_bufs(r)})

    arrivals: Dict = {}     # transfer tag -> (deps of the sent payload)
    pcs = [0] * sched.n
    remaining = sum(len(p) for p in sched.programs)
    while remaining:
        progressed = False
        for r in range(sched.n):
            prog = sched.programs[r]
            while pcs[r] < len(prog):
                op = prog[pcs[r]]
                deps = producers[r]
                if isinstance(op, Recv):
                    if op.tag not in arrivals:
                        break
                    a, bt = (alpha, beta) if link is None \
                        else link(op.peer, r)
                    lat = a + bt * op.frac * size
                    cid = new_task(
                        r, 0.0, kind, f"xfer:{op.tag}",
                        events=[(d, lat) for d in arrivals[op.tag]])
                    # proxy whose BODY completion == transfer completion,
                    # so downstream event edges measure from the right
                    # instant (event edges fire at body-done).
                    pid = new_task(r, 0.0, COMPUTE, f"got:{op.tag}",
                                   start=[cid])
                    deps[op.buf] = {pid}
                elif isinstance(op, Send):
                    arrivals[op.tag] = frozenset(deps[op.buf])
                elif isinstance(op, Combine):
                    kid = new_task(r, gamma * op.frac * size, COMPUTE,
                                   f"combine:{op.out}",
                                   start=sorted(deps[op.a] | deps[op.b]))
                    deps[op.out] = {kid}
                elif isinstance(op, Copy):
                    deps[op.out] = set(deps[op.src])
                elif isinstance(op, Pack):
                    merged: Set[int] = set()
                    for p in op.parts:
                        merged |= deps[p]
                    deps[op.out] = merged
                elif isinstance(op, Unpack):
                    for o in op.outs:
                        deps[o] = set(deps[op.src])
                elif isinstance(op, Slice):
                    deps[op.out] = set(deps[op.src])
                elif isinstance(op, Concat):
                    merged = set()
                    for p in op.reads:
                        merged |= deps[p]
                    deps[op.out] = merged
                elif isinstance(op, Const):
                    deps[op.out] = {entry[r]}
                else:           # pragma: no cover - new op kinds
                    raise TypeError(f"cannot simulate op {op!r}")
                pcs[r] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            stuck = [r for r in range(sched.n)
                     if pcs[r] < len(sched.programs[r])]
            raise RuntimeError(f"schedule deadlock while expanding: "
                               f"ranks {stuck} cannot progress")
    return tasks


def schedule_makespan(sched, *, size: float, alpha: float, beta: float,
                      gamma: float = 0.0, kind: str = COMM_EVENTS,
                      workers_per_rank: int = 1,
                      task_overhead: float = 0.0,
                      resume_overhead: float = 0.0,
                      link=None) -> float:
    """Discrete-event makespan of one schedule under the α-β(-γ) model —
    the simulator-side twin of :meth:`Schedule.cost` (which is analytic
    and additionally serialises send ports).  ``link`` (see
    :func:`two_tier_link`) replays the DAG on a heterogeneous machine."""
    tasks = schedule_tasks(sched, size=size, alpha=alpha, beta=beta,
                           gamma=gamma, kind=kind, link=link)
    sim = Simulator(sched.n, workers_per_rank, task_overhead=task_overhead,
                    resume_overhead=resume_overhead)
    return sim.run(tasks).makespan
