"""repro.core — the paper's contribution: task runtime + Task-Aware Collectives.

Exports the two generic runtime APIs proposed by the paper (§4) with their
original names, the task runtime that implements them, the TAC library
(the TAMPI analogue for JAX), and the schedule-IR stack: one schedule
description (`repro.core.schedule`) with two executors — the host
progress engine (`repro.core.collectives`, Level A) and the XLA lowering
(`repro.core.lowering`, Level B).
"""

from .events import (BlockingContext, EventCounter,
                     get_current_blocking_context, block_current_task,
                     unblock_task, get_current_event_counter,
                     increase_current_task_event_counter,
                     decrease_task_event_counter, current_task)
from .polling import PollingRegistry
from . import continuations
from .continuations import Continuation, ContinuationEngine
from .taskgraph import Task, TaskGraph
from .executor import TaskRuntime, TaskError
from . import tac
from . import schedule
from . import simulate
from . import collectives
from . import lowering
from . import overlap
from . import options
from . import resilience
from .schedule import Schedule, build_neighbor, best_schedule
from .collectives import (Collectives, CollectiveHandle, HaloExchange,
                          HierarchicalCollectives, PersistentCollective)
from .options import CollectiveOptions
from .tac import (CommWorld, CommGroup, CartGroup, DistGraphGroup,
                  RankFailedError, CommRevokedError, AsyncHandle,
                  as_handle)
from .resilience import FaultInjector

__all__ = [
    # pause/resume API (§4.1)
    "get_current_blocking_context", "block_current_task", "unblock_task",
    # external events API (§4.3)
    "get_current_event_counter", "increase_current_task_event_counter",
    "decrease_task_event_counter",
    # polling services API (§4.2) — register/unregister live on the registry
    "PollingRegistry",
    # continuation-based completion notification (poll-free progress)
    "continuations", "Continuation", "ContinuationEngine",
    # runtime
    "Task", "TaskGraph", "TaskRuntime", "TaskError", "BlockingContext",
    "EventCounter", "current_task",
    # TAMPI analogue + task-aware collectives
    "tac", "simulate", "collectives", "Collectives", "CollectiveHandle",
    # unified async-handle protocol + consolidated tuning spec
    "AsyncHandle", "as_handle", "options", "CollectiveOptions",
    # schedule IR + its two executors
    "schedule", "lowering", "overlap", "Schedule", "build_neighbor",
    "best_schedule",
    # sub-communicators + neighbourhood collectives
    "CommWorld", "CommGroup", "CartGroup", "DistGraphGroup", "HaloExchange",
    "HierarchicalCollectives",
    # persistent collectives (MPI_*_init analogue)
    "PersistentCollective",
    # ULFM-style fault tolerance (elastic worlds)
    "resilience", "FaultInjector", "RankFailedError", "CommRevokedError",
]
