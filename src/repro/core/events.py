"""Blocking contexts and event counters (paper §4.1 and §4.3).

This module implements the two generic runtime APIs that the paper proposes
for integrating blocking and non-blocking operations with a task-based
runtime:

* the *pause/resume* API — ``get_current_blocking_context`` /
  ``block_current_task`` / ``unblock_task`` (paper §4.1, Fig. 1); and
* the *external events* API — ``get_current_event_counter`` /
  ``increase_current_task_event_counter`` / ``decrease_task_event_counter``
  (paper §4.3, Fig. 2).

The semantics follow the paper exactly:

* A :class:`BlockingContext` is valid for **one** pause/resume round trip and
  requesting a new context invalidates the currently active one (§4.1).
* A task's event counter is initialised to **1** to prevent the release of
  dependencies while the task is running (§4.6).  The task itself is the only
  party allowed to *increase* its counter; anybody may *decrease* it.  The
  runtime releases the task's dependencies when the counter reaches zero,
  which happens either when the task finishes execution (the implicit
  decrease of the initial 1) or later, when the last bound external event is
  fulfilled.
"""

from __future__ import annotations

import threading
from typing import Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .taskgraph import Task
    from .executor import TaskRuntime


class _CurrentTask(threading.local):
    """Thread-local binding of the task currently executing on this thread."""

    def __init__(self) -> None:
        self.task: Optional["Task"] = None


_current = _CurrentTask()


def set_current_task(task: Optional["Task"]) -> None:
    _current.task = task


def current_task() -> Optional["Task"]:
    """The task bound to the calling thread, or ``None`` outside task code."""
    return _current.task


class BlockingContext:
    """Opaque handle for one pause/resume cycle of a task (paper §4.1).

    Created through :func:`get_current_blocking_context`.  The context wraps a
    ``threading.Event``: :func:`block_current_task` parks the executing thread
    on it after notifying the runtime (so the runtime can hand the core to
    another task), and :func:`unblock_task` — callable from *any* thread —
    sets it.
    """

    __slots__ = ("_task", "_runtime", "_event", "_used", "_valid")

    def __init__(self, task: "Task", runtime: "TaskRuntime") -> None:
        self._task = task
        self._runtime = runtime
        self._event = threading.Event()
        self._used = False
        self._valid = True

    @property
    def task(self) -> "Task":
        return self._task

    def _invalidate(self) -> None:
        self._valid = False


def get_current_blocking_context() -> BlockingContext:
    """Return a fresh blocking context for the invoking task (paper §4.1).

    Requesting a new context invalidates the previously active one.  Must be
    called from inside a task.
    """
    task = current_task()
    if task is None:
        raise RuntimeError(
            "get_current_blocking_context() called from outside a task")
    prev = task._blocking_context
    if prev is not None:
        prev._invalidate()
    ctx = BlockingContext(task, task._runtime)
    task._blocking_context = ctx
    return ctx


def block_current_task(blocking_ctx: BlockingContext) -> None:
    """Suspend the invoking task (paper §4.1).

    The runtime is notified *before* parking, so it can schedule another
    ready task on the core that would otherwise idle (§4.4: the blocking call
    forces a scheduling point).  The call returns once some other thread has
    invoked :func:`unblock_task` on the same context.
    """
    task = current_task()
    if task is None or blocking_ctx._task is not task:
        raise RuntimeError("block_current_task: the argument must be the "
                           "current blocking context of the invoking task")
    if not blocking_ctx._valid or blocking_ctx._used:
        raise RuntimeError("block_current_task: stale blocking context "
                           "(contexts are valid for one pause/resume cycle)")
    blocking_ctx._used = True
    blocking_ctx._runtime._block_task(blocking_ctx)


def unblock_task(blocking_ctx: BlockingContext) -> None:
    """Mark the task bound to ``blocking_ctx`` as resumable (paper §4.1).

    Callable from any thread (e.g. a polling service).  Following §4.4 the
    task is "sent back to the scheduler": here the parked thread wakes and
    contends for a core slot with the regular workers.
    """
    blocking_ctx._runtime._on_task_unblock(blocking_ctx._task)
    blocking_ctx._event.set()


class EventCounter:
    """Per-task atomic counter gating dependency release (paper §4.3, §4.6).

    Initialised to 1.  ``decrease`` to zero triggers
    ``runtime._release_task``: the dependencies of the owning task are
    released, making successor tasks ready.
    """

    __slots__ = ("_task", "_runtime", "_lock", "_count", "_released")

    def __init__(self, task: "Task", runtime: "TaskRuntime") -> None:
        self._task = task
        self._runtime = runtime
        self._lock = threading.Lock()
        self._count = 1  # §4.6: starts at 1 while the task is running.
        self._released = False

    @property
    def task(self) -> "Task":
        return self._task

    @property
    def value(self) -> int:
        with self._lock:
            return self._count

    def _increase(self, increment: int) -> None:
        if increment < 0:
            raise ValueError("increment must be non-negative")
        with self._lock:
            if self._count <= 0:
                raise RuntimeError("event counter already released")
            self._count += increment

    def _decrease(self, decrement: int) -> None:
        if decrement < 0:
            raise ValueError("decrement must be non-negative")
        release = False
        with self._lock:
            if decrement > self._count:
                raise RuntimeError(
                    f"event counter underflow ({self._count} - {decrement})")
            self._count -= decrement
            if self._count == 0 and not self._released:
                self._released = True
                release = True
        if release:
            self._runtime._release_task(self._task)


def get_current_event_counter() -> EventCounter:
    """Return the event counter of the invoking task (paper §4.3)."""
    task = current_task()
    if task is None:
        raise RuntimeError(
            "get_current_event_counter() called from outside a task")
    return task._event_counter


def increase_current_task_event_counter(event_counter: EventCounter,
                                        increment: int = 1) -> None:
    """Bind ``increment`` new external events to the *invoking* task (§4.3).

    Only the task itself may increase its own counter — enforced.
    """
    task = current_task()
    if task is None or event_counter._task is not task:
        raise RuntimeError(
            "increase_current_task_event_counter: only the owning task may "
            "bind new external events (paper §4.3)")
    event_counter._increase(increment)


def decrease_task_event_counter(event_counter: EventCounter,
                                decrement: int = 1) -> None:
    """Fulfil ``decrement`` external events of a (possibly finished) task.

    May be invoked from any thread (paper §4.3, Fig. 2b).  If this drops the
    counter to zero the runtime releases the task's dependencies.
    """
    event_counter._decrease(decrement)
