"""Compiled schedule programs — persistent plans for the Level-A executor.

The reference Level-A executor (:func:`repro.core.collectives._interpret`)
re-walks the schedule IR on every call: per op it re-tests the op's type,
re-derives the wire tag through a fresh ``tag()`` closure, re-translates
communicator-local ranks through ``CommGroup``'s indirection, and re-decides
what to wait on by probing a ``pending`` dict.  That per-operation setup
cost is what the calibrated per-call ``overhead`` constant in
BENCH_baseline.json measures, and it is exactly the cost persistent
operations exist to amortise (``MPI_Allreduce_init``-style plans, cf.
*Designing and Prototyping Extensions to MPI in MPICH*; *MPI Progress For
All*).

This module compiles each (schedule, communicator, op, tag-family) triple
ONCE into a :class:`CompiledProgram` — a flat per-rank list of
``(waits, action)`` steps where

* the wait set is **precomputed** from the schedule's static wait plan
  (:meth:`repro.core.schedule.Schedule.wait_plan`): which receives an op
  consumes is a property of the IR, not of the run;
* ``Send``/``Recv`` actions are **pre-bound closures** posting straight to
  the underlying world transport: peer ranks are pre-translated via
  :meth:`repro.core.tac.CommGroup.translate_many` and each step carries a
  pre-built tag template — at call time only the per-call ``key`` (the tag
  epoch) is inserted, so group indirection and tag assembly vanish from
  the steady state;
* compute actions (``Combine``/``Pack``/``Slice``/...) are closures with
  the combine function pre-resolved — no isinstance dispatch.

Programs are cached immutably (:func:`compile_schedule`), keyed by the
*identities* of the schedule and communicator plus the op and tag family.
Identity keying is deliberate: schedules are lru-cached by their builders
(``schedule.build``/``build_neighbor``/``build_hierarchical``), so equal
requests share one object, and hashing a frozen ``Schedule`` would
recursively hash thousands of ops per call — costing more than the
interpretation it replaces.  The cache holds strong references to the
schedule and communicator, so a cached id can never be recycled by the
garbage collector while its entry lives; eviction (FIFO beyond
``CACHE_MAX``) drops the whole entry.

Execution (:meth:`CompiledProgram.gen`) still produces a generator with
the interpreter's exact driving contract — yields a handle (or list) when
a wait is genuinely outstanding, accepts the payload(s) via ``send()``,
returns the rank result through ``StopIteration`` — so all three drivers
(inline waits, blocking-mode progress engine, event-bound progress engine)
and the group driver run compiled and interpreted ranks interchangeably.
The wire protocol (tags, posting order) is identical op-for-op, so a
compiled rank interoperates with an interpreted peer on the same
communicator.  One deliberate fast path: a wait whose handle already
completed (eager matching) is consumed **without suspending**, skipping
the generator round-trip the interpreter pays.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace as _trc
from . import tac
from .schedule import (Combine, Concat, Const, Copy, Pack, Recv, Schedule,
                       Send, Slice, Unpack)

__all__ = ["CompiledProgram", "compile_schedule", "cache_stats",
           "clear_cache", "CACHE_MAX", "bind_inputs"]

# Reserved env key under which a caller-owned buffer arena rides along a
# run.  An arena maps combine-output buffer names to pre-allocated numpy
# arrays that ufunc combines write into with ``out=`` instead of
# allocating a fresh result per round — the zero-copy half of a
# persistent plan (MPI_Allreduce_init's pre-registered buffers).  The
# arena is only sound when the holder serialises iterations: a run must
# complete (every receive consumed by the peers) before the next one is
# posted, because the in-process transport passes arrays by reference
# and the next iteration overwrites them in place.  That is exactly the
# MPI persistent-request contract (wait before re-start), and it is what
# :class:`repro.core.collectives.PersistentCollective` guarantees.
_ARENA = "__arena__"


def bind_inputs(sched: Schedule, value, blocks, sends):
    """Initial buffer environment for one rank; returns (env, shape).

    Shared input binding of both Level-A executors (the interpreter
    imports it as ``_bind_inputs``); see
    :class:`repro.core.schedule.Schedule` for the input kinds.
    """
    env: Dict[Any, Any] = {}
    shape = None
    kind = sched.input_kind
    if kind == "value":
        env["in"] = value
    elif kind == "array":
        env["in"] = np.asarray(value)
    elif kind == "chunks":
        arr = np.asarray(value)
        shape = arr.shape
        outer = np.array_split(arr.reshape(-1), sched.n_chunks or sched.n)
        if sched.segments == 1:
            for i, c in enumerate(outer):
                env[("c", i)] = c
        else:
            for i, c in enumerate(outer):
                segs = np.array_split(c, sched.segments)
                for s, seg in enumerate(segs):
                    env[("c", i, s)] = seg
    elif kind == "blocks":
        for d in range(sched.n):
            env[("b", d)] = blocks[d]
    elif kind == "dirs":
        for d, v in sends.items():
            env[("s", d)] = v
    elif kind != "none":            # pragma: no cover - new input kinds
        raise ValueError(f"unknown input kind {kind!r}")
    return env, shape


# ---------------------------------------------------------------------------
# Step compilation.  Each op becomes action(env, pending, key): Sends post
# through the pre-bound transport, Recvs deposit their handle in
# ``pending``, compute ops write ``env``.  The per-call ``key`` (tag epoch)
# is the only value not baked in.
# ---------------------------------------------------------------------------
def _compile_op(o, rank: int, isend, irecv, wranks, mktag, op):
    if isinstance(o, Send):
        src, dst, tag = wranks[rank], wranks[o.peer], mktag(o.tag)
        buf = o.buf

        def action(env, pending, key):
            isend(env[buf], src=src, dst=dst, tag=tag(key))
    elif isinstance(o, Recv):
        src, dst, tag = wranks[o.peer], wranks[rank], mktag(o.tag)
        buf = o.buf

        def action(env, pending, key):
            pending[buf] = irecv(src=src, dst=dst, tag=tag(key))
    elif isinstance(o, Combine):
        if op is None:
            raise ValueError(
                f"schedule combines ({o!r}) but no op was compiled in")
        out, a, b = o.out, o.a, o.b
        if isinstance(op, np.ufunc):
            # Named reductions resolve to raw ufuncs, which accept
            # ``out=`` — under an arena the combine writes into a
            # persistent per-output buffer instead of allocating.
            def action(env, pending, key):
                va, vb = env[a], env[b]
                arena = env.get(_ARENA)
                if (arena is None or not isinstance(va, np.ndarray)
                        or not isinstance(vb, np.ndarray)
                        or va.shape != vb.shape):
                    env[out] = op(va, vb)
                    return
                buf = arena.get(out)
                rt = np.result_type(va, vb)
                if buf is None or buf.shape != va.shape or buf.dtype != rt:
                    buf = np.empty(va.shape, rt)
                    arena[out] = buf
                env[out] = op(va, vb, out=buf)
        else:
            def action(env, pending, key):
                env[out] = op(env[a], env[b])
    elif isinstance(o, Copy):
        out, src_buf = o.out, o.src

        def action(env, pending, key):
            env[out] = env[src_buf]
    elif isinstance(o, Pack):
        out, parts = o.out, o.parts

        def action(env, pending, key):
            env[out] = tuple(env[p] for p in parts)
    elif isinstance(o, Unpack):
        outs, src_buf = o.outs, o.src

        def action(env, pending, key):
            for b, v in zip(outs, env[src_buf]):
                env[b] = v
    elif isinstance(o, Slice):
        out, src_buf, parts, index = o.out, o.src, o.parts, o.index

        def action(env, pending, key):
            env[out] = np.array_split(
                np.asarray(env[src_buf]).reshape(-1), parts)[index]
    elif isinstance(o, Concat):
        out, parts, like = o.out, o.parts, o.like

        def action(env, pending, key):
            flat = np.concatenate([np.asarray(env[p]).reshape(-1)
                                   for p in parts])
            env[out] = flat if like is None else flat.reshape(
                np.asarray(env[like]).shape)
    elif isinstance(o, Const):
        out, value = o.out, o.value

        def action(env, pending, key):
            env[out] = value
    else:                           # pragma: no cover - new op kinds
        raise TypeError(f"cannot compile op {o!r}")
    return action


def _compile_finish(sched: Schedule) -> Optional[Callable]:
    """The rank-independent parts of result formation, pre-dispatched."""
    kind = sched.output_kind
    if kind == "none":
        return None
    if kind == "concat":
        chunk_bufs = sched.chunk_bufs

        def finish(env, shape, rank):
            flat = np.concatenate([env[c] for c in chunk_bufs])
            return flat.reshape(shape)
    elif kind == "buf":
        out_bufs = sched.out_bufs

        def finish(env, shape, rank):
            out = out_bufs[rank]
            if out is None:
                return None
            v = env[out]
            # Under an arena the final combine result lives in a reused
            # buffer; hand the caller a copy so the next iteration's
            # in-place writes cannot reach a result already returned.
            if _ARENA in env and isinstance(v, np.ndarray):
                return v.copy()
            return v
    elif kind == "list":
        names = tuple(("g", i) for i in range(sched.n))

        def finish(env, shape, rank):
            return [env[g] for g in names]
    elif kind == "dirs":
        rv_dirs = sched.in_dirs or sched.out_dirs

        def finish(env, shape, rank):
            return {d: env[("rv", d)] for d in rv_dirs[rank]}
    else:                           # pragma: no cover - new output kinds
        raise ValueError(f"unknown output kind {kind!r}")
    return finish


class _RankPlan:
    """One rank's straight-line program: ``(waits, action)`` steps plus the
    trailing receives completion must drain."""

    __slots__ = ("steps", "tail")

    def __init__(self, steps, tail) -> None:
        self.steps = steps
        self.tail = tail


class CompiledProgram:
    """A schedule pre-bound to one communicator, op and tag family.

    Construction resolves everything rank-independent (transport, rank
    translation table, output formation); per-rank step lists compile
    lazily on first use — a collective caller only ever runs its own
    rank — and are retained for the program's lifetime, so iterating
    callers (persistent collectives, halo exchanges, solver loops) pay
    compilation once.

    ``head`` is the tag-family prefix: step tags are
    ``head + (key, sub)`` — with ``sub`` baked in at compile time —
    namespaced through ``("grp", gid, ...)`` exactly as the communicator
    itself would, so compiled and interpreted ranks of the same
    collective match on the wire.
    """

    __slots__ = ("sched", "comm", "op", "head", "epoch", "_ranks",
                 "_finish", "_isend", "_irecv", "_wranks", "_mktag",
                 "_lock")

    def __init__(self, sched: Schedule, comm, *, op: Optional[Callable],
                 head: Tuple[Any, ...]) -> None:
        if sched.n != comm.size:
            raise ValueError(f"schedule is for n={sched.n} ranks but the "
                             f"communicator has size {comm.size}")
        self.sched = sched
        self.comm = comm
        self.op = op
        self.head = head
        self.epoch = epoch_of(comm)
        self._ranks: List[Optional[_RankPlan]] = [None] * sched.n
        self._finish = _compile_finish(sched)
        self._lock = threading.Lock()
        if isinstance(comm, tac.CommGroup):
            # Pre-translate the whole group ONCE (MPI_Group_translate_ranks)
            # and post straight to the world transport with the group's
            # ("grp", gid, ...) tag namespace baked into the template.
            world = comm.world
            self._wranks = tuple(
                comm.translate_many(range(comm.size), world))
            self._isend, self._irecv = world.isend, world.irecv
            gid = comm.gid

            def mktag(sub):
                def tag(key):
                    return ("grp", gid, head + (key, sub))
                return tag
        else:
            # CommWorld — or any duck-typed communicator without group
            # indirection: local ranks are transport ranks.
            self._wranks = tuple(range(sched.n))
            self._isend, self._irecv = comm.isend, comm.irecv

            def mktag(sub):
                def tag(key):
                    return head + (key, sub)
                return tag
        self._mktag = mktag

    # -- per-rank compilation ----------------------------------------------
    def _rank_plan(self, rank: int) -> _RankPlan:
        plan = self._ranks[rank]
        if plan is None:
            with self._lock:
                plan = self._ranks[rank]
                if plan is None:
                    ops, tail = self.sched.wait_plan(rank)
                    steps = tuple(
                        (waits, _compile_op(o, rank, self._isend,
                                            self._irecv, self._wranks,
                                            self._mktag, self.op))
                        for o, waits in ops)
                    plan = _RankPlan(steps, tail)
                    self._ranks[rank] = plan
        return plan

    # -- execution ----------------------------------------------------------
    def gen(self, rank: int, key: Any, *, value=None, blocks=None,
            sends=None, arena: Optional[Dict[Any, Any]] = None):
        """One rank's compiled run — same generator contract as the
        interpreter: yields outstanding handle(s), result via
        ``StopIteration``.  Binding and validation happen on first
        advance (generator semantics), matching ``_interpret``.

        ``arena`` is an optional caller-owned, per-rank dict of reusable
        combine buffers (see :data:`_ARENA`); pass the same dict on every
        iteration to eliminate per-round result allocations.  The caller
        must not re-post before the previous run completed."""
        if not 0 <= rank < self.sched.n:
            raise ValueError(
                f"rank {rank} out of range for n={self.sched.n}")
        if epoch_of(self.comm) != self.epoch:
            raise StaleProgramError(
                f"compiled program {self.head!r} was built at communicator "
                f"epoch {self.epoch} but the communicator is now at epoch "
                f"{epoch_of(self.comm)} (a rank failed or the communicator "
                f"was revoked) — recompile via compile_schedule()")
        plan = self._rank_plan(rank)
        return self._run(plan, rank, key, value, blocks, sends, arena)

    def _run(self, plan, rank, key, value, blocks, sends, arena=None):
        env, shape = bind_inputs(self.sched, value, blocks, sends)
        if arena is not None:
            env[_ARENA] = arena
        pending: Dict[Any, Any] = {}
        rounds = 0
        for waits, action in plan.steps:
            if waits:
                if len(waits) == 1:
                    b = waits[0]
                    h = pending.pop(b)
                    # Fast path: eager matching often completes the recv
                    # before its consumer runs — take the result without
                    # suspending (the interpreter would yield regardless).
                    env[b] = h.result if h.test() else (yield h)
                else:
                    hs = [pending.pop(b) for b in waits]
                    if all(h.test() for h in hs):
                        for b, h in zip(waits, hs):
                            env[b] = h.result
                    else:
                        vals = yield hs
                        for b, v in zip(waits, vals):
                            env[b] = v
                rounds += 1
                if _trc.TRACING:
                    _trc.TRACER.instant("collective", "round", rank=rank,
                                        step=rounds, waits=len(waits))
            action(env, pending, key)
        tail = plan.tail
        if tail:
            if len(tail) == 1:
                h = pending.pop(tail[0])
                env[tail[0]] = h.result if h.test() else (yield h)
            else:
                hs = [pending.pop(b) for b in tail]
                if all(h.test() for h in hs):
                    for b, h in zip(tail, hs):
                        env[b] = h.result
                else:
                    vals = yield hs
                    for b, v in zip(tail, vals):
                        env[b] = v
            if _trc.TRACING:
                _trc.TRACER.instant("collective", "round", rank=rank,
                                    step=rounds + 1, waits=len(tail))
        finish = self._finish
        return None if finish is None else finish(env, shape, rank)


# ---------------------------------------------------------------------------
# The plan cache.
# ---------------------------------------------------------------------------
CACHE_MAX = 256

_cache: Dict[Tuple[int, int, int, Any, Any], CompiledProgram] = {}
_cache_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0, "evictions": 0}


class StaleProgramError(RuntimeError):
    """A compiled program outlived its communicator epoch.

    Raised by :meth:`CompiledProgram.gen` when the communicator was
    revoked or lost a rank after the program was compiled; the holder
    must recompile (``compile_schedule`` with the bumped epoch in its key
    returns a fresh program).  Persistent wrappers
    (:class:`repro.core.collectives.PersistentCollective`,
    :class:`repro.core.collectives.HaloExchange`) do this automatically.
    """


def epoch_of(comm) -> int:
    """The communicator's failure epoch (0 for epoch-less communicators).

    :class:`repro.core.tac.CommWorld` bumps its ``epoch`` on every
    ``fail_rank``/``revoke``; a :class:`~repro.core.tac.CommGroup`
    inherits the parent world's.  The epoch is part of the plan-cache
    key, so a failure invalidates every cached plan over the affected
    communicator without any explicit flush.
    """
    return getattr(comm, "epoch", 0)


def compile_schedule(sched: Schedule, comm, *, op: Optional[Callable] = None,
                     head: Tuple[Any, ...] = ("prog",)) -> CompiledProgram:
    """The cached entry point: one :class:`CompiledProgram` per
    (schedule identity, communicator identity, communicator epoch, op,
    tag family).

    ``op`` must be the *resolved* combine callable (``_op_fn`` output) —
    named ops resolve to shared module-level functions, so ``"sum"``
    callers share an entry.  Insertion order doubles as the FIFO eviction
    order beyond :data:`CACHE_MAX`; entries pin their schedule and
    communicator (see module docstring on identity keying).  The epoch
    term (:func:`epoch_of`) makes failure recovery automatic: after a
    ``fail_rank``/``revoke`` the old entries are unreachable and the
    first caller compiles a fresh plan.
    """
    key = (id(sched), id(comm), epoch_of(comm), op, head)
    with _cache_lock:
        prog = _cache.get(key)
        if prog is not None:
            _stats["hits"] += 1
            return prog
    prog = CompiledProgram(sched, comm, op=op, head=head)
    with _cache_lock:
        cached = _cache.setdefault(key, prog)
        if cached is prog:
            _stats["misses"] += 1
            while len(_cache) > CACHE_MAX:
                _cache.pop(next(iter(_cache)))
                _stats["evictions"] += 1
        else:
            _stats["hits"] += 1
    return cached


def cache_stats() -> Dict[str, int]:
    """Snapshot of plan-cache counters (plus current ``size``)."""
    with _cache_lock:
        out = dict(_stats)
        out["size"] = len(_cache)
    return out


def clear_cache() -> None:
    """Drop every cached program (tests; releases pinned communicators)."""
    with _cache_lock:
        _cache.clear()
        for k in _stats:
            _stats[k] = 0
