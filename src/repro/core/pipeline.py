"""Pipeline parallelism: GPipe-style microbatch schedule over a stage axis.

The Level-B catalogue's remaining parallelism letter.  Stages are laid out
over a mesh axis; activations travel stage→stage via
``jax.lax.ppermute`` inside ``shard_map`` (manual over the stage axis).
The schedule is the classic GPipe ladder: with S stages and M microbatches
the loop runs M+S−1 ticks; stage s computes microbatch t−s at tick t.
Bubble fraction = (S−1)/(M+S−1) — reported by :func:`bubble_fraction` so
the trade-off is visible in benchmarks.

The production (pod, data, model) mesh does not carry a stage axis — PP is
exercised on custom meshes (``tests/test_pipeline.py`` uses (stage=4,)) and
composes with the other axes through ``shard_map``'s ``axis_names``.
Communication pattern: one ``collective-permute`` per tick — the paper's
Level-B story again: the permutes carry no false dependencies, so
consecutive ticks' sends overlap the next microbatch's compute under XLA's
scheduler.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, *,
                   mesh, stage_axis: str = "stage") -> jax.Array:
    """Run ``stage_fn`` as a pipeline over ``stage_axis``.

    stage_params: pytree with a leading stage dimension (sharded over
    ``stage_axis``); ``x``: (M, ...) microbatched inputs (replicated).
    Returns (M, ...) outputs of the final stage (replicated).
    """
    S = mesh.shape[stage_axis]
    M = x.shape[0]

    def local(params_local, x_all):
        # params_local: this stage's slice (leading dim 1) — squeeze it.
        params_here = jax.tree_util.tree_map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        n_ticks = M + S - 1
        fwd_perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            inbuf, outbuf = carry
            m = t - sid                       # microbatch index at my stage
            valid = (m >= 0) & (m < M)
            mb = jnp.clip(m, 0, M - 1)
            # stage 0 reads the raw microbatch; others read the permuted buf
            x_in = jnp.where(sid == 0, x_all[mb], inbuf)
            y = stage_fn(params_here, x_in)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage records its finished microbatch
            record = valid & (sid == S - 1)
            outbuf = jax.lax.dynamic_update_index_in_dim(
                outbuf, jnp.where(record, y, outbuf[mb]), mb, 0)
            # everyone ships their activation to the next stage
            nxt = jax.lax.ppermute(y, stage_axis, fwd_perm)
            return (nxt, outbuf), None

        inbuf0 = jnp.zeros_like(x_all[0])
        outbuf0 = jnp.zeros_like(x_all)
        (_, outbuf), _ = jax.lax.scan(
            tick, (inbuf0, outbuf0), jnp.arange(n_ticks))
        # replicate the last stage's collected outputs to every stage
        mask = (jax.lax.axis_index(stage_axis) == S - 1).astype(outbuf.dtype)
        return jax.lax.psum(outbuf * mask, stage_axis)

    f = shard_map(
        local, mesh=mesh,
        in_specs=(P(stage_axis), P()), out_specs=P(),
        axis_names={stage_axis}, check_vma=False)
    return f(stage_params, x)
