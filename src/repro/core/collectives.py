"""Task-aware collectives layered on CommWorld point-to-point (paper §6,
extended to collectives).

The paper applies the pause/resume and external-events APIs to MPI
point-to-point; follow-on work (*Callback-based Completion Notification
using MPI Continuations*; *MPI Progress For All*) shows the same two modes
extend naturally to collectives when completion is driven by a
progress/notification engine instead of per-call blocking.  This module
implements that design for the host runtime:

* Every collective is expressed as a *schedule of point-to-point rounds*
  over a communicator — a :class:`~repro.core.tac.CommWorld` or any
  :class:`~repro.core.tac.CommGroup` sub-communicator (``world.group``,
  the collective ``world.split``, Cartesian ``world.cart_create``); a
  group's namespaced tag context keeps concurrent collectives on
  disjoint groups, or on a group and its parent, isolated.  A schedule
  is a Python generator that posts ``isend``s and yields the ``irecv``
  handles it needs completed before the next round.  Two algorithm
  families are provided per collective:

  - ``ring``      — neighbour rounds (ring/chain/pairwise): ``n-1`` steps,
                    bandwidth-optimal for large payloads.
  - ``doubling``  — logarithmic schedules (recursive doubling /
                    dissemination / binomial tree / Bruck): ``⌈log2 n⌉``
                    steps, latency-optimal for small payloads.  Non-power-
                    of-two rank counts are handled by folding (reductions)
                    or by the Bruck construction (gathers/all-to-all),
                    which works for any ``n`` directly.

* Each collective runs in one of the paper's two interoperability modes:

  - ``mode="blocking"`` (§6.1): the call returns the rank's result; inside
    a task the rounds are advanced by the progress engine and the task
    pays a *single* test → register ticket → pause on the completion
    handle (one pause per collective, not per round — per-round pausing
    would deadlock help-first nested blocking, whose LIFO stacks cannot
    interleave two in-flight multi-round schedules).  Outside a task (or
    without ``TASK_MULTIPLE``) the schedule is driven inline with plain
    OS-level waits, exactly like the point-to-point wrappers.

  - ``mode="event"`` (§6.2): the call returns a
    :class:`CollectiveHandle` *immediately* and binds one external event
    to the calling task.  The remaining rounds are advanced by a
    :class:`ProgressEngine` registered as a polling service — the
    continuation/progress-engine design of the follow-on papers: no live
    stack, no context switch, sends of later rounds are posted by the
    polling thread as their inputs arrive.  The task's dependencies are
    released only when the collective completes; successors read
    ``handle.result``.

Determinism: within one collective all ranks apply the combining operator
in matching order, so every rank finishes with a bitwise-identical result
(for commutative IEEE ops like add/max).  Tag space is isolated per call —
either through the per-rank call sequence (MPI's "same order on every
rank" rule) or an explicit ``key`` for programs whose task schedulers may
reorder independent collectives.

Beyond the seven world-wide collectives this module provides the
*neighbourhood* layer over Cartesian groups —
:meth:`Collectives.neighbor_alltoall` and the persistent
:class:`HaloExchange` — and :class:`HierarchicalCollectives`, an
allreduce over two nested sub-groups.  All families share the same
schedule machinery, progress engine and interoperability modes.
"""

from __future__ import annotations

import itertools
import math
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from . import tac
from .events import (current_task, get_current_event_counter,
                     increase_current_task_event_counter,
                     decrease_task_event_counter)

__all__ = ["Collectives", "CollectiveHandle", "ProgressEngine", "n_rounds",
           "HaloExchange", "HierarchicalCollectives",
           "ALGORITHMS", "MODES"]

ALGORITHMS = ("ring", "doubling")
MODES = ("blocking", "event")

_OPS: Dict[str, Callable] = {"sum": np.add, "prod": np.multiply,
                             "max": np.maximum, "min": np.minimum}

_ALG_ALIASES = {"ring": "ring", "chain": "ring", "pairwise": "ring",
                "doubling": "doubling", "recursive-doubling": "doubling",
                "rd": "doubling", "tree": "doubling", "bruck": "doubling",
                "dissemination": "doubling"}
_MODE_ALIASES = {"blocking": "blocking", "wait": "blocking",
                 "event": "event", "iwait": "event",
                 "nonblocking": "event", "non-blocking": "event"}


def _op_fn(op) -> Callable:
    if callable(op):
        return op
    try:
        return _OPS[op]
    except KeyError:
        raise ValueError(f"unknown reduction op {op!r}; "
                         f"use one of {sorted(_OPS)} or a callable")


def _norm_alg(algorithm: str) -> str:
    try:
        return _ALG_ALIASES[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; "
                         f"aliases: {sorted(_ALG_ALIASES)}")


def _norm_mode(mode: str) -> str:
    try:
        return _MODE_ALIASES[mode]
    except KeyError:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"aliases: {sorted(_MODE_ALIASES)}")


def n_rounds(name: str, algorithm: str, size: int) -> int:
    """Message rounds on the critical path — the simulator's latency model."""
    if size <= 1:
        return 0
    alg = _norm_alg(algorithm)
    log2_ceil = max(1, math.ceil(math.log2(size)))
    if alg == "doubling":
        # Reductions butterfly over 2^⌊log2 n⌋ after folding the remainder
        # ranks (+1 fold and +1 unfold round when n is not a power of two).
        butterfly = size.bit_length() - 1
        extra = 0 if size & (size - 1) == 0 else 2
        return {"allreduce": butterfly + extra,
                "reduce_scatter": butterfly + extra,
                "reduce": log2_ceil, "bcast": log2_ceil,
                "barrier": log2_ceil, "allgather": log2_ceil,
                "alltoall": log2_ceil}[name]
    return {"allreduce": 2 * (size - 1)}.get(name, size - 1)


class CollectiveHandle(tac.EventHandle):
    """Completion handle of an event-bound collective (result at release).

    A schedule failure (bad payloads, a raising ``op``...) completes the
    handle with the exception stored; ``result`` re-raises it on whichever
    thread consumes the collective, so errors surface instead of killing
    the polling service or hanging ``taskwait``.
    """

    def __init__(self) -> None:
        super().__init__()
        self.error: Optional[BaseException] = None

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self.complete(None)

    @property
    def result(self) -> Any:
        if self.error is not None:
            raise self.error
        return self._result


# ---------------------------------------------------------------------------
# Generator-driven state machines + progress engine
# ---------------------------------------------------------------------------
class _Machine:
    """One rank's collective schedule, advanced as its handles complete.

    The generator yields the handle (or list of handles) it waits on; the
    driver sends the received payload(s) back in.  ``advance`` is *not*
    re-entrant: callers must ensure one thread at a time (the progress
    engine serialises via the polling registry's per-service lock; the
    group driver is single-threaded).
    """

    __slots__ = ("gen", "handle", "counter", "steps", "done", "_waiting",
                 "_started")

    def __init__(self, gen, handle: CollectiveHandle,
                 counter=None) -> None:
        self.gen = gen
        self.handle = handle
        self.counter = counter
        self.steps = 0          # resolved waits — progress indicator
        self.done = False
        self._waiting: Any = None
        self._started = False

    def advance(self) -> bool:
        """Run until the next incomplete wait; True once finished."""
        if self.done:
            return True
        try:
            if not self._started:
                self._started = True
                self._waiting = next(self.gen)
            while True:
                w = self._waiting
                many = isinstance(w, (list, tuple))
                hs = list(w) if many else [w]
                if not all(h.test() for h in hs):
                    return False
                res = [h.result for h in hs] if many else hs[0].result
                self.steps += 1
                self._waiting = self.gen.send(res)
        except StopIteration as stop:
            self.done = True
            self.handle.complete(stop.value)
            if self.counter is not None:
                decrease_task_event_counter(self.counter, 1)
            return True
        except BaseException as exc:  # noqa: BLE001 - surfaced via handle
            # A raising schedule must not kill the polling thread or leave
            # the task's event counter bound forever — fail the handle
            # (consumers re-raise) and release the dependency.
            self.done = True
            self.handle.fail(exc)
            if self.counter is not None:
                decrease_task_event_counter(self.counter, 1)
            return True


class ProgressEngine:
    """Drains event-bound collective machines from the polling service.

    The notification engine of the follow-on papers: completion is detected
    and *continued* (next rounds posted, results combined, dependencies
    released) by the runtime's polling threads, never by a blocked caller.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._machines: List[_Machine] = []

    def submit(self, machine: _Machine) -> None:
        # First advance on the caller's thread (posts the initial sends);
        # the machine only becomes visible to the poller if still pending,
        # so `advance` never runs concurrently.
        if machine.advance():
            return
        with self._lock:
            self._machines.append(machine)

    def poll(self, _data: Any) -> bool:
        with self._lock:
            snapshot = list(self._machines)
        finished = [m for m in snapshot if m.advance()]
        if finished:
            with self._lock:
                self._machines = [m for m in self._machines
                                  if m not in finished]
        return False  # stay registered

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._machines)


def _engine(runtime) -> ProgressEngine:
    eng = getattr(runtime, "_coll_engine", None)
    if eng is None:
        with runtime._lock:
            eng = getattr(runtime, "_coll_engine", None)
            if eng is None:
                eng = ProgressEngine()
                runtime.polling.register_polling_service(
                    "collective progress engine", eng.poll, None)
                runtime._coll_engine = eng  # type: ignore[attr-defined]
    return eng


def _drive_blocking(gen):
    """Drive a schedule with task-aware waits (pause/resume per round)."""
    try:
        w = next(gen)
        while True:
            if isinstance(w, (list, tuple)):
                res = tac.waitall(list(w))
            else:
                res = tac.wait(w)
            w = gen.send(res)
    except StopIteration as stop:
        return stop.value


def _execute_schedule(gen, mode: str):
    """Run one rank's schedule in an interoperability mode (normalized).

    Shared by every collective family (world-wide, neighbourhood,
    hierarchical).  Outside a task (or without TASK_MULTIPLE) the schedule
    is driven inline with OS-level waits — the PMPI path.  Inside a task
    the progress engine advances the rounds from the polling service:
    ``blocking`` pays one pause on the completion handle, ``event`` binds
    the handle to the task's event counter and returns it immediately.
    """
    task = current_task()
    if not (tac.is_enabled() and task is not None):
        result = _drive_blocking(gen)
        if mode == "blocking":
            return result
        handle = CollectiveHandle()
        handle.complete(result)
        return handle
    handle = CollectiveHandle()
    if mode == "blocking":
        _engine(task._runtime).submit(_Machine(gen, handle))
        return tac.wait(handle)
    counter = get_current_event_counter()
    increase_current_task_event_counter(counter, 1)
    _engine(task._runtime).submit(_Machine(gen, handle, counter))
    return handle


def _drive_group(machines: Sequence[_Machine]) -> None:
    """Round-robin all ranks' machines on the calling thread.

    The deterministic single-threaded driver: used by the sequential
    ('pure'/fork-join) benchmark versions and by tests that need a
    collective without a task runtime.  All matching is in-memory and
    eager, so a full pass with zero progress means the schedule itself is
    stuck — reported instead of spinning.
    """
    pending = [m for m in machines if not m.advance()]
    while pending:
        progressed = False
        nxt = []
        for m in pending:
            before = m.steps
            if m.advance() or m.steps != before:
                progressed = True
            if not m.done:
                nxt.append(m)
        if nxt and not progressed:
            # A failed rank stalls its peers (their recvs never match);
            # surface the root cause rather than the symptom.
            for m in machines:
                if m.handle.error is not None:
                    raise m.handle.error
            names = [getattr(m.gen, "__name__", "?") for m in nxt]
            raise RuntimeError(
                f"collective group stalled: {len(nxt)} ranks cannot "
                f"progress ({names}) — mismatched call order or rank set")
        pending = nxt


# ---------------------------------------------------------------------------
# Schedules.  Each generator: posts isends, yields irecv handle(s), receives
# the payload(s) via send(); StopIteration.value is the rank's result.
# ---------------------------------------------------------------------------
def _barrier_dissemination(w: tac.CommWorld, n: int, r: int, tag):
    k, rnd = 1, 0
    while k < n:
        w.isend(True, src=r, dst=(r + k) % n, tag=tag(rnd))
        yield w.irecv(src=(r - k) % n, dst=r, tag=tag(rnd))
        k <<= 1
        rnd += 1
    return None


def _barrier_ring(w: tac.CommWorld, n: int, r: int, tag):
    # n-1 neighbour rounds: afterwards every rank has transitively heard
    # from every other, so none can exit before all have entered.
    for k in range(n - 1):
        w.isend(True, src=r, dst=(r + 1) % n, tag=tag(k))
        yield w.irecv(src=(r - 1) % n, dst=r, tag=tag(k))
    return None


def _bcast_tree(w: tac.CommWorld, n: int, r: int, tag, value, root: int):
    """Binomial-tree broadcast (MPICH-style), any rank count."""
    vr = (r - root) % n
    buf = value
    mask = 1
    while mask < n:
        if vr & mask:
            buf = yield w.irecv(src=(r - mask) % n, dst=r, tag=tag(mask))
            break
        mask <<= 1
    mask >>= 1
    while mask:
        if vr + mask < n:
            w.isend(buf, src=r, dst=(r + mask) % n, tag=tag(mask))
        mask >>= 1
    return buf


def _bcast_chain(w: tac.CommWorld, n: int, r: int, tag, value, root: int):
    vr = (r - root) % n
    buf = value
    if vr > 0:
        buf = yield w.irecv(src=(r - 1) % n, dst=r, tag=tag("c"))
    if vr < n - 1:
        w.isend(buf, src=r, dst=(r + 1) % n, tag=tag("c"))
    return buf


def _reduce_tree(w: tac.CommWorld, n: int, r: int, tag, value, op,
                 root: int):
    """Binomial-tree reduction to ``root`` (commutative ``op``)."""
    vr = (r - root) % n
    acc = value
    mask = 1
    while mask < n:
        if vr & mask:
            w.isend(acc, src=r, dst=(r - mask) % n, tag=tag(mask))
            return None
        partner_vr = vr | mask
        if partner_vr < n:
            other = yield w.irecv(src=(r + mask) % n, dst=r, tag=tag(mask))
            acc = op(acc, other)
        mask <<= 1
    return acc


def _reduce_chain(w: tac.CommWorld, n: int, r: int, tag, value, op,
                  root: int):
    vr = (r - root) % n
    acc = value
    if vr < n - 1:
        other = yield w.irecv(src=(r + 1) % n, dst=r, tag=tag("c"))
        acc = op(acc, other)
    if vr > 0:
        w.isend(acc, src=r, dst=(r - 1) % n, tag=tag("c"))
        return None
    return acc


def _allreduce_ring(w: tac.CommWorld, n: int, r: int, tag, value, op):
    """Ring allreduce: reduce-scatter rounds then allgather rounds."""
    arr = np.asarray(value)
    chunks = list(np.array_split(arr.reshape(-1), n))
    right, left = (r + 1) % n, (r - 1) % n
    for k in range(n - 1):          # reduce-scatter: end owning chunk r
        w.isend(chunks[(r - 1 - k) % n], src=r, dst=right, tag=tag(("s", k)))
        other = yield w.irecv(src=left, dst=r, tag=tag(("s", k)))
        i = (r - 2 - k) % n
        chunks[i] = op(chunks[i], other)
    for k in range(n - 1):          # allgather the reduced chunks
        w.isend(chunks[(r - k) % n], src=r, dst=right, tag=tag(("g", k)))
        other = yield w.irecv(src=left, dst=r, tag=tag(("g", k)))
        chunks[(r - k - 1) % n] = other
    return np.concatenate(chunks).reshape(arr.shape)


def _allreduce_doubling(w: tac.CommWorld, n: int, r: int, tag, value, op):
    """Recursive doubling with the fold/unfold trick for non-power-of-two
    rank counts: the ``rem = n - 2^⌊log2 n⌋`` odd ranks below ``2*rem``
    fold into their even partners, the power-of-two remainder runs the
    butterfly, results are unfolded back."""
    acc = np.asarray(value)
    pow2 = 1 << (n.bit_length() - 1)
    rem = n - pow2
    if r < 2 * rem:
        if r % 2:
            w.isend(acc, src=r, dst=r - 1, tag=tag("fold"))
            result = yield w.irecv(src=r - 1, dst=r, tag=tag("unfold"))
            return result
        other = yield w.irecv(src=r + 1, dst=r, tag=tag("fold"))
        acc = op(acc, other)
        vr = r // 2
    else:
        vr = r - rem
    mask = 1
    while mask < pow2:
        partner_vr = vr ^ mask
        partner = partner_vr * 2 if partner_vr < rem else partner_vr + rem
        w.isend(acc, src=r, dst=partner, tag=tag(("x", mask)))
        other = yield w.irecv(src=partner, dst=r, tag=tag(("x", mask)))
        acc = op(acc, other)
        mask <<= 1
    if r < 2 * rem:
        w.isend(acc, src=r, dst=r + 1, tag=tag("unfold"))
    return acc


def _allgather_ring(w: tac.CommWorld, n: int, r: int, tag, value):
    items: List[Any] = [None] * n
    items[r] = value
    right, left = (r + 1) % n, (r - 1) % n
    for k in range(n - 1):
        w.isend(items[(r - k) % n], src=r, dst=right, tag=tag(k))
        items[(r - k - 1) % n] = yield w.irecv(src=left, dst=r, tag=tag(k))
    return items


def _allgather_bruck(w: tac.CommWorld, n: int, r: int, tag, value):
    """Bruck allgather: ⌈log2 n⌉ rounds, any rank count."""
    acc: List[Any] = [value]
    k = 1
    while k < n:
        cnt = min(k, n - k)
        w.isend(tuple(acc[:cnt]), src=r, dst=(r - k) % n, tag=tag(k))
        got = yield w.irecv(src=(r + k) % n, dst=r, tag=tag(k))
        acc.extend(got)
        k <<= 1
    # acc[j] is rank (r + j) % n's contribution
    return [acc[(i - r) % n] for i in range(n)]


def _reduce_scatter_ring(w: tac.CommWorld, n: int, r: int, tag, value, op):
    chunks = list(np.array_split(np.asarray(value).reshape(-1), n))
    right, left = (r + 1) % n, (r - 1) % n
    for k in range(n - 1):
        w.isend(chunks[(r - 1 - k) % n], src=r, dst=right, tag=tag(k))
        other = yield w.irecv(src=left, dst=r, tag=tag(k))
        i = (r - 2 - k) % n
        chunks[i] = op(chunks[i], other)
    return chunks[r]


def _reduce_scatter_doubling(w: tac.CommWorld, n: int, r: int, tag, value,
                             op):
    # Recursive-halving needs a power-of-two block mapping that clashes
    # with n-way output blocks; run the doubling allreduce and slice — the
    # same logarithmic round structure, trade payload for simplicity.
    full = yield from _allreduce_doubling(w, n, r, tag, value, op)
    return np.array_split(np.asarray(full).reshape(-1), n)[r]


def _alltoall_pairwise(w: tac.CommWorld, n: int, r: int, tag, blocks):
    result: List[Any] = [None] * n
    result[r] = blocks[r]
    for k in range(1, n):
        dst, src = (r + k) % n, (r - k) % n
        w.isend(blocks[dst], src=r, dst=dst, tag=tag(k))
        result[src] = yield w.irecv(src=src, dst=r, tag=tag(k))
    return result


def _alltoall_bruck(w: tac.CommWorld, n: int, r: int, tag, blocks):
    """Bruck all-to-all: rotate, ⌈log2 n⌉ bit-rounds, inverse rotate."""
    tmp = [blocks[(r + j) % n] for j in range(n)]
    k = 1
    while k < n:
        idxs = [j for j in range(n) if j & k]
        w.isend(tuple(tmp[j] for j in idxs), src=r, dst=(r + k) % n,
                tag=tag(k))
        got = yield w.irecv(src=(r - k) % n, dst=r, tag=tag(k))
        for j, g in zip(idxs, got):
            tmp[j] = g
        k <<= 1
    return [tmp[(r - i) % n] for i in range(n)]


def _opp(direction):
    dim, disp = direction
    return (dim, -disp)


def _neighbor_round(comm, rank: int, tag, dirs, sends):
    """One neighbourhood round: isend per outgoing direction, one batched
    wait on the irecvs of all incoming directions.

    ``dirs`` is the rank's persistent neighbour list ``[((dim, ±1),
    neighbour)]``; messages are tagged by their direction of *travel*, so
    the sender in direction ``d`` matches the receiver expecting traffic
    from its ``-d`` neighbour.  Returns ``{direction: payload received
    from the neighbour in that direction}``.
    """
    for d, nbr in dirs:
        comm.isend(sends[d], src=rank, dst=nbr, tag=tag(("n", d)))
    handles = [comm.irecv(src=nbr, dst=rank, tag=tag(("n", _opp(d))))
               for d, nbr in dirs]
    got = yield handles
    return {d: v for (d, _), v in zip(dirs, got)}


# Per-op default algorithm, shared by the per-rank methods and run_group:
# latency-optimal doubling for the rooted/small ops, bandwidth-optimal ring
# for the bulk ones.
_DEFAULT_ALGORITHM = {
    "barrier": "doubling", "bcast": "doubling", "reduce": "doubling",
    "allreduce": "ring", "allgather": "ring", "reduce_scatter": "ring",
    "alltoall": "ring",
}

_SCHEDULES = {
    ("barrier", "doubling"): _barrier_dissemination,
    ("barrier", "ring"): _barrier_ring,
    ("bcast", "doubling"): _bcast_tree,
    ("bcast", "ring"): _bcast_chain,
    ("reduce", "doubling"): _reduce_tree,
    ("reduce", "ring"): _reduce_chain,
    ("allreduce", "doubling"): _allreduce_doubling,
    ("allreduce", "ring"): _allreduce_ring,
    ("allgather", "doubling"): _allgather_bruck,
    ("allgather", "ring"): _allgather_ring,
    ("reduce_scatter", "doubling"): _reduce_scatter_doubling,
    ("reduce_scatter", "ring"): _reduce_scatter_ring,
    ("alltoall", "doubling"): _alltoall_bruck,
    ("alltoall", "ring"): _alltoall_pairwise,
}


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
class Collectives:
    """Collective operations over a communicator.

    The communicator may be a :class:`tac.CommWorld` or any
    :class:`tac.CommGroup` (``world.group(...)``, ``world.split(...)``,
    ``world.cart_create(...)``): ranks are communicator-local and a
    group's tag namespace keeps concurrent collectives on disjoint
    sub-groups — or on a group and its parent world — fully isolated.

    Every rank participating in a collective calls the same method (from
    its own task or thread).  Tag isolation follows MPI's rule — each rank
    must issue its collectives in the same order — via per-rank sequence
    counters; programs whose schedulers may reorder *independent*
    collectives pass an explicit ``key`` instead (any hashable, identical
    on all ranks of one collective).

    ``mode="blocking"`` returns the rank's result (pausing the task per
    round); ``mode="event"`` returns a :class:`CollectiveHandle` bound to
    the calling task's event counter — consume ``handle.result`` from a
    successor task.
    """

    def __init__(self, comm) -> None:
        self.comm = comm
        self.world = comm   # historical alias (pre-sub-communicator name)
        self._seq = [itertools.count() for _ in range(comm.size)]

    # -- plumbing ----------------------------------------------------------
    def _tagger(self, name: str, rank: int, key: Any):
        if key is None:
            key = next(self._seq[rank])
        def tag(sub: Any):
            return ("coll", name, key, sub)
        return tag

    def _schedule(self, name: str, algorithm: str, rank: int, key: Any,
                  *args):
        n = self.world.size
        if not 0 <= rank < n:
            raise ValueError(f"rank {rank} out of range for size {n}")
        fn = _SCHEDULES[(name, _norm_alg(algorithm))]
        return fn(self.world, n, rank, self._tagger(name, rank, key), *args)

    def _run(self, name: str, algorithm: Optional[str], rank: int,
             key: Any, mode: str, *args):
        # Normalize/validate EVERYTHING before _schedule consumes the
        # per-rank tag sequence — a rejected call must not desynchronize
        # this rank's subsequent keyless collectives from its peers.
        mode = _norm_mode(mode)
        algorithm = algorithm or _DEFAULT_ALGORITHM[name]
        return _execute_schedule(
            self._schedule(name, algorithm, rank, key, *args), mode)

    # -- the seven collectives ---------------------------------------------
    # algorithm=None picks the per-op default from _DEFAULT_ALGORITHM
    # (latency-optimal doubling for the rooted/small ops, bandwidth-optimal
    # ring for the bulk ones) — shared with run_group so the two entry
    # points can never drift apart.
    def barrier(self, *, rank: int, algorithm: Optional[str] = None,
                mode: str = "blocking", key: Any = None):
        return self._run("barrier", algorithm, rank, key, mode)

    def bcast(self, value: Any = None, *, rank: int, root: int = 0,
              algorithm: Optional[str] = None, mode: str = "blocking",
              key: Any = None):
        return self._run("bcast", algorithm, rank, key, mode, value, root)

    def reduce(self, value: Any, *, rank: int, op="sum", root: int = 0,
               algorithm: Optional[str] = None, mode: str = "blocking",
               key: Any = None):
        return self._run("reduce", algorithm, rank, key, mode,
                         np.asarray(value), _op_fn(op), root)

    def allreduce(self, value: Any, *, rank: int, op="sum",
                  algorithm: Optional[str] = None, mode: str = "blocking",
                  key: Any = None):
        return self._run("allreduce", algorithm, rank, key, mode,
                         np.asarray(value), _op_fn(op))

    def allgather(self, value: Any, *, rank: int,
                  algorithm: Optional[str] = None, mode: str = "blocking",
                  key: Any = None):
        """Returns the list of every rank's contribution, rank order."""
        return self._run("allgather", algorithm, rank, key, mode, value)

    def reduce_scatter(self, value: Any, *, rank: int, op="sum",
                       algorithm: Optional[str] = None,
                       mode: str = "blocking", key: Any = None):
        """Returns this rank's ``np.array_split`` chunk of the flattened
        element-wise reduction."""
        return self._run("reduce_scatter", algorithm, rank, key, mode,
                         np.asarray(value), _op_fn(op))

    def alltoall(self, blocks: Sequence[Any], *, rank: int,
                 algorithm: Optional[str] = None, mode: str = "blocking",
                 key: Any = None):
        """``blocks[d]`` goes to rank ``d``; returns blocks received,
        indexed by source rank."""
        blocks = list(blocks)
        if len(blocks) != self.world.size:
            raise ValueError(f"alltoall needs exactly {self.world.size} "
                             f"blocks, got {len(blocks)}")
        return self._run("alltoall", algorithm, rank, key, mode, blocks)

    # -- neighbourhood collectives (Cartesian communicators) ---------------
    def neighbor_alltoall(self, sends: Dict[Any, Any], *, rank: int,
                          mode: str = "blocking", key: Any = None):
        """Neighbourhood all-to-all (MPI_Neighbor_alltoall).

        Requires a communicator with a Cartesian topology
        (``CommWorld.cart_create``).  ``sends`` maps each of this rank's
        neighbour directions ``(dim, ±1)`` to the payload for the
        neighbour in that direction; the result maps each direction to
        the payload received *from* that neighbour.  Boundary ranks of a
        non-periodic grid simply have fewer directions.
        """
        mode = _norm_mode(mode)
        dirs = _topology_dirs(self.comm, rank)
        sends = _check_dir_payloads(sends, dirs)
        gen = _neighbor_round(self.comm, rank,
                              self._tagger("neighbor_alltoall", rank, key),
                              dirs, sends)
        return _execute_schedule(gen, mode)

    # -- single-threaded group driver --------------------------------------
    def run_group(self, name: str, per_rank: Sequence[Dict[str, Any]],
                  **common: Any) -> List[Any]:
        """Run one collective for ALL ranks round-robin on this thread.

        The sequential ('pure'/fork-join) execution path and the
        deterministic test driver: no runtime, no threads, no pausing.
        ``per_rank[r]`` holds rank-specific kwargs (e.g. ``value``);
        ``common`` the shared ones (``op``, ``algorithm``, ``key``...).
        Returns the per-rank results in rank order.
        """
        if len(per_rank) != self.world.size:
            raise ValueError(f"need kwargs for all {self.world.size} ranks")
        machines = []
        for r, kw in enumerate(per_rank):
            gen = self._make_gen(name, rank=r, **dict(common, **kw))
            machines.append(_Machine(gen, CollectiveHandle()))
        _drive_group(machines)
        return [m.handle.result for m in machines]

    _GROUP_SPEC = {
        # name -> (accepted kwargs, required kwargs)
        "barrier": (set(), set()),
        "bcast": ({"value", "root"}, set()),
        "reduce": ({"value", "op", "root"}, {"value"}),
        "allreduce": ({"value", "op"}, {"value"}),
        "allgather": ({"value"}, {"value"}),
        "reduce_scatter": ({"value", "op"}, {"value"}),
        "alltoall": ({"blocks"}, {"blocks"}),
    }

    def _make_gen(self, name: str, *, rank: int,
                  algorithm: Optional[str] = None, key: Any = None, **kw):
        if name not in self._GROUP_SPEC:
            raise ValueError(f"unknown collective {name!r}; "
                             f"one of {sorted(self._GROUP_SPEC)}")
        accepted, required = self._GROUP_SPEC[name]
        unknown = set(kw) - accepted
        if unknown:
            # `mode` lands here too: run_group drives all ranks inline.
            raise ValueError(
                f"{name}: unexpected argument(s) {sorted(unknown)}; "
                f"accepted: {sorted(accepted | {'algorithm', 'key'})}")
        missing = required - set(kw)
        if missing:
            raise ValueError(f"{name}: missing argument(s) "
                             f"{sorted(missing)}")
        algorithm = algorithm or _DEFAULT_ALGORITHM[name]
        if name == "barrier":
            return self._schedule(name, algorithm, rank, key)
        if name == "bcast":
            return self._schedule(name, algorithm, rank, key,
                                  kw.get("value"), kw.get("root", 0))
        if name == "reduce":
            return self._schedule(name, algorithm, rank, key,
                                  np.asarray(kw["value"]),
                                  _op_fn(kw.get("op", "sum")),
                                  kw.get("root", 0))
        if name in ("allreduce", "reduce_scatter"):
            return self._schedule(name, algorithm, rank, key,
                                  np.asarray(kw["value"]),
                                  _op_fn(kw.get("op", "sum")))
        if name == "allgather":
            return self._schedule(name, algorithm, rank, key, kw["value"])
        blocks = list(kw["blocks"])
        if len(blocks) != self.world.size:
            raise ValueError("alltoall block count != world size")
        return self._schedule(name, algorithm, rank, key, blocks)


# ---------------------------------------------------------------------------
# Neighbourhood collectives: persistent halo exchange
# ---------------------------------------------------------------------------
def _topology_dirs(comm, rank: int):
    neighbor_dirs = getattr(comm, "neighbor_dirs", None)
    if neighbor_dirs is None:
        raise TypeError(
            "neighbourhood collectives need a communicator with a "
            "Cartesian topology — build one with CommWorld.cart_create")
    return tuple(neighbor_dirs(rank))


def _check_dir_payloads(sends, dirs):
    sends = dict(sends)
    expected = {d for d, _ in dirs}
    if set(sends) != expected:
        raise ValueError(
            f"send payloads must cover exactly this rank's neighbour "
            f"directions {sorted(expected)}, got {sorted(sends)}")
    return sends


_HALO_IDS = itertools.count()


class HaloExchange:
    """Persistent halo exchange over a Cartesian group (paper §7.1 pattern).

    The neighbourhood analogue of MPI's persistent collectives: the
    per-rank neighbour lists — one ``(dim, ±1)`` direction per grid edge,
    from :meth:`tac.CartGroup.neighbor_dirs` — are computed once at
    construction.  Each :meth:`start` then posts one ``isend`` per
    outgoing direction and one ``irecv`` per incoming direction through
    the communicator and runs the round in either interoperability mode:

    * ``mode="blocking"`` (§6.1) returns ``{direction: halo received from
      that neighbour}``; inside a task the wait pauses (one pause, rounds
      driven by the progress engine).
    * ``mode="event"`` (§6.2, the default — halo exchange exists to be
      overlapped) returns a :class:`CollectiveHandle` immediately and
      binds one event to the calling task; interior compute proceeds
      while the halos fly, boundary compute declares a dependency and
      reads ``handle.result``.

    Stencil codes call one ``start`` per rank per iteration; the implicit
    per-rank sequence numbers keep iterations' tag spaces apart (or pass
    ``key=iteration``).
    """

    def __init__(self, cart) -> None:
        self.cart = cart
        self.dirs = {r: _topology_dirs(cart, r) for r in range(cart.size)}
        self._seq = [itertools.count() for _ in range(cart.size)]
        self._id = next(_HALO_IDS)

    def neighbors(self, rank: int):
        """The persistent neighbour list ``[((dim, ±1), neighbour)]``."""
        return self.dirs[rank]

    def _tagger(self, rank: int, key: Any):
        if key is None:
            key = next(self._seq[rank])

        def tag(sub: Any):
            return ("halo", self._id, key, sub)
        return tag

    def _schedule(self, rank: int, key: Any, sends):
        dirs = self.dirs[rank]
        sends = _check_dir_payloads(sends, dirs)
        return _neighbor_round(self.cart, rank, self._tagger(rank, key),
                               dirs, sends)

    def start(self, sends: Dict[Any, Any], *, rank: int,
              mode: str = "event", key: Any = None):
        """Post this rank's halo round; see the class docstring for modes."""
        mode = _norm_mode(mode)
        return _execute_schedule(self._schedule(rank, key, sends), mode)

    def exchange(self, sends: Dict[Any, Any], *, rank: int,
                 key: Any = None):
        """Blocking convenience: ``start(..., mode="blocking")``."""
        return self.start(sends, rank=rank, mode="blocking", key=key)

    def run_group(self, per_rank_sends: Sequence[Dict[Any, Any]],
                  key: Any = None) -> List[Dict[Any, Any]]:
        """All ranks' rounds round-robin on the calling thread — the
        sequential ('pure'/fork-join) path and the deterministic test
        driver.  Returns the per-rank received-halo dicts."""
        if len(per_rank_sends) != self.cart.size:
            raise ValueError(f"need send dicts for all {self.cart.size} "
                             f"ranks")
        machines = [_Machine(self._schedule(r, key, s), CollectiveHandle())
                    for r, s in enumerate(per_rank_sends)]
        _drive_group(machines)
        return [m.handle.result for m in machines]


# ---------------------------------------------------------------------------
# Hierarchical allreduce over nested sub-communicators
# ---------------------------------------------------------------------------
class HierarchicalCollectives:
    """Hierarchical allreduce via two nested groups (ROADMAP item).

    The first consumer of :meth:`tac.CommWorld.split`: construction runs
    the split collective — consecutive ranks share ``color = rank //
    group_size`` — and gathers the per-color *intra* groups plus a
    *leaders* group of each color's rank 0.  An allreduce is then the
    classic fat-node shape:

    1. chain-reduce to the local leader inside each intra group (the ring
       family — bandwidth-optimal within a "node"),
    2. recursive-doubling allreduce across the leaders (latency-optimal
       across "nodes", any leader count),
    3. chain-broadcast back down each intra group.

    Works for any world size and ``group_size`` (the last group may be
    smaller).  Both interoperability modes are supported per rank, same
    contract as :class:`Collectives`.
    """

    def __init__(self, world: tac.CommWorld, group_size: int) -> None:
        if group_size <= 0:
            raise ValueError(f"group_size must be positive, got "
                             f"{group_size}")
        handles = [world.split(r // group_size, key=r, rank=r)
                   for r in range(world.size)]
        self.world = world
        self.group_size = group_size
        self.intra: List[tac.CommGroup] = [h.result for h in handles]
        leader_ranks = sorted({g.world_rank(0) for g in self.intra})
        self.leaders = world.group(leader_ranks)
        self._seq = [itertools.count() for _ in range(world.size)]

    def _schedule(self, rank: int, key: Any, value, op):
        intra = self.intra[rank]
        lr = intra.group_rank(rank)
        if key is None:
            key = next(self._seq[rank])

        def tag(stage):
            return lambda sub: ("hier", key, stage, sub)

        def gen():
            acc = yield from _reduce_chain(intra, intra.size, lr,
                                           tag("reduce"), np.asarray(value),
                                           op, 0)
            if lr == 0:
                li = self.leaders.group_rank(rank)
                acc = yield from _allreduce_doubling(
                    self.leaders, self.leaders.size, li, tag("leaders"),
                    acc, op)
            result = yield from _bcast_chain(intra, intra.size, lr,
                                             tag("bcast"), acc, 0)
            return result
        return gen()

    def allreduce(self, value, *, rank: int, op="sum",
                  mode: str = "blocking", key: Any = None):
        mode = _norm_mode(mode)
        op = _op_fn(op)
        if not 0 <= rank < self.world.size:
            raise ValueError(f"rank {rank} out of range for size "
                             f"{self.world.size}")
        return _execute_schedule(self._schedule(rank, key, value, op), mode)

    def run_group(self, values: Sequence[Any], *, op="sum",
                  key: Any = None) -> List[Any]:
        """Sequential driver: all ranks round-robin on this thread."""
        if len(values) != self.world.size:
            raise ValueError(f"need values for all {self.world.size} ranks")
        op = _op_fn(op)
        machines = [_Machine(self._schedule(r, key, v, op),
                             CollectiveHandle())
                    for r, v in enumerate(values)]
        _drive_group(machines)
        return [m.handle.result for m in machines]

    def n_rounds(self) -> int:
        """Critical-path rounds: intra chain-reduce + leader doubling +
        intra chain-broadcast (the simulator's latency model)."""
        deepest = max(g.size for g in self.intra)
        return (2 * (deepest - 1)
                + n_rounds("allreduce", "doubling", self.leaders.size))
