"""Task-aware collectives layered on CommWorld point-to-point (paper §6,
extended to collectives).

The paper applies the pause/resume and external-events APIs to MPI
point-to-point; follow-on work (*Callback-based Completion Notification
using MPI Continuations*; *MPI Progress For All*) shows the same two modes
extend naturally to collectives when completion is driven by a
progress/notification engine instead of per-call blocking.  This module
implements that design for the host runtime:

* Every collective is described ONCE as **data** — a
  :class:`repro.core.schedule.Schedule`, a DAG of
  ``Send``/``Recv``/``Combine``/``Slice``... ops over abstract
  communicator-local ranks (see :mod:`repro.core.schedule`).  This module
  is the schedule IR's **Level-A executor**: :func:`_interpret` walks one
  rank's program, posting ``isend``/``irecv`` through the communicator —
  a :class:`~repro.core.tac.CommWorld` or any
  :class:`~repro.core.tac.CommGroup` sub-communicator — and yielding the
  handles it must wait on.  The in-graph **Level-B executor** for the
  same IR is :mod:`repro.core.lowering`.  Two algorithm families are
  provided per collective:

  - ``ring``      — neighbour rounds (ring/chain/pairwise): ``n-1`` steps,
                    bandwidth-optimal for large payloads.
  - ``doubling``  — logarithmic schedules (recursive doubling /
                    dissemination / binomial tree / Bruck): ``⌈log2 n⌉``
                    steps, latency-optimal for small payloads.  Non-power-
                    of-two rank counts are handled by folding (reductions)
                    or by the Bruck construction (gathers/all-to-all),
                    which works for any ``n`` directly.
  - ``"auto"``    — pick by minimum predicted α-β cost
                    (:func:`repro.core.schedule.best_schedule`) for the
                    actual payload size, including the segment count of
                    the pipelined ring allreduce.

* Each collective runs in one of the paper's two interoperability modes:

  - ``mode="blocking"`` (§6.1): the call returns the rank's result; inside
    a task the rounds are advanced by the progress engine and the task
    pays a *single* test → register ticket → pause on the completion
    handle (one pause per collective, not per round — per-round pausing
    would deadlock help-first nested blocking, whose LIFO stacks cannot
    interleave two in-flight multi-round schedules).  Outside a task (or
    without ``TASK_MULTIPLE``) the schedule is driven inline with plain
    OS-level waits, exactly like the point-to-point wrappers.

  - ``mode="event"`` (§6.2): the call returns a
    :class:`CollectiveHandle` *immediately* and binds one external event
    to the calling task.  The remaining rounds are advanced by a
    :class:`ProgressEngine` registered as a polling service — the
    continuation/progress-engine design of the follow-on papers: no live
    stack, no context switch, sends of later rounds are posted by the
    polling thread as their inputs arrive.  The task's dependencies are
    released only when the collective completes; successors read
    ``handle.result``.

Determinism: the combine operand order is part of the schedule, so every
rank applies the operator in matching order and finishes with a bitwise-
identical result (for commutative IEEE ops like add/max).  Tag space is
isolated per call — either through the per-rank call sequence (MPI's
"same order on every rank" rule) or an explicit ``key`` for programs
whose task schedulers may reorder independent collectives.

Beyond the seven world-wide collectives this module provides the
*neighbourhood* layer over Cartesian groups —
:meth:`Collectives.neighbor_alltoall` and the persistent
:class:`HaloExchange` — :class:`HierarchicalCollectives` (an allreduce
composed from three schedules over two nested sub-groups), and
**persistent collectives** (:meth:`Collectives.persistent`, the
``MPI_Allreduce_init`` analogue): since schedules are data, a pre-built
handle can be re-posted every iteration with a fresh tag space.
"""

from __future__ import annotations

import itertools
import math
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..obs import trace as _trc
from . import tac
from . import program as program_ir
from . import schedule as schedule_ir
from .options import CollectiveOptions, renamed_kwarg
from .program import bind_inputs as _bind_inputs
from .schedule import (Combine, Concat, Const, Copy, Pack, Recv, Schedule,
                       Send, Slice, Unpack)
from .events import (current_task, get_current_event_counter,
                     increase_current_task_event_counter,
                     decrease_task_event_counter)

__all__ = ["Collectives", "CollectiveHandle", "ProgressEngine", "n_rounds",
           "HaloExchange", "HierarchicalCollectives",
           "PersistentCollective", "ALGORITHMS", "MODES", "EXECUTORS"]

ALGORITHMS = ("ring", "doubling")
MODES = ("blocking", "event")
# Level-A executors: "compiled" caches each (schedule, communicator, op,
# tag-family) as a flat pre-bound program (repro.core.program) — the
# steady-state default; "interpreted" re-walks the IR per call
# (_interpret) — the reference executor.  Wire protocol (tags, posting
# order) is identical, so mixed-executor ranks interoperate.
EXECUTORS = ("compiled", "interpreted")


def _norm_executor(executor: str) -> str:
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r}; "
                         f"one of {EXECUTORS}")
    return executor

_OPS: Dict[str, Callable] = {"sum": np.add, "prod": np.multiply,
                             "max": np.maximum, "min": np.minimum}

_ALG_ALIASES = {"ring": "ring", "chain": "ring", "pairwise": "ring",
                "doubling": "doubling", "recursive-doubling": "doubling",
                "rd": "doubling", "tree": "doubling", "bruck": "doubling",
                "dissemination": "doubling", "auto": "auto"}
_MODE_ALIASES = {"blocking": "blocking", "wait": "blocking",
                 "event": "event", "iwait": "event",
                 "nonblocking": "event", "non-blocking": "event"}


def _op_fn(op) -> Callable:
    if callable(op):
        return op
    try:
        return _OPS[op]
    except KeyError:
        raise ValueError(f"unknown reduction op {op!r}; "
                         f"use one of {sorted(_OPS)} or a callable")


def _norm_alg(algorithm: str) -> str:
    try:
        return _ALG_ALIASES[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; "
                         f"aliases: {sorted(_ALG_ALIASES)}")


def _norm_mode(mode: str) -> str:
    try:
        return _MODE_ALIASES[mode]
    except KeyError:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"aliases: {sorted(_MODE_ALIASES)}")


def n_rounds(name: str, algorithm: str, size: int) -> int:
    """Message rounds on the critical path — the closed-form latency model.

    Equals ``schedule.build(name, algorithm, size).rounds`` (asserted in
    tests/test_schedule.py) but needs no schedule construction; for
    payload-size-aware predictions use
    :meth:`repro.core.schedule.Schedule.cost` instead.
    """
    alg = _norm_alg(algorithm)
    if alg == "auto":
        raise ValueError('n_rounds needs a concrete algorithm, not "auto" '
                         '(auto is payload-size dependent — use '
                         'Schedule.cost / Collectives.predict)')
    if size <= 1:
        return 0
    log2_ceil = max(1, math.ceil(math.log2(size)))
    if alg == "doubling":
        # Reductions butterfly over 2^⌊log2 n⌋ after folding the remainder
        # ranks (+1 fold and +1 unfold round when n is not a power of two).
        butterfly = size.bit_length() - 1
        extra = 0 if size & (size - 1) == 0 else 2
        return {"allreduce": butterfly + extra,
                "reduce_scatter": butterfly + extra,
                "reduce": log2_ceil, "bcast": log2_ceil,
                "barrier": log2_ceil, "allgather": log2_ceil,
                "alltoall": log2_ceil}[name]
    return {"allreduce": 2 * (size - 1)}.get(name, size - 1)


class CollectiveHandle(tac.EventHandle):
    """Completion handle of an event-bound collective (result at release).

    A schedule failure (bad payloads, a raising ``op``, a dead peer's
    :class:`~repro.core.tac.RankFailedError`...) completes the handle via
    :meth:`~repro.core.tac.EventHandle.fail`; ``result`` re-raises it on
    whichever thread consumes the collective, so errors surface instead
    of killing the polling service or hanging ``taskwait``.
    """


# ---------------------------------------------------------------------------
# Generator-driven state machines + progress engine
# ---------------------------------------------------------------------------
class _Machine:
    """One rank's collective schedule, advanced as its handles complete.

    The generator yields the handle (or list of handles) it waits on; the
    driver sends the received payload(s) back in.  ``advance`` is *not*
    re-entrant: callers must ensure one thread at a time (the progress
    engine serialises via the polling registry's per-service lock; the
    group driver is single-threaded).
    """

    __slots__ = ("gen", "handle", "counter", "comm", "steps", "done",
                 "_waiting", "_started")

    def __init__(self, gen, handle: CollectiveHandle,
                 counter=None, comm=None) -> None:
        self.gen = gen
        self.handle = handle
        self.counter = counter
        self.comm = comm        # revoked on peer failure (ULFM recovery)
        self.steps = 0          # resolved waits — progress indicator
        self.done = False
        self._waiting: Any = None
        self._started = False

    def advance(self) -> bool:
        """Run until the next incomplete wait; True once finished."""
        if self.done:
            return True
        try:
            if not self._started:
                self._started = True
                self._waiting = next(self.gen)
            while True:
                w = self._waiting
                many = isinstance(w, (list, tuple))
                hs = list(w) if many else [w]
                if not all(h.test() for h in hs):
                    return False
                res = [h.result for h in hs] if many else hs[0].result
                self.steps += 1
                if _trc.TRACING:
                    # One resolved wait == one round of this rank's
                    # schedule advanced (by whichever progress thread).
                    _trc.TRACER.instant("collective", "round",
                                        step=self.steps, waits=len(hs))
                self._waiting = self.gen.send(res)
        except StopIteration as stop:
            self.done = True
            self.handle.complete(stop.value)
            if self.counter is not None:
                decrease_task_event_counter(self.counter, 1)
            return True
        except BaseException as exc:  # noqa: BLE001 - surfaced via handle
            # A raising schedule must not kill the polling thread or leave
            # the task's event counter bound forever — fail the handle
            # (consumers re-raise) and release the dependency.
            self.done = True
            if (self.comm is not None
                    and isinstance(exc, tac.RankFailedError)
                    and not isinstance(exc, tac.CommRevokedError)):
                # ULFM recovery step 1: the rank that observes a peer
                # failure mid-collective revokes the communicator, so
                # every *other* rank's pending rounds fail too instead of
                # parking forever on sends the aborted ranks will never
                # post.  CommRevokedError is excluded — a machine killed
                # by the revoke itself must not re-revoke.
                revoke = getattr(self.comm, "revoke", None)
                if revoke is not None:
                    revoke()
            self.handle.fail(exc)
            if self.counter is not None:
                decrease_task_event_counter(self.counter, 1)
            return True


class ProgressEngine:
    """Advances event-bound collective machines to completion.

    The notification engine of the follow-on papers (*Callback-based
    Completion Notification using MPI Continuations*; *MPI Progress For
    All*): completion is detected and *continued* (next rounds posted,
    results combined, dependencies released) by the runtime's progress
    threads, never by a blocked caller.  Two backends:

    * ``notify="polling"`` — a registered polling service re-``advance``s
      every pending machine each tick: O(in-flight machines) handle
      tests per poll (``stats["tests"]`` counts them).
    * ``notify="continuation"`` — each machine, when it parks on an
      incomplete wait, **re-arms a continuation on its next awaited
      handle(s)** via the runtime's
      :class:`repro.core.continuations.ContinuationEngine`; the machine
      is advanced exactly when something it waits on completes — O(1)
      dispatches per completion, zero re-polling, no machine list at
      all.  Event-bound dependency release
      (:func:`repro.core.events.decrease_task_event_counter`) fires from
      the continuation callback (inside :meth:`_Machine.advance`).

    ``stats``: ``polls`` (service invocations), ``tests`` (machines
    re-advanced by polling — the O(in-flight × ticks) term), ``rearms``
    (continuations armed — O(completions)).
    """

    def __init__(self, notify: str = "polling",
                 continuations: Any = None) -> None:
        if notify not in ("polling", "continuation"):
            raise ValueError(f"unknown notify backend {notify!r}")
        if notify == "continuation" and continuations is None:
            raise ValueError('notify="continuation" needs a '
                             'ContinuationEngine (continuations=)')
        self.notify = notify
        self._continuations = continuations
        self._lock = threading.Lock()
        self._machines: List[_Machine] = []
        self._armed = 0
        self.stats: Dict[str, int] = {"polls": 0, "tests": 0, "rearms": 0}

    def submit(self, machine: _Machine) -> None:
        # First advance on the caller's thread (posts the initial sends);
        # the machine only becomes visible to the poller/continuation if
        # still pending, so `advance` never runs concurrently.
        if machine.advance():
            return
        if self.notify == "continuation":
            with self._lock:
                self._armed += 1
            self._arm(machine)
            return
        with self._lock:
            self._machines.append(machine)

    # -- continuation backend ----------------------------------------------
    def _arm(self, machine: _Machine) -> None:
        """Attach a continuation to the machine's next awaited handles."""
        w = machine._waiting
        handles = list(w) if isinstance(w, (list, tuple)) else [w]
        with self._lock:
            self.stats["rearms"] += 1
        self._continuations.attach(
            handles, lambda: self._continue(machine))

    def _continue(self, machine: _Machine) -> None:
        if machine.advance():
            with self._lock:
                self._armed -= 1
        else:
            self._arm(machine)

    # -- polling backend ----------------------------------------------------
    def poll(self, _data: Any) -> bool:
        with self._lock:
            snapshot = list(self._machines)
            self.stats["polls"] += 1
            self.stats["tests"] += len(snapshot)
        finished = [m for m in snapshot if m.advance()]
        if finished:
            with self._lock:
                self._machines = [m for m in self._machines
                                  if m not in finished]
        return False  # stay registered

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._machines) + self._armed


def _engine(runtime) -> ProgressEngine:
    eng = getattr(runtime, "_coll_engine", None)
    if eng is None:
        with runtime._lock:
            eng = getattr(runtime, "_coll_engine", None)
            if eng is None:
                if getattr(runtime, "notify", "polling") == "continuation":
                    # Machines ride the runtime's continuation engine —
                    # its single service; nothing new to register.
                    eng = ProgressEngine(
                        notify="continuation",
                        continuations=runtime.continuations)
                else:
                    eng = ProgressEngine()
                    runtime._register_service(
                        "collective progress engine", eng.poll)
                runtime._coll_engine = eng  # type: ignore[attr-defined]
    return eng


def _drive_blocking(gen):
    """Drive a schedule with task-aware waits (pause/resume per round)."""
    try:
        w = next(gen)
        while True:
            if isinstance(w, (list, tuple)):
                res = tac.waitall(list(w))
            else:
                res = tac.wait(w)
            w = gen.send(res)
    except StopIteration as stop:
        return stop.value


def _execute_schedule(gen, mode: str, comm=None):
    """Run one rank's schedule in an interoperability mode (normalized).

    Shared by every collective family (world-wide, neighbourhood,
    hierarchical, persistent).  Outside a task (or without TASK_MULTIPLE)
    the schedule is driven inline with OS-level waits — the PMPI path.
    Inside a task the progress engine advances the rounds from the polling
    service: ``blocking`` pays one pause on the completion handle,
    ``event`` binds the handle to the task's event counter and returns it
    immediately.  ``comm`` is the communicator to revoke if a peer dies
    mid-schedule (see :meth:`_Machine.advance`).
    """
    task = current_task()
    if not (tac.is_enabled() and task is not None):
        result = _drive_blocking(gen)
        if mode == "blocking":
            return result
        handle = CollectiveHandle()
        handle.complete(result)
        return handle
    handle = CollectiveHandle()
    if mode == "blocking":
        _engine(task._runtime).submit(_Machine(gen, handle, comm=comm))
        return tac.wait(handle)
    counter = get_current_event_counter()
    increase_current_task_event_counter(counter, 1)
    _engine(task._runtime).submit(_Machine(gen, handle, counter, comm=comm))
    return handle


def _drive_group(machines: Sequence[_Machine]) -> None:
    """Round-robin all ranks' machines on the calling thread.

    The deterministic single-threaded driver: used by the sequential
    ('pure'/fork-join) benchmark versions and by tests that need a
    collective without a task runtime.  All matching is in-memory and
    eager, so a full pass with zero progress means the schedule itself is
    stuck — reported instead of spinning.
    """
    pending = [m for m in machines if not m.advance()]
    while pending:
        progressed = False
        nxt = []
        for m in pending:
            before = m.steps
            if m.advance() or m.steps != before:
                progressed = True
            if not m.done:
                nxt.append(m)
        if nxt and not progressed:
            # A failed rank stalls its peers (their recvs never match);
            # surface the root cause rather than the symptom.
            for m in machines:
                if m.handle.error is not None:
                    raise m.handle.error
            names = [getattr(m.gen, "__name__", "?") for m in nxt]
            raise RuntimeError(
                f"collective group stalled: {len(nxt)} ranks cannot "
                f"progress ({names}) — mismatched call order or rank set")
        pending = nxt


# ---------------------------------------------------------------------------
# The Level-A executor: one generator interprets any Schedule.
# Posts isends, yields irecv handle(s), receives the payload(s) via
# send(); StopIteration.value is the rank's result.
# ---------------------------------------------------------------------------
def _interpret(sched: Schedule, comm, rank: int, tag, *, value=None,
               op=None, blocks=None, sends=None):
    """Execute rank ``rank``'s program of ``sched`` over ``comm``.

    The host-side (Level A) consumer of the schedule IR: ops run in
    program order; ``Recv`` posts the ``irecv`` immediately (eager
    matching), and the generator only *yields* — a single handle or a
    batched list — when an op actually reads a buffer that is still in
    flight.  The same generator therefore serves all three drivers
    (inline PMPI waits, the blocking-mode progress engine, the event-bound
    progress engine) and any communicator with ``isend``/``irecv`` —
    world, sub-group, or Cartesian group, whose namespaced tags and rank
    translation apply transparently.
    """
    if not 0 <= rank < sched.n:
        raise ValueError(f"rank {rank} out of range for n={sched.n}")
    env, shape = _bind_inputs(sched, value, blocks, sends)
    pending: Dict[Any, Any] = {}    # buffer -> in-flight irecv handle

    def _reads_of(o):
        return [b for b in o.reads if b in pending]

    for o in sched.programs[rank]:
        needed = _reads_of(o)
        if len(needed) == 1:
            env[needed[0]] = yield pending.pop(needed[0])
        elif needed:
            handles = [pending.pop(b) for b in needed]
            vals = yield handles
            for b, v in zip(needed, vals):
                env[b] = v
        if isinstance(o, Send):
            comm.isend(env[o.buf], src=rank, dst=o.peer, tag=tag(o.tag))
        elif isinstance(o, Recv):
            pending[o.buf] = comm.irecv(src=o.peer, dst=rank,
                                        tag=tag(o.tag))
        elif isinstance(o, Combine):
            env[o.out] = op(env[o.a], env[o.b])
        elif isinstance(o, Copy):
            env[o.out] = env[o.src]
        elif isinstance(o, Pack):
            env[o.out] = tuple(env[p] for p in o.parts)
        elif isinstance(o, Unpack):
            for b, v in zip(o.outs, env[o.src]):
                env[b] = v
        elif isinstance(o, Slice):
            flat = np.asarray(env[o.src]).reshape(-1)
            env[o.out] = np.array_split(flat, o.parts)[o.index]
        elif isinstance(o, Concat):
            flat = np.concatenate([np.asarray(env[p]).reshape(-1)
                                   for p in o.parts])
            env[o.out] = flat if o.like is None else flat.reshape(
                np.asarray(env[o.like]).shape)
        elif isinstance(o, Const):
            env[o.out] = o.value
        else:                       # pragma: no cover - new op kinds
            raise TypeError(f"cannot interpret op {o!r}")
    if pending:
        # Completion requires every posted receive (a collective may not
        # finish before its incoming rounds do — barrier semantics).
        bufs = list(pending)
        if len(bufs) == 1:
            env[bufs[0]] = yield pending.pop(bufs[0])
        else:
            vals = yield [pending.pop(b) for b in bufs]
            for b, v in zip(bufs, vals):
                env[b] = v

    kind = sched.output_kind
    if kind == "none":
        return None
    if kind == "buf":
        out = sched.out_bufs[rank]
        return None if out is None else env[out]
    if kind == "concat":
        flat = np.concatenate([env[c] for c in sched.chunk_bufs])
        return flat.reshape(shape)
    if kind == "list":
        return [env[("g", i)] for i in range(sched.n)]
    if kind == "dirs":
        rv_dirs = sched.in_dirs or sched.out_dirs
        return {d: env[("rv", d)] for d in rv_dirs[rank]}
    raise ValueError(f"unknown output kind {kind!r}")  # pragma: no cover


def _payload_nbytes(value) -> int:
    """Per-rank payload size for ``algorithm="auto"`` (reductions only —
    their element-wise semantics make the size identical on all ranks,
    so every rank resolves the same schedule)."""
    try:
        return 0 if value is None else np.asarray(value).nbytes
    except Exception:               # noqa: BLE001 - opaque payloads
        return 0


# Per-op default algorithm, shared by the per-rank methods and run_group:
# latency-optimal doubling for the rooted/small ops, bandwidth-optimal ring
# for the bulk ones.
_DEFAULT_ALGORITHM = {
    "barrier": "doubling", "bcast": "doubling", "reduce": "doubling",
    "allreduce": "ring", "allgather": "ring", "reduce_scatter": "ring",
    "alltoall": "ring",
}


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
class Collectives:
    """Collective operations over a communicator.

    The communicator may be a :class:`tac.CommWorld` or any
    :class:`tac.CommGroup` (``world.group(...)``, ``world.split(...)``,
    ``world.cart_create(...)``): ranks are communicator-local and a
    group's tag namespace keeps concurrent collectives on disjoint
    sub-groups — or on a group and its parent world — fully isolated.

    Every rank participating in a collective calls the same method (from
    its own task or thread).  Tag isolation follows MPI's rule — each rank
    must issue its collectives in the same order — via per-rank sequence
    counters; programs whose schedulers may reorder *independent*
    collectives pass an explicit ``key`` instead (any hashable, identical
    on all ranks of one collective).

    ``mode="blocking"`` returns the rank's result (pausing the task per
    round); ``mode="event"`` returns a :class:`CollectiveHandle` bound to
    the calling task's event counter — consume ``handle.result`` from a
    successor task.

    ``alpha``/``beta``/``gamma`` parameterise the α-β cost model used by
    ``algorithm="auto"`` (and by :meth:`predict`): per-message latency,
    wire seconds per byte, combine seconds per byte.
    """

    def __init__(self, comm, *, alpha: float = 1e-6, beta: float = 1e-9,
                 gamma: float = 0.0, calibration: Any = None,
                 executor: str = "compiled",
                 hierarchical: Optional[int] = None,
                 hierarchy: Optional[int] = None,
                 inter_alpha: Optional[float] = None,
                 inter_beta: Optional[float] = None,
                 options: Optional[CollectiveOptions] = None) -> None:
        # `hierarchy=` is the pre-CollectiveOptions spelling of the pod
        # size; the per-call kwarg was always `hierarchical=`, so the
        # constructor now matches it (one spelling everywhere).
        hierarchical = renamed_kwarg("hierarchy", hierarchy,
                                     "hierarchical", hierarchical)
        if options is not None:
            [hierarchical] = options.take(hierarchical=hierarchical)
        self.executor = _norm_executor(executor)
        self.comm = comm
        self.world = comm   # historical alias (pre-sub-communicator name)
        self.alpha = alpha
        self.beta = beta
        self.gamma = gamma
        self.inter_alpha = inter_alpha
        self.inter_beta = inter_beta
        if calibration is not None:
            # a CALIBRATION.json path (tools/calibrate.py output) or a
            # pre-loaded {"alpha", "beta", "gamma"} mapping: measured
            # constants replace the nominal ones, so algorithm="auto"
            # selects under the machine actually running.
            consts = (dict(calibration) if isinstance(calibration, dict)
                      else schedule_ir.load_calibration(calibration))
            self.alpha = float(consts["alpha"])
            self.beta = float(consts["beta"])
            self.gamma = float(consts["gamma"])
            if inter_alpha is None and not isinstance(calibration, dict):
                # pick up the calibrated inter-pod transport when the
                # benchmark legs have fitted one ("inter" family) — the
                # constants the two-tier auto candidates pay cross-pod.
                try:
                    inter = schedule_ir.load_calibration(calibration,
                                                         family="inter")
                except KeyError:
                    pass
                else:
                    self.inter_alpha = inter["alpha"]
                    self.inter_beta = inter["beta"]
        # Pod structure for algorithm="auto": `hierarchical` consecutive
        # ranks form a pod; auto then also considers the composed
        # hierarchical allreduce and costs EVERY candidate under the
        # two-tier link (see schedule.best_schedule).
        self.hierarchy = int(hierarchical) if hierarchical else None
        if self.hierarchy is not None and (
                self.hierarchy < 1 or comm.size % self.hierarchy):
            raise ValueError(f"hierarchical pod size {hierarchical} must "
                             f"divide the communicator size {comm.size}")
        self._seq = [itertools.count() for _ in range(comm.size)]

    # -- plumbing ----------------------------------------------------------
    def _key(self, rank: int, key: Any) -> Any:
        """The call's tag epoch: explicit, or the next per-rank sequence
        number (MPI's same-order-on-every-rank rule).  Consumed only
        AFTER validation/resolution succeeded — both executors draw from
        the same counters, so a rejected call can never desynchronize a
        rank's subsequent keyless collectives from its peers."""
        return next(self._seq[rank]) if key is None else key

    def _tagger(self, name: str, rank: int, key: Any):
        key = self._key(rank, key)

        def tag(sub: Any):
            return ("coll", name, key, sub)
        return tag

    # Ops whose payload size is identical on every rank by the collective's
    # semantics (element-wise reductions; barrier is size-free).  Only
    # these may pick the schedule from the local payload: for
    # bcast/allgather/alltoall the local sizes can legitimately differ
    # across ranks (non-root bcast callers pass None, gathers may be
    # ragged), and a size-dependent choice would hand different ranks
    # different schedules — a mismatch stall.
    _UNIFORM_PAYLOAD = ("barrier", "reduce", "allreduce", "reduce_scatter")

    def _resolve(self, name: str, algorithm: Optional[str],
                 segments: int = 1, root: int = 0, value=None,
                 nbytes: Optional[int] = None,
                 hierarchical: Optional[int] = None) -> Schedule:
        """Algorithm/segment resolution -> the (cached) schedule object."""
        if hierarchical is not None:
            if name != "allreduce":
                raise ValueError("hierarchical schedules exist for "
                                 "allreduce only")
            if algorithm is not None or segments != 1:
                raise ValueError("hierarchical= fixes the composed "
                                 "schedule; drop algorithm/segments")
            intra = int(hierarchical)
            if intra < 1 or self.comm.size % intra:
                raise ValueError(
                    f"hierarchical intra size {hierarchical} must divide "
                    f"the communicator size {self.comm.size}")
            return schedule_ir.build_hierarchical(
                intra, self.comm.size // intra)
        algorithm = _norm_alg(algorithm or _DEFAULT_ALGORITHM[name])
        if algorithm == "auto":
            if name not in self._UNIFORM_PAYLOAD:
                # size can differ per rank: fall back to the deterministic
                # per-op default so all ranks agree on the schedule.
                algorithm = _DEFAULT_ALGORITHM[name]
            else:
                if nbytes is None:
                    nbytes = _payload_nbytes(value)
                return schedule_ir.best_schedule(
                    name, self.comm.size, nbytes, alpha=self.alpha,
                    beta=self.beta, gamma=self.gamma, root=root,
                    intra=self.hierarchy if name == "allreduce" else None,
                    inter_alpha=self.inter_alpha,
                    inter_beta=self.inter_beta)
        return schedule_ir.build(name, algorithm, self.comm.size,
                                 root=root, segments=segments)

    def _schedule(self, name: str, algorithm: str, rank: int, key: Any,
                  *, segments: int = 1, root: int = 0, value=None,
                  op=None, blocks=None, hierarchical: Optional[int] = None):
        n = self.comm.size
        if not 0 <= rank < n:
            raise ValueError(f"rank {rank} out of range for size {n}")
        sched = self._resolve(name, algorithm, segments, root, value,
                              hierarchical=hierarchical)
        key = self._key(rank, key)
        if self.executor == "compiled":
            prog = program_ir.compile_schedule(sched, self.comm, op=op,
                                               head=("coll", name))
            return prog.gen(rank, key, value=value, blocks=blocks)

        def tag(sub: Any):
            return ("coll", name, key, sub)
        return _interpret(sched, self.comm, rank, tag,
                          value=value, op=op, blocks=blocks)

    def _run(self, name: str, algorithm: Optional[str], rank: int,
             key: Any, mode: str, **kw):
        # Normalize/validate EVERYTHING before _schedule consumes the
        # per-rank tag sequence — a rejected call must not desynchronize
        # this rank's subsequent keyless collectives from its peers.
        mode = _norm_mode(mode)
        if algorithm is not None:
            _norm_alg(algorithm)
        return _execute_schedule(
            self._schedule(name, algorithm, rank, key, **kw), mode,
            comm=self.comm)

    def predict(self, name: str, nbytes: int, *,
                algorithm: Optional[str] = None,
                segments: int = 1) -> float:
        """Predicted seconds for one collective under the α-β model
        (``algorithm="auto"`` resolves for the given ``nbytes``)."""
        sched = self._resolve(name, algorithm, segments, nbytes=nbytes)
        return sched.cost(self.alpha, self.beta, nbytes, gamma=self.gamma)

    # -- the seven collectives ---------------------------------------------
    # algorithm=None picks the per-op default from _DEFAULT_ALGORITHM
    # (latency-optimal doubling for the rooted/small ops, bandwidth-optimal
    # ring for the bulk ones) — shared with run_group so the two entry
    # points can never drift apart.  algorithm="auto" picks by α-β cost.
    # Every method additionally accepts options=CollectiveOptions(...) —
    # the consolidated tuning spec (repro.core.options); explicit kwargs
    # override its fields, and fields an op cannot honour raise.
    def barrier(self, *, rank: int, algorithm: Optional[str] = None,
                mode: str = "blocking", key: Any = None,
                options: Optional[CollectiveOptions] = None):
        [algorithm] = CollectiveOptions.merge(options, algorithm=algorithm)
        return self._run("barrier", algorithm, rank, key, mode)

    def bcast(self, value: Any = None, *, rank: int, root: int = 0,
              algorithm: Optional[str] = None, mode: str = "blocking",
              key: Any = None,
              options: Optional[CollectiveOptions] = None):
        [algorithm] = CollectiveOptions.merge(options, algorithm=algorithm)
        return self._run("bcast", algorithm, rank, key, mode,
                         value=value, root=root)

    def reduce(self, value: Any, *, rank: int, op="sum", root: int = 0,
               algorithm: Optional[str] = None, mode: str = "blocking",
               key: Any = None,
               options: Optional[CollectiveOptions] = None):
        [algorithm] = CollectiveOptions.merge(options, algorithm=algorithm)
        return self._run("reduce", algorithm, rank, key, mode,
                         value=np.asarray(value), op=_op_fn(op), root=root)

    def allreduce(self, value: Any, *, rank: int, op="sum",
                  algorithm: Optional[str] = None, mode: str = "blocking",
                  key: Any = None, segments: int = 1,
                  hierarchical: Optional[int] = None,
                  options: Optional[CollectiveOptions] = None):
        """``segments > 1`` runs the pipelined ring allreduce (combine of
        segment *k* overlaps transport of segment *k+1*).
        ``hierarchical=intra`` runs the composed two-axis schedule
        (:func:`repro.core.schedule.build_hierarchical` — intra ring
        reduce-scatter, inter doubling, intra ring allgather) with
        ``intra`` consecutive ranks per pod; ``intra`` must divide the
        communicator size."""
        algorithm, segments, hierarchical = CollectiveOptions.merge(
            options, algorithm=algorithm, segments=segments,
            hierarchical=hierarchical)
        if segments > 1:
            algorithm = algorithm or "ring"
            if _norm_alg(algorithm) != "ring":
                raise ValueError("segmented allreduce requires the ring "
                                 "algorithm")
        return self._run("allreduce", algorithm, rank, key, mode,
                         value=np.asarray(value), op=_op_fn(op),
                         segments=segments, hierarchical=hierarchical)

    def allgather(self, value: Any, *, rank: int,
                  algorithm: Optional[str] = None, mode: str = "blocking",
                  key: Any = None, segments: int = 1,
                  options: Optional[CollectiveOptions] = None):
        """Returns the list of every rank's contribution, rank order.

        ``segments > 1`` runs the segmented ring (contributions sliced
        into pipelined sub-rings); it requires array payloads of one
        common shape (the MPI uniform-count contract) and returns each
        contribution as an array of that shape."""
        algorithm, segments = CollectiveOptions.merge(
            options, algorithm=algorithm, segments=segments)
        if segments > 1:
            algorithm = algorithm or "ring"
            if _norm_alg(algorithm) != "ring":
                raise ValueError("segmented allgather requires the ring "
                                 "algorithm")
            value = np.asarray(value)
        return self._run("allgather", algorithm, rank, key, mode,
                         value=value, segments=segments)

    def reduce_scatter(self, value: Any, *, rank: int, op="sum",
                       algorithm: Optional[str] = None,
                       mode: str = "blocking", key: Any = None,
                       segments: int = 1,
                       options: Optional[CollectiveOptions] = None):
        """Returns this rank's ``np.array_split`` chunk of the flattened
        element-wise reduction.  ``segments > 1`` pipelines the ring
        (combine of segment *k* overlaps transport of segment *k+1*);
        the returned chunk is bit-identical to the unsegmented one."""
        algorithm, segments = CollectiveOptions.merge(
            options, algorithm=algorithm, segments=segments)
        if segments > 1:
            algorithm = algorithm or "ring"
            if _norm_alg(algorithm) != "ring":
                raise ValueError("segmented reduce_scatter requires the "
                                 "ring algorithm")
        return self._run("reduce_scatter", algorithm, rank, key, mode,
                         value=np.asarray(value), op=_op_fn(op),
                         segments=segments)

    def alltoall(self, blocks: Sequence[Any], *, rank: int,
                 algorithm: Optional[str] = None, mode: str = "blocking",
                 key: Any = None,
                 options: Optional[CollectiveOptions] = None):
        """``blocks[d]`` goes to rank ``d``; returns blocks received,
        indexed by source rank."""
        [algorithm] = CollectiveOptions.merge(options, algorithm=algorithm)
        blocks = list(blocks)
        if len(blocks) != self.world.size:
            raise ValueError(f"alltoall needs exactly {self.world.size} "
                             f"blocks, got {len(blocks)}")
        return self._run("alltoall", algorithm, rank, key, mode,
                         blocks=blocks)

    # -- neighbourhood collectives (Cartesian communicators) ---------------
    def neighbor_alltoall(self, sends: Dict[Any, Any], *, rank: int,
                          mode: str = "blocking", key: Any = None):
        """Neighbourhood all-to-all (MPI_Neighbor_alltoall).

        Requires a communicator with a Cartesian topology
        (``CommWorld.cart_create``).  ``sends`` maps each of this rank's
        neighbour directions ``(dim, ±1)`` to the payload for the
        neighbour in that direction; the result maps each direction to
        the payload received *from* that neighbour.  Boundary ranks of a
        non-periodic grid simply have fewer directions.
        """
        mode = _norm_mode(mode)
        sched = _neighbor_schedule(self.comm)
        sends = _check_dir_payloads(sends, sched.out_dirs[rank])
        if self.executor == "compiled":
            prog = program_ir.compile_schedule(
                sched, self.comm, head=("coll", "neighbor_alltoall"))
            gen = prog.gen(rank, self._key(rank, key), sends=sends)
        else:
            gen = _interpret(sched, self.comm, rank,
                             self._tagger("neighbor_alltoall", rank, key),
                             sends=sends)
        return _execute_schedule(gen, mode, comm=self.comm)

    # -- persistent collectives (MPI_*_init analogue) ----------------------
    def persistent(self, name: str, *, algorithm: Optional[str] = None,
                   op="sum", root: int = 0, segments: int = 1,
                   options: Optional[CollectiveOptions] = None
                   ) -> "PersistentCollective":
        """Pre-build a collective schedule for repeated posting.

        The ``MPI_Allreduce_init`` analogue made trivial by schedules
        being data: the returned :class:`PersistentCollective` holds the
        resolved schedule/operator and its own tag namespace; each
        :meth:`PersistentCollective.start` re-posts it (per-rank sequence
        numbers keep iterations apart, or pass ``key=iteration``).
        """
        algorithm, segments = CollectiveOptions.merge(
            options, algorithm=algorithm, segments=segments)
        return PersistentCollective(self, name, algorithm=algorithm,
                                    op=op, root=root, segments=segments)

    # -- single-threaded group driver --------------------------------------
    def run_group(self, name: str, per_rank: Sequence[Dict[str, Any]],
                  **common: Any) -> List[Any]:
        """Run one collective for ALL ranks round-robin on this thread.

        The sequential ('pure'/fork-join) execution path and the
        deterministic test driver: no runtime, no threads, no pausing.
        ``per_rank[r]`` holds rank-specific kwargs (e.g. ``value``);
        ``common`` the shared ones (``op``, ``algorithm``, ``key``...).
        Returns the per-rank results in rank order.
        """
        if len(per_rank) != self.world.size:
            raise ValueError(f"need kwargs for all {self.world.size} ranks")
        machines = []
        for r, kw in enumerate(per_rank):
            gen = self._make_gen(name, rank=r, **dict(common, **kw))
            machines.append(_Machine(gen, CollectiveHandle()))
        _drive_group(machines)
        return [m.handle.result for m in machines]

    _GROUP_SPEC = {
        # name -> (accepted kwargs, required kwargs)
        "barrier": (set(), set()),
        "bcast": ({"value", "root"}, set()),
        "reduce": ({"value", "op", "root"}, {"value"}),
        "allreduce": ({"value", "op", "segments", "hierarchical"},
                      {"value"}),
        "allgather": ({"value", "segments"}, {"value"}),
        "reduce_scatter": ({"value", "op", "segments"}, {"value"}),
        "alltoall": ({"blocks"}, {"blocks"}),
    }

    def _make_gen(self, name: str, *, rank: int,
                  algorithm: Optional[str] = None, key: Any = None, **kw):
        if name not in self._GROUP_SPEC:
            raise ValueError(f"unknown collective {name!r}; "
                             f"one of {sorted(self._GROUP_SPEC)}")
        accepted, required = self._GROUP_SPEC[name]
        unknown = set(kw) - accepted
        if unknown:
            # `mode` lands here too: run_group drives all ranks inline.
            raise ValueError(
                f"{name}: unexpected argument(s) {sorted(unknown)}; "
                f"accepted: {sorted(accepted | {'algorithm', 'key'})}")
        missing = required - set(kw)
        if missing:
            raise ValueError(f"{name}: missing argument(s) "
                             f"{sorted(missing)}")
        if name == "barrier":
            return self._schedule(name, algorithm, rank, key)
        if name == "bcast":
            return self._schedule(name, algorithm, rank, key,
                                  value=kw.get("value"),
                                  root=kw.get("root", 0))
        if name == "reduce":
            return self._schedule(name, algorithm, rank, key,
                                  value=np.asarray(kw["value"]),
                                  op=_op_fn(kw.get("op", "sum")),
                                  root=kw.get("root", 0))
        if name in ("allreduce", "reduce_scatter"):
            return self._schedule(name, algorithm, rank, key,
                                  value=np.asarray(kw["value"]),
                                  op=_op_fn(kw.get("op", "sum")),
                                  segments=kw.get("segments", 1),
                                  hierarchical=kw.get("hierarchical"))
        if name == "allgather":
            value = kw["value"]
            segments = kw.get("segments", 1)
            if segments > 1:
                value = np.asarray(value)
            return self._schedule(name, algorithm, rank, key,
                                  value=value, segments=segments)
        blocks = list(kw["blocks"])
        if len(blocks) != self.world.size:
            raise ValueError("alltoall block count != world size")
        return self._schedule(name, algorithm, rank, key, blocks=blocks)


# ---------------------------------------------------------------------------
# Persistent collectives
# ---------------------------------------------------------------------------
_PERSISTENT_IDS = itertools.count()

_REDUCING = {"reduce", "allreduce", "reduce_scatter"}


class PersistentCollective:
    """A pre-built schedule handle, re-postable every iteration.

    The ``MPI_Allreduce_init`` analogue (ROADMAP item 5): the schedule —
    algorithm, segment count, rank programs — is resolved once at
    construction; every :meth:`start` binds fresh payloads and a fresh
    tag context to the same immutable :class:`repro.core.schedule.Schedule`
    and runs it in either interoperability mode.  Iteration isolation
    comes from the per-rank sequence numbers (or an explicit
    ``key=iteration``), exactly like the one-shot collectives.
    """

    def __init__(self, coll: Collectives, name: str, *,
                 algorithm: Optional[str] = None, op="sum", root: int = 0,
                 segments: int = 1) -> None:
        algorithm = _norm_alg(algorithm or _DEFAULT_ALGORITHM[name])
        if algorithm == "auto":
            raise ValueError('algorithm="auto" is not valid for persistent '
                             'collectives (the schedule is fixed at init); '
                             'pick via Collectives.predict or pass '
                             '"ring"/"doubling"')
        self.coll = coll
        self.name = name
        self.sched = schedule_ir.build(name, algorithm, coll.comm.size,
                                       root=root, segments=segments)
        self.op = _op_fn(op) if name in _REDUCING else None
        self._id = next(_PERSISTENT_IDS)
        self._seq = [itertools.count() for _ in range(coll.comm.size)]
        # Per-rank combine-buffer arenas: compiled runs write reduction
        # results into these pre-allocated buffers (ufunc ``out=``)
        # instead of allocating per round, reused across every posting
        # of this plan — the MPI persistent-request buffer registration.
        # Sound because persistent postings are serialised per rank
        # (wait before re-start), which the drivers enforce.
        self._arenas = [dict() for _ in range(coll.comm.size)]
        # The persistent plan (MPI_*_init analogue): under the owner's
        # compiled executor the pre-bound program is resolved once here
        # and re-posted by every start()/run_group() with a fresh tag
        # epoch; the cache makes same-schedule instances share it.
        self._prog = (program_ir.compile_schedule(
            self.sched, coll.comm, op=self.op, head=("pers", self._id))
            if coll.executor == "compiled" else None)

    def _tagger(self, rank: int, key: Any):
        if key is None:
            key = next(self._seq[rank])

        def tag(sub: Any):
            return ("pers", self._id, key, sub)
        return tag

    def _plan(self):
        """The compiled plan, recompiled when the communicator epoch
        moved — a rank failure or revoke invalidated the cached program
        and the first post after recovery rebuilds it automatically
        (:func:`repro.core.program.epoch_of`)."""
        prog = self._prog
        if (prog is not None
                and prog.epoch != program_ir.epoch_of(self.coll.comm)):
            prog = self._prog = program_ir.compile_schedule(
                self.sched, self.coll.comm, op=self.op,
                head=("pers", self._id))
        return prog

    def _gen(self, rank: int, key: Any, value, blocks):
        if not 0 <= rank < self.sched.n:
            raise ValueError(f"rank {rank} out of range for n="
                             f"{self.sched.n}")
        if self.sched.input_kind == "blocks" and blocks is None:
            blocks = list(value) if value is not None else None
        prog = self._plan()
        if prog is not None:
            if key is None:
                key = next(self._seq[rank])
            return prog.gen(rank, key, value=value, blocks=blocks,
                            arena=self._arenas[rank])
        return _interpret(self.sched, self.coll.comm, rank,
                          self._tagger(rank, key), value=value,
                          op=self.op, blocks=blocks)

    def start(self, value: Any = None, *, rank: int,
              mode: str = "blocking", key: Any = None,
              blocks: Optional[Sequence[Any]] = None):
        """Post this rank's pre-built schedule; same mode contract as the
        one-shot collectives."""
        return _execute_schedule(self._gen(rank, key, value, blocks),
                                 _norm_mode(mode), comm=self.coll.comm)

    def run_group(self, per_rank_values: Sequence[Any],
                  key: Any = None) -> List[Any]:
        """All ranks round-robin on the calling thread (test/'pure' path)."""
        if len(per_rank_values) != self.sched.n:
            raise ValueError(f"need values for all {self.sched.n} ranks")
        machines = [_Machine(self._gen(r, key, v, None), CollectiveHandle())
                    for r, v in enumerate(per_rank_values)]
        _drive_group(machines)
        return [m.handle.result for m in machines]

    def cost(self, nbytes: int) -> float:
        """Predicted seconds per posting under the owner's α-β model."""
        return self.sched.cost(self.coll.alpha, self.coll.beta, nbytes,
                               gamma=self.coll.gamma)


# ---------------------------------------------------------------------------
# Neighbourhood collectives: persistent halo exchange
# ---------------------------------------------------------------------------
def _topology_dirs(comm, rank: int):
    neighbor_dirs = getattr(comm, "neighbor_dirs", None)
    if neighbor_dirs is None:
        raise TypeError(
            "neighbourhood collectives need a communicator with a "
            "topology — build a Cartesian one with CommWorld.cart_create "
            "or a graph one with CommWorld.dist_graph_create")
    return tuple(neighbor_dirs(rank))


def _neighbor_schedule(comm) -> Schedule:
    """The neighbourhood schedule of a Cartesian communicator.

    Memoised on the communicator itself (topologies are immutable), so
    per-rank postings don't rebuild/re-hash the O(size) topology tuple;
    ``build_neighbor``'s cache additionally shares one schedule object
    across same-shape grids.
    """
    sched = getattr(comm, "_neighbor_sched", None)
    if sched is None:
        topology = getattr(comm, "topology", None)
        if topology is None:
            raise TypeError(
                "neighbourhood collectives need a communicator with a "
                "topology — build a Cartesian one with CommWorld.cart_create "
                "or a graph one with CommWorld.dist_graph_create")
        # Directed topologies (one-way dist-graph edges) declare their
        # receive directions separately; symmetric ones return None here.
        in_topology = getattr(comm, "in_topology", None)
        in_topo = in_topology() if in_topology is not None else None
        # Call with one arg when symmetric so the lru_cache key matches
        # direct ``build_neighbor(topology())`` calls (shared identity).
        sched = (schedule_ir.build_neighbor(topology(), in_topo)
                 if in_topo is not None
                 else schedule_ir.build_neighbor(topology()))
        comm._neighbor_sched = sched
    return sched


def _check_dir_payloads(sends, dirs):
    """``dirs`` is the rank's direction tuple (``Schedule.out_dirs[r]``)."""
    sends = dict(sends)
    expected = set(dirs)
    if set(sends) != expected:
        raise ValueError(
            f"send payloads must cover exactly this rank's neighbour "
            f"directions {sorted(expected)}, got {sorted(sends)}")
    return sends


_HALO_IDS = itertools.count()


class HaloExchange:
    """Persistent halo exchange over a Cartesian group (paper §7.1 pattern).

    The neighbourhood analogue of MPI's persistent collectives: the
    schedule — one ``Send``/``Recv`` pair per grid edge, from
    :meth:`tac.CartGroup.topology` — is built once at construction
    (:func:`repro.core.schedule.build_neighbor`; grids of equal shape
    share the cached object).  Each :meth:`start` re-posts one rank's
    program through the communicator and runs it in either
    interoperability mode:

    * ``mode="blocking"`` (§6.1) returns ``{direction: halo received from
      that neighbour}``; inside a task the wait pauses (one pause, rounds
      driven by the progress engine).
    * ``mode="event"`` (§6.2, the default — halo exchange exists to be
      overlapped) returns a :class:`CollectiveHandle` immediately and
      binds one event to the calling task; interior compute proceeds
      while the halos fly, boundary compute declares a dependency and
      reads ``handle.result``.

    Stencil codes call one ``start`` per rank per iteration; the implicit
    per-rank sequence numbers keep iterations' tag spaces apart (or pass
    ``key=iteration``).
    """

    def __init__(self, cart, *, executor: str = "compiled") -> None:
        self.executor = _norm_executor(executor)
        self.cart = cart
        self.sched = _neighbor_schedule(cart)
        self.dirs = {r: _topology_dirs(cart, r) for r in range(cart.size)}
        self._seq = [itertools.count() for _ in range(cart.size)]
        self._id = next(_HALO_IDS)
        # The persistent neighbourhood plan: edge peers pre-translated,
        # per-direction tags pre-built; every iteration re-posts it.
        self._prog = (program_ir.compile_schedule(
            self.sched, cart, head=("halo", self._id))
            if self.executor == "compiled" else None)

    def neighbors(self, rank: int):
        """The persistent neighbour list ``[((dim, ±1), neighbour)]``."""
        return self.dirs[rank]

    def _tagger(self, rank: int, key: Any):
        if key is None:
            key = next(self._seq[rank])

        def tag(sub: Any):
            return ("halo", self._id, key, sub)
        return tag

    def _plan(self):
        """The compiled plan, recompiled when the communicator epoch
        moved (automatic rebuild after failure recovery — see
        :meth:`PersistentCollective._plan`)."""
        prog = self._prog
        if (prog is not None
                and prog.epoch != program_ir.epoch_of(self.cart)):
            prog = self._prog = program_ir.compile_schedule(
                self.sched, self.cart, head=("halo", self._id))
        return prog

    def _gen(self, rank: int, key: Any, sends):
        sends = _check_dir_payloads(sends, self.sched.out_dirs[rank])
        prog = self._plan()
        if prog is not None:
            if key is None:
                key = next(self._seq[rank])
            return prog.gen(rank, key, sends=sends)
        return _interpret(self.sched, self.cart, rank,
                          self._tagger(rank, key), sends=sends)

    def start(self, sends: Dict[Any, Any], *, rank: int,
              mode: str = "event", key: Any = None):
        """Post this rank's halo round; see the class docstring for modes."""
        mode = _norm_mode(mode)
        return _execute_schedule(self._gen(rank, key, sends), mode,
                                 comm=self.cart)

    def exchange(self, sends: Dict[Any, Any], *, rank: int,
                 key: Any = None):
        """Blocking convenience: ``start(..., mode="blocking")``."""
        return self.start(sends, rank=rank, mode="blocking", key=key)

    def run_group(self, per_rank_sends: Sequence[Dict[Any, Any]],
                  key: Any = None) -> List[Dict[Any, Any]]:
        """All ranks' rounds round-robin on the calling thread — the
        sequential ('pure'/fork-join) path and the deterministic test
        driver.  Returns the per-rank received-halo dicts."""
        if len(per_rank_sends) != self.cart.size:
            raise ValueError(f"need send dicts for all {self.cart.size} "
                             f"ranks")
        machines = [_Machine(self._gen(r, key, s), CollectiveHandle())
                    for r, s in enumerate(per_rank_sends)]
        _drive_group(machines)
        return [m.handle.result for m in machines]


# ---------------------------------------------------------------------------
# Hierarchical allreduce over nested sub-communicators
# ---------------------------------------------------------------------------
class HierarchicalCollectives:
    """Hierarchical allreduce via two nested groups (ROADMAP item).

    The first consumer of :meth:`tac.CommWorld.split`: construction runs
    the split collective — consecutive ranks share ``color = rank //
    group_size`` — and gathers the per-color *intra* groups plus a
    *leaders* group of each color's rank 0.  An allreduce composes THREE
    schedules from the IR (rank translation via the
    :meth:`tac.CommGroup.group_rank` hooks):

    1. chain-reduce to the local leader inside each intra group (the ring
       family — bandwidth-optimal within a "node"),
    2. recursive-doubling allreduce across the leaders (latency-optimal
       across "nodes", any leader count),
    3. chain-broadcast back down each intra group.

    Works for any world size and ``group_size`` (the last group may be
    smaller).  Both interoperability modes are supported per rank, same
    contract as :class:`Collectives`.
    """

    def __init__(self, world: tac.CommWorld, group_size: int, *,
                 executor: str = "compiled") -> None:
        self.executor = _norm_executor(executor)
        if group_size <= 0:
            raise ValueError(f"group_size must be positive, got "
                             f"{group_size}")
        handles = [world.split(r // group_size, key=r, rank=r)
                   for r in range(world.size)]
        self.world = world
        self.group_size = group_size
        self.intra: List[tac.CommGroup] = [h.result for h in handles]
        # MPI_Group_translate_ranks: each intra group's local rank 0 in
        # the world's numbering (the world's identity group_rank hook
        # makes it a valid translation target like any CommGroup).
        leader_ranks = sorted({r for g in self.intra
                               for r in g.translate_many([0], world)})
        self.leaders = world.group(leader_ranks)
        self._seq = [itertools.count() for _ in range(world.size)]
        # The composed single-schedule form: ONE flat IR object
        # (reduce-scatter / inter-allreduce / allgather over the
        # (inter × intra) rank grid) that the Level-B lowering emits over
        # two mesh axes — available when every intra group is full.
        self.sched: Optional[Schedule] = (
            schedule_ir.build_hierarchical(group_size,
                                           world.size // group_size)
            if world.size % group_size == 0 else None)

    def _schedule(self, rank: int, key: Any, value, op):
        intra = self.intra[rank]
        lr = intra.group_rank(rank)
        if key is None:
            key = next(self._seq[rank])

        # Stage tags are ("hier", stage, key, sub) — the uniform
        # family-head + (epoch, transfer) shape every collective uses, so
        # the compiled executor's pre-built templates (head=("hier",
        # stage)) and the interpreter produce identical wire tags.
        reduce_s = schedule_ir.build("reduce", "ring", intra.size)
        leaders_s = schedule_ir.build("allreduce", "doubling",
                                      self.leaders.size)
        bcast_s = schedule_ir.build("bcast", "ring", intra.size)

        if self.executor == "compiled":
            # Per-color intra groups are shared objects, so every member
            # of a pod (and every iteration) hits the same cached plans.
            stage = [
                program_ir.compile_schedule(reduce_s, intra, op=op,
                                            head=("hier", "reduce")),
                program_ir.compile_schedule(leaders_s, self.leaders, op=op,
                                            head=("hier", "leaders")),
                program_ir.compile_schedule(bcast_s, intra,
                                            head=("hier", "bcast")),
            ]

            def gen():
                acc = yield from stage[0].gen(lr, key,
                                              value=np.asarray(value))
                if lr == 0:
                    li = intra.translate(0, self.leaders)
                    acc = yield from stage[1].gen(li, key, value=acc)
                result = yield from stage[2].gen(lr, key, value=acc)
                return result
            return gen()

        def tag(stage):
            return lambda sub: ("hier", stage, key, sub)

        def gen():
            acc = yield from _interpret(reduce_s, intra, lr,
                                        tag("reduce"),
                                        value=np.asarray(value), op=op)
            if lr == 0:
                # rank translation across the nested groups: this rank is
                # intra-local 0; its leaders-local number comes from
                # MPI_Group_translate_ranks, not arithmetic.
                li = intra.translate(0, self.leaders)
                acc = yield from _interpret(leaders_s, self.leaders, li,
                                            tag("leaders"), value=acc,
                                            op=op)
            result = yield from _interpret(bcast_s, intra, lr,
                                           tag("bcast"), value=acc)
            return result
        return gen()

    def _composed_gen(self, rank: int, key: Any, value, op):
        if self.sched is None:
            raise ValueError(
                f"composed hierarchical schedule needs equal intra groups "
                f"(world size {self.world.size} % group_size "
                f"{self.group_size} != 0)")
        if key is None:
            key = next(self._seq[rank])
        if self.executor == "compiled":
            prog = program_ir.compile_schedule(self.sched, self.world,
                                               op=op,
                                               head=("hier-composed",))
            return prog.gen(rank, key, value=np.asarray(value))

        def tag(sub):
            return ("hier-composed", key, sub)
        return _interpret(self.sched, self.world, rank, tag,
                          value=np.asarray(value), op=op)

    def allreduce(self, value, *, rank: int, op="sum",
                  mode: str = "blocking", key: Any = None,
                  composed: bool = False):
        """``composed=True`` interprets the single flat
        :func:`repro.core.schedule.build_hierarchical` schedule over the
        world communicator — the same IR object the Level-B lowering
        emits over two mesh axes — instead of the three per-group
        schedules with rank translation.  Results agree; the composed
        form exists so one schedule instance spans both executors."""
        mode = _norm_mode(mode)
        op = _op_fn(op)
        self.world.world_rank(rank)   # identity hook: validates the rank
        gen = (self._composed_gen(rank, key, value, op) if composed
               else self._schedule(rank, key, value, op))
        return _execute_schedule(gen, mode, comm=self.world)

    def persistent(self, *, op="sum") -> "PersistentHierarchical":
        """Pre-resolve the three-stage composition for per-iteration
        re-posting (the Gauss–Seidel residual's shape)."""
        return PersistentHierarchical(self, _op_fn(op))

    def run_group(self, values: Sequence[Any], *, op="sum",
                  key: Any = None, composed: bool = False) -> List[Any]:
        """Sequential driver: all ranks round-robin on this thread."""
        if len(values) != self.world.size:
            raise ValueError(f"need values for all {self.world.size} ranks")
        op = _op_fn(op)
        make = self._composed_gen if composed else self._schedule
        machines = [_Machine(make(r, key, v, op), CollectiveHandle())
                    for r, v in enumerate(values)]
        _drive_group(machines)
        return [m.handle.result for m in machines]

    def n_rounds(self) -> int:
        """Critical-path rounds: intra chain-reduce + leader doubling +
        intra chain-broadcast (the simulator's latency model)."""
        deepest = max(g.size for g in self.intra)
        return (2 * (deepest - 1)
                + n_rounds("allreduce", "doubling", self.leaders.size))

    def cost(self, alpha: float, beta: float, nbytes: int, *,
             gamma: float = 0.0) -> float:
        """α-β predicted seconds: the three stage costs on the critical
        path (deepest intra group; payload does not shrink)."""
        deepest = max(g.size for g in self.intra)
        stages = (schedule_ir.build("reduce", "ring", deepest),
                  schedule_ir.build("allreduce", "doubling",
                                    self.leaders.size),
                  schedule_ir.build("bcast", "ring", deepest))
        return sum(s.cost(alpha, beta, nbytes, gamma=gamma)
                   for s in stages)


class PersistentHierarchical:
    """Persistent handle over :class:`HierarchicalCollectives` — the
    residual-allreduce shape posted once per solver iteration."""

    def __init__(self, hier: HierarchicalCollectives, op: Callable) -> None:
        self.hier = hier
        self.op = op
        self._id = next(_PERSISTENT_IDS)
        self._seq = [itertools.count() for _ in range(hier.world.size)]
        self._group_seq = itertools.count()

    def start(self, value: Any, *, rank: int, mode: str = "blocking",
              key: Any = None):
        """Post one rank's residual round.  Implicit keys come from
        per-rank counters (aligned as long as every rank posts the same
        sequence — MPI's rule); group-driver postings use a disjoint
        ``("g", n)`` namespace, so the two entry points never collide."""
        if key is None:
            key = ("r", next(self._seq[rank]))
        return self.hier.allreduce(value, rank=rank, op=self.op,
                                   mode=mode,
                                   key=("pers-hier", self._id, key))

    def run_group(self, values: Sequence[Any],
                  key: Any = None) -> List[Any]:
        if key is None:
            key = ("g", next(self._group_seq))
        return self.hier.run_group(values, op=self.op,
                                   key=("pers-hier", self._id, key))

    def cost(self, alpha: float, beta: float, nbytes: int, *,
             gamma: float = 0.0) -> float:
        return self.hier.cost(alpha, beta, nbytes, gamma=gamma)
