"""CollectiveOptions — one coherent keyword spec for collective tuning.

The tuning knobs of the collective stack accreted spellings as the tiers
landed: the host Collectives grew ``hierarchical=`` (per call) next to
``hierarchy=`` (constructor), the Level-B lowering spelled the ring
transport dtype ``wire=`` while the grad-sync wrapper used ``wire=`` for
the *presentation* dtype policy and ``stage_wire=`` for the transport.
This module is the consolidation: one frozen dataclass naming every
knob once, accepted as ``options=`` by every entry point of the stack —
:class:`repro.core.collectives.Collectives` (constructor and the seven
collectives), :func:`repro.core.lowering.allreduce` /
:func:`~repro.core.lowering.lower_allreduce`, and
:func:`repro.core.overlap.sync_grads` — with the superseded spellings
kept as back-compat shims that raise :class:`DeprecationWarning`.

Canonical spellings (each knob means the same thing at every layer):

========================  ====================================================
``algorithm``             wire schedule (``"ring"``/``"doubling"``/
                          ``"native"``/``"auto"``; ``None`` = per-op default)
``segments``              ring pipelining factor (``> 1`` overlaps combine of
                          segment *k* with transport of segment *k+1*)
``hierarchical``          pod/intra size of the composed two-tier allreduce
                          (host tiers: consecutive-rank pod size; Level-B
                          grad sync: truthy selects the two-axis schedule,
                          the axes carry the sizes)
``stage_impl``            fused between-round stage tier (``"pallas"``/
                          ``"pallas_interpret"``/``"ref"``; ``None`` = plain
                          XLA elementwise)
``stage_wire``            ring *transport* dtype per round (``"bf16"``/
                          ``"int8"``; needs ``stage_impl``) — was ``wire=``
                          in :mod:`repro.core.lowering`
``reduce_dtype``          dtype policy a gradient leaf is *presented* to the
                          collective in (``"fp32"``/``"leaf"``; grad sync
                          only) — was ``wire=`` in :mod:`repro.core.overlap`
========================  ====================================================
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, List, Optional

__all__ = ["CollectiveOptions", "renamed_kwarg"]


def renamed_kwarg(old: str, old_value: Any, new: str,
                  new_value: Any) -> Any:
    """Back-compat shim for a renamed keyword.

    Returns the effective value: the old spelling (with a
    ``DeprecationWarning``) when given, else the new one.  Passing both
    spellings with different values is a :class:`TypeError` — silently
    preferring either would mask a caller bug.
    """
    if old_value is None:
        return new_value
    warnings.warn(
        f"{old}= is deprecated; spell it {new}= (see "
        f"repro.core.options.CollectiveOptions)",
        DeprecationWarning, stacklevel=3)
    if new_value is not None and new_value != old_value:
        raise TypeError(f"both {old}= (deprecated) and {new}= given with "
                        f"different values: {old_value!r} vs {new_value!r}")
    return old_value


@dataclasses.dataclass(frozen=True)
class CollectiveOptions:
    """The consolidated collective tuning spec (see module docstring).

    Pass an instance as ``options=`` to any entry point of the stack;
    explicit keyword arguments override the corresponding field.  Fields
    a given entry point cannot honour must be left at their defaults —
    a set-but-unconsumable field is a :class:`ValueError`, never a
    silent drop (a dropped ``segments=4`` would fake pipelining).
    """

    algorithm: Optional[str] = None
    segments: int = 1
    hierarchical: Optional[int] = None
    stage_impl: Optional[str] = None
    stage_wire: Optional[str] = None
    reduce_dtype: Optional[str] = None

    def take(self, **explicit: Any) -> List[Any]:
        """Merge ``explicit`` keyword values over this spec.

        Returns the effective values in keyword order; an explicit
        ``None`` (or, for ``segments``, the default ``1``) defers to the
        field.  Fields set to non-default here but NOT consumed by the
        caller raise — the entry point cannot honour them.
        """
        out = []
        for name, val in explicit.items():
            field_val = getattr(self, name)
            if name == "segments":
                out.append(field_val if val in (None, 1) else val)
            else:
                out.append(field_val if val is None else val)
        leftovers = [
            f.name for f in dataclasses.fields(self)
            if f.name not in explicit
            and getattr(self, f.name) != f.default]
        if leftovers:
            raise ValueError(
                f"CollectiveOptions field(s) {leftovers} are not "
                f"applicable to this entry point (consumable here: "
                f"{sorted(explicit)})")
        return out

    @staticmethod
    def merge(options: Optional["CollectiveOptions"],
              **explicit: Any) -> List[Any]:
        """:meth:`take` on ``options`` (or a default spec when None)."""
        return (options or CollectiveOptions()).take(**explicit)
