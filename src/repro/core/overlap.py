"""In-graph communication schedules — the Level-B TAMPI adaptation.

On a TPU pod the performance-critical communication lives *inside* one XLA
program, where "task dependencies" are HLO dataflow edges and "the
scheduler" is XLA's latency-hiding scheduler.  The paper's insight maps to
schedule construction: present the gradient synchronisation as

* ``fused``    — ONE all-reduce over the whole flattened gradient at the end
                 of backward.  This is the Fork-Join/Pure-MPI pattern: a
                 barrier-style phase boundary; nothing can overlap.
* ``bucketed`` — one all-reduce per parameter bucket with NO artificial
                 dependencies between them, so each reduction is issued as
                 soon as its producers are done and overlaps the remaining
                 backward compute.  This is the Interop/TAMPI pattern —
                 dependencies alone order the collectives.
* ``sentinel`` — the bucketed collectives chained through explicit tokens
                 (``lax.optimization_barrier``), serialising them exactly
                 like the artificial sentinel dependency of paper §6.3/§7.1.

Since the schedule-IR refactor this module is a thin wrapper over
:mod:`repro.core.lowering`, the Level-B executor of the same
:mod:`repro.core.schedule` IR the host progress engine interprets: each
bucket's reduction is one schedule node — ``algorithm="native"`` (the
default) lowers it to a fused ``lax.psum`` (identical HLO to the pre-IR
code: one ``all-reduce`` per bucket, same order), while ``"ring"`` /
``"doubling"`` lower the explicit ppermute rounds of the corresponding
host schedule, including the segmented/pipelined ring
(``segments > 1``).  ``halo_exchange_rows`` likewise executes the
1-D neighbourhood schedule via :func:`repro.core.lowering.lower_neighbor`.

These run inside ``jax.shard_map`` manual over the DP axes (the model axis
stays auto/GSPMD).  Structural verification = collective count/order in the
lowered HLO; benchmarks/overlap_bench.py measures wall time on the local
mesh plus the α-β predicted times, and EXPERIMENTS.md §Perf reports the
roofline deltas.

``compress="bf16"`` halves the bytes on the wire (cast → reduce → cast), an
orthogonal distributed-optimization trick.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..compat import axis_size
from . import lowering
from . import schedule as schedule_ir
from .options import CollectiveOptions, renamed_kwarg

DEFAULT_BUCKET_BYTES = 4 << 20


def _flatten_with_sizes(grads):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [l.shape for l in leaves]
    sizes = [int(l.size) for l in leaves]
    return leaves, treedef, shapes, sizes


def _make_buckets(nbytes: Sequence[int],
                  bucket_bytes: int) -> List[List[int]]:
    """Greedy byte-based bucketing of leaf indices (DDP-style).

    ``nbytes[i]`` is leaf i's byte count AS SENT (``size × wire-dtype
    itemsize`` — bf16 buckets pack twice the element count of fp32, so
    ``bucket_bytes`` bounds the actual message size; the old
    4-bytes-per-element assumption over-fragmented narrow dtypes).
    """
    buckets: List[List[int]] = []
    cur: List[int] = []
    acc = 0
    for i, b in enumerate(nbytes):
        cur.append(i)
        acc += b
        if acc >= bucket_bytes:
            buckets.append(cur)
            cur, acc = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def _wire_dtype(leaf, compress: Optional[str], wire: str):
    """The dtype a leaf travels (and accumulates) in.

    Default ``reduce_dtype="fp32"``: everything upcasts to fp32 — the
    safe accumulation the pre-IR code always used (the repo's default
    model dtype is bf16, so silently summing DP gradients in bf16 would
    be a numerics regression).  ``reduce_dtype="leaf"`` opts floating
    leaves into their own dtype (a bf16 grad travels AND accumulates in
    bf16 — the same trade ``compress="bf16"`` makes globally); integer
    dtypes always upcast (a psum would overflow).  ``compress`` overrides
    both.
    """
    if compress == "bf16":
        return jnp.dtype(jnp.bfloat16)
    if compress is None and wire == "leaf" and \
            jnp.issubdtype(leaf.dtype, jnp.floating):
        return jnp.dtype(leaf.dtype)
    return jnp.dtype(jnp.float32)


def sync_grads(grads, *, axes, mode: str = "bucketed",
               bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               compress: Optional[str] = None, mean: bool = True,
               algorithm: Optional[str] = None, segments: int = 1,
               reduce_dtype: Optional[str] = None,
               wire: Optional[str] = None,
               hierarchical: Optional[bool] = None,
               stage_impl: Optional[str] = None,
               stage_wire: Optional[str] = None,
               options: Optional[CollectiveOptions] = None):
    """Reduce gradients over the (manual) DP axes with a chosen schedule.

    Must be called inside ``shard_map`` manual over ``axes``.  ``mode``
    picks the bucketing/ordering pattern (fused/bucketed/sentinel);
    ``algorithm`` picks each bucket's wire schedule — ``"native"`` (one
    fused all-reduce node, the default and the production path),
    ``"ring"``/``"doubling"`` (explicit in-graph rounds lowered from the
    schedule IR; single DP axis only), with ``segments > 1`` pipelining
    the ring.  ``hierarchical=True`` requires exactly two DP axes in
    ``(inter, intra)`` order — e.g. ``("pod", "data")`` on the multi-pod
    production mesh — and reduces each bucket with the composed
    :func:`repro.core.schedule.build_hierarchical` schedule (intra-axis
    ring rounds, inter-axis butterfly or fused psum), the Level-B form of
    :class:`repro.core.collectives.HierarchicalCollectives`.

    Presentation dtype: by default (``reduce_dtype="fp32"``) every leaf
    travels and accumulates in fp32 — identical numerics to the pre-IR
    code in every mode; ``reduce_dtype="leaf"`` opts floating leaves
    into their own dtype — halving bf16 wire bytes at the cost of bf16
    accumulation, the same trade ``compress="bf16"`` makes globally.
    Buckets are dtype-grouped and sized by bytes AS SENT, so
    ``bucket_bytes`` bounds the real message size under either setting.
    The rule is shared by all three modes, so mode selection never
    changes numerics.  ``wire=`` is the deprecated spelling of
    ``reduce_dtype=`` (see
    :class:`repro.core.options.CollectiveOptions`, accepted here as
    ``options=``).

    ``stage_impl`` routes each bucket's between-round elementwise stages
    through the fused Pallas tier (see
    :func:`repro.core.lowering.allreduce`; explicit-round algorithms
    only).  ``stage_wire`` (``"bf16"``/``"int8"``) additionally narrows
    the ring transport dtype per round — distinct from ``reduce_dtype=``,
    which picks the dtype a leaf is PRESENTED to the collective in.
    """
    reduce_dtype = renamed_kwarg("wire", wire, "reduce_dtype",
                                 reduce_dtype)
    (algorithm, segments, hierarchical, stage_impl, stage_wire,
     reduce_dtype) = CollectiveOptions.merge(
        options, algorithm=algorithm, segments=segments,
        hierarchical=hierarchical, stage_impl=stage_impl,
        stage_wire=stage_wire, reduce_dtype=reduce_dtype)
    algorithm = algorithm or "native"
    reduce_dtype = reduce_dtype or "fp32"
    hierarchical = bool(hierarchical)
    if isinstance(axes, str):
        axes = (axes,)
    if compress == "int8" and (stage_impl is not None
                               or stage_wire is not None):
        raise ValueError("compress='int8' uses its own quantised "
                         "all_to_all path; drop stage_impl=/stage_wire=")
    if hierarchical:
        if len(tuple(axes)) != 2:
            raise ValueError(f"hierarchical grad sync needs exactly two "
                             f"DP axes (inter, intra), got {tuple(axes)}")
        if algorithm != "native" or segments != 1:
            raise ValueError("hierarchical=True picks the schedule; drop "
                             "algorithm=/segments=")
        algorithm = "hierarchical"
    leaves, treedef, shapes, sizes = _flatten_with_sizes(grads)
    # psum over multiple axes: pass the tuple directly.
    axis_arg = tuple(axes)

    def reduce_block(x):
        if compress == "int8":
            assert len(axis_arg) == 1, "int8 path: single reduction axis"
            return quantized_psum_mean(x.astype(jnp.float32),
                                       axis_arg[0]) * \
                axis_size(axis_arg[0])  # sync_grads divides later
        if compress == "bf16":
            x = x.astype(jnp.bfloat16)
        x = lowering.allreduce(x, axis_arg, algorithm=algorithm,
                               segments=segments, stage_impl=stage_impl,
                               stage_wire=stage_wire)
        return x.astype(jnp.float32)

    if reduce_dtype not in ("fp32", "leaf"):
        raise ValueError(f"unknown reduce_dtype policy {reduce_dtype!r}; "
                         f"one of ['fp32', 'leaf']")
    # Leaves group by their presentation dtype in EVERY mode, so the
    # per-leaf numerics are identical whichever mode is selected (under
    # the fp32 default that is one group with the exact pre-IR layout
    # and HLO).
    groups: Dict[Any, List[int]] = {}
    for i, l in enumerate(leaves):
        groups.setdefault(_wire_dtype(l, compress, reduce_dtype),
                          []).append(i)

    if mode == "fused":
        # one collective per wire dtype (one total for uniform models) —
        # the fork-join phase boundary.
        out = [None] * len(leaves)
        for wdt, idxs in groups.items():
            flat = jnp.concatenate([leaves[i].astype(wdt).reshape(-1)
                                    for i in idxs])
            flat = reduce_block(flat)
            off = 0
            for i in idxs:
                out[i] = flat[off:off + sizes[i]].reshape(shapes[i])
                off += sizes[i]
    elif mode in ("bucketed", "sentinel"):
        # dtype-homogeneous buckets (DDP-style): each group buckets
        # greedily by bytes AS SENT, so ``bucket_bytes`` bounds the real
        # message size — a bf16 bucket packs twice the elements of an
        # fp32 one.
        reduced: List[Any] = [None] * len(leaves)
        token = None
        for wdt, idxs in groups.items():
            itemsize = 1 if compress == "int8" else wdt.itemsize
            nbytes = [sizes[i] * itemsize for i in idxs]
            for b in _make_buckets(nbytes, bucket_bytes):
                sel = [idxs[j] for j in b]
                chunk = jnp.concatenate(
                    [leaves[i].astype(wdt).reshape(-1) for i in sel])
                if mode == "sentinel" and token is not None:
                    # Serialise on the previous collective — the artificial
                    # dependency the paper's technique removes.
                    chunk, _ = jax.lax.optimization_barrier((chunk, token))
                chunk = reduce_block(chunk)
                token = jnp.sum(chunk[:1])
                off = 0
                for i in sel:
                    reduced[i] = chunk[off:off + sizes[i]].reshape(
                        shapes[i])
                    off += sizes[i]
        out = reduced
    else:
        raise ValueError(f"unknown grad sync mode {mode!r}")

    if mean:
        # DP world size is static inside shard_map — no collective needed.
        ws = 1.0
        for a in axis_arg:
            ws *= axis_size(a)
        out = [o / ws for o in out]
    return treedef.unflatten([o.astype(l.dtype)
                              for o, l in zip(out, leaves)])


# ---------------------------------------------------------------------------
# int8 quantized reduction (gradient compression, 4x wire reduction)
# ---------------------------------------------------------------------------
def quantized_psum_mean(x: jax.Array, axis: str) -> jax.Array:
    """Mean-reduce a flat fp32 vector over ``axis`` with int8 on the wire.

    reduce-scatter leg: per-rank symmetric int8 quantisation (scales
    exchanged as scalars), shards moved with an int8 ``all_to_all``,
    dequantised and summed in fp32; all-gather leg: the reduced shard is
    re-quantised and gathered in int8.  Wire bytes ≈ 2·n·1B vs 2·n·4B for
    an fp32 ring all-reduce.  Quantisation error is bounded by
    max|g|/127 per element per leg (no error feedback — acceptable for
    gradients under Adam's normalisation; see EXPERIMENTS.md).
    Must run inside shard_map manual over ``axis``.
    """
    world = axis_size(axis)
    n = x.size
    pad = (-n) % world
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    qs = q.reshape(world, -1)
    # my shard of everyone's quantised gradient (int8 on the wire)
    recv = jax.lax.all_to_all(qs, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    scales = jax.lax.all_gather(scale, axis)            # (world,) fp32
    partial = jnp.sum(recv.astype(jnp.float32)
                      * scales[:, None], axis=0) / world
    # gather the reduced shards back, again in int8
    s2 = jnp.maximum(jnp.max(jnp.abs(partial)), 1e-20) / 127.0
    q2 = jnp.clip(jnp.round(partial / s2), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis)             # (world, n/world) s8
    s2s = jax.lax.all_gather(s2, axis)
    out = (gathered.astype(jnp.float32) * s2s[:, None]).reshape(-1)
    return out[:n] if pad else out


# ---------------------------------------------------------------------------
# Halo exchange schedules (Gauss–Seidel, paper §7.1 at Level B)
# ---------------------------------------------------------------------------
def halo_exchange_rows(x, axis_name: str, *, width: int = 1
                       ) -> Tuple[jax.Array, jax.Array]:
    """Exchange boundary rows with both neighbours along a sharded axis.

    x: the local (rows, cols) block of a 1-D row decomposition.  Returns
    (top_halo, bottom_halo) received from the previous/next shard (zeros at
    the domain edges).  Inside shard_map manual over ``axis_name``.

    Executes the 1-D non-periodic neighbourhood schedule — the same
    :func:`repro.core.schedule.build_neighbor` IR the host-side
    :class:`repro.core.collectives.HaloExchange` interprets — lowered to
    one ppermute per direction; boundary ranks have no permutation pair,
    so their halos arrive as ppermute's zeros.
    """
    n = axis_size(axis_name)
    sched = schedule_ir.build_neighbor(lowering.chain_topology(n))
    got = lowering.lower_neighbor(
        sched, {(0, 1): x[-width:], (0, -1): x[:width]}, axis_name)
    return got[(0, -1)], got[(0, 1)]


def chained(x, token):
    """Serialise ``x`` on ``token`` (sentinel-style artificial dependency)."""
    if token is None:
        return x, jnp.zeros((), x.dtype)
    x, _ = jax.lax.optimization_barrier((x, token))
    return x, jnp.sum(jnp.ravel(x)[:1]).astype(x.dtype)
