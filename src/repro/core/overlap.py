"""In-graph communication schedules — the Level-B TAMPI adaptation.

On a TPU pod the performance-critical communication lives *inside* one XLA
program, where "task dependencies" are HLO dataflow edges and "the
scheduler" is XLA's latency-hiding scheduler.  The paper's insight maps to
schedule construction: present the gradient synchronisation as

* ``fused``    — ONE all-reduce over the whole flattened gradient at the end
                 of backward.  This is the Fork-Join/Pure-MPI pattern: a
                 barrier-style phase boundary; nothing can overlap.
* ``bucketed`` — one all-reduce per parameter bucket with NO artificial
                 dependencies between them, so each reduction is issued as
                 soon as its producers are done and overlaps the remaining
                 backward compute.  This is the Interop/TAMPI pattern —
                 dependencies alone order the collectives.
* ``sentinel`` — the bucketed collectives chained through explicit tokens
                 (``lax.optimization_barrier``), serialising them exactly
                 like the artificial sentinel dependency of paper §6.3/§7.1.

These run inside ``jax.shard_map`` manual over the DP axes (the model axis
stays auto/GSPMD).  Structural verification = collective count/order in the
lowered HLO; benchmarks/overlap_bench.py measures wall time on the local
mesh and EXPERIMENTS.md §Perf reports the roofline deltas.

``compress="bf16"`` halves the bytes on the wire (cast → reduce → cast), an
orthogonal distributed-optimization trick.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..compat import axis_size

DEFAULT_BUCKET_BYTES = 4 << 20


def _flatten_with_sizes(grads):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [l.shape for l in leaves]
    sizes = [int(l.size) for l in leaves]
    return leaves, treedef, shapes, sizes


def _make_buckets(sizes: Sequence[int], bucket_bytes: int,
                  bytes_per_el: int = 4) -> List[List[int]]:
    """Greedy size-based bucketing of leaf indices (DDP-style)."""
    buckets: List[List[int]] = []
    cur: List[int] = []
    acc = 0
    for i, s in enumerate(sizes):
        cur.append(i)
        acc += s * bytes_per_el
        if acc >= bucket_bytes:
            buckets.append(cur)
            cur, acc = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def sync_grads(grads, *, axes, mode: str = "bucketed",
               bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               compress: Optional[str] = None, mean: bool = True):
    """Reduce gradients over the (manual) DP axes with a chosen schedule.

    Must be called inside ``shard_map`` manual over ``axes``.
    """
    if isinstance(axes, str):
        axes = (axes,)
    leaves, treedef, shapes, sizes = _flatten_with_sizes(grads)
    nshards = 1
    # psum over multiple axes: pass the tuple directly.
    axis_arg = tuple(axes)

    def reduce_block(x):
        if compress == "int8":
            assert len(axis_arg) == 1, "int8 path: single reduction axis"
            return quantized_psum_mean(x.astype(jnp.float32),
                                       axis_arg[0]) * \
                axis_size(axis_arg[0])  # sync_grads divides later
        if compress == "bf16":
            x = x.astype(jnp.bfloat16)
        x = jax.lax.psum(x, axis_arg)
        return x.astype(jnp.float32)

    if mode == "fused":
        flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                for l in leaves])
        flat = reduce_block(flat)
        out, off = [], 0
        for sh, sz in zip(shapes, sizes):
            out.append(flat[off:off + sz].reshape(sh))
            off += sz
    elif mode in ("bucketed", "sentinel"):
        buckets = _make_buckets(sizes, bucket_bytes)
        reduced: List[Any] = [None] * len(leaves)
        token = None
        for b in buckets:
            chunk = jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in b])
            if mode == "sentinel" and token is not None:
                # Serialise on the previous collective — the artificial
                # dependency the paper's technique removes.
                chunk, _ = jax.lax.optimization_barrier((chunk, token))
            chunk = reduce_block(chunk)
            token = jnp.sum(chunk[:1])
            off = 0
            for i in b:
                reduced[i] = chunk[off:off + sizes[i]].reshape(shapes[i])
                off += sizes[i]
        out = reduced
    else:
        raise ValueError(f"unknown grad sync mode {mode!r}")

    if mean:
        # DP world size is static inside shard_map — no collective needed.
        ws = 1.0
        for a in axis_arg:
            ws *= axis_size(a)
        out = [o / ws for o in out]
    return treedef.unflatten([o.astype(l.dtype)
                              for o, l in zip(out, leaves)])


# ---------------------------------------------------------------------------
# int8 quantized reduction (gradient compression, 4x wire reduction)
# ---------------------------------------------------------------------------
def quantized_psum_mean(x: jax.Array, axis: str) -> jax.Array:
    """Mean-reduce a flat fp32 vector over ``axis`` with int8 on the wire.

    reduce-scatter leg: per-rank symmetric int8 quantisation (scales
    exchanged as scalars), shards moved with an int8 ``all_to_all``,
    dequantised and summed in fp32; all-gather leg: the reduced shard is
    re-quantised and gathered in int8.  Wire bytes ≈ 2·n·1B vs 2·n·4B for
    an fp32 ring all-reduce.  Quantisation error is bounded by
    max|g|/127 per element per leg (no error feedback — acceptable for
    gradients under Adam's normalisation; see EXPERIMENTS.md).
    Must run inside shard_map manual over ``axis``.
    """
    world = axis_size(axis)
    n = x.size
    pad = (-n) % world
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    qs = q.reshape(world, -1)
    # my shard of everyone's quantised gradient (int8 on the wire)
    recv = jax.lax.all_to_all(qs, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    scales = jax.lax.all_gather(scale, axis)            # (world,) fp32
    partial = jnp.sum(recv.astype(jnp.float32)
                      * scales[:, None], axis=0) / world
    # gather the reduced shards back, again in int8
    s2 = jnp.maximum(jnp.max(jnp.abs(partial)), 1e-20) / 127.0
    q2 = jnp.clip(jnp.round(partial / s2), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis)             # (world, n/world) s8
    s2s = jax.lax.all_gather(s2, axis)
    out = (gathered.astype(jnp.float32) * s2s[:, None]).reshape(-1)
    return out[:n] if pad else out


# ---------------------------------------------------------------------------
# Halo exchange schedules (Gauss–Seidel, paper §7.1 at Level B)
# ---------------------------------------------------------------------------
def halo_exchange_rows(x, axis_name: str, *, width: int = 1
                       ) -> Tuple[jax.Array, jax.Array]:
    """Exchange boundary rows with both neighbours along a sharded axis.

    x: the local (rows, cols) block of a 1-D row decomposition.  Returns
    (top_halo, bottom_halo) received from the previous/next shard (zeros at
    the domain edges).  Inside shard_map manual over ``axis_name``.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    down = [(i, (i + 1) % n) for i in range(n)]   # send my last rows down
    up = [(i, (i - 1) % n) for i in range(n)]     # send my first rows up
    from_above = jax.lax.ppermute(x[-width:], axis_name, down)
    from_below = jax.lax.ppermute(x[:width], axis_name, up)
    top = jnp.where(idx == 0, jnp.zeros_like(from_above), from_above)
    bot = jnp.where(idx == n - 1, jnp.zeros_like(from_below), from_below)
    return top, bot


def chained(x, token):
    """Serialise ``x`` on ``token`` (sentinel-style artificial dependency)."""
    if token is None:
        return x, jnp.zeros((), x.dtype)
    x, _ = jax.lax.optimization_barrier((x, token))
    return x, jnp.sum(jnp.ravel(x)[:1]).astype(x.dtype)
