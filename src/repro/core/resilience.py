"""ULFM-style resilience: fault injection, revoke/shrink, elastic resume.

Production traffic means ranks die.  MPI's User-Level Failure Mitigation
(ULFM) proposal defines the minimal recovery vocabulary — a failed
process surfaces as ``MPI_ERR_PROC_FAILED`` on the operations that touch
it, any survivor may ``MPI_Comm_revoke`` the communicator to propagate
the failure to peers that would otherwise block forever, and the
survivors call ``MPI_Comm_shrink`` to agree on a working communicator
without the dead ranks.  This module is that vocabulary for the host
runtime, built on the failure model in :mod:`repro.core.tac`:

* **Detection** — :meth:`repro.core.tac.CommWorld.fail_rank` kills a
  rank: every pending handle naming it completes erroneously with
  :class:`~repro.core.tac.RankFailedError`, *pushed* through the
  handles' completion callbacks — the continuation engine dispatches the
  failure exactly like a success, so neither notification backend gains
  a single new poll.  Failures are observable at task granularity: a
  dead peer is a raising ``handle.result`` / ``taskwait``, never a hang.

* **Propagation** — a collective machine that observes a
  ``RankFailedError`` revokes its communicator
  (:meth:`repro.core.collectives._Machine.advance`), failing every
  peer's pending rounds with
  :class:`~repro.core.tac.CommRevokedError`; posts stay failing until
  recovery completes.

* **Agreement** — survivors call :meth:`repro.core.tac.CommWorld.shrink`
  (same generation-counted collective construction as ``split``); the
  agreement completes once every live rank voted and yields one shared
  :class:`~repro.core.tac.CommGroup` over the survivors, closing the
  revocation window.

* **Rebuild** — compiled plans are cached keyed on the communicator
  *epoch* (:func:`repro.core.program.epoch_of`), which every
  failure/revoke bumps, so persistent schedules
  (:class:`~repro.core.collectives.PersistentCollective`,
  :class:`~repro.core.collectives.HaloExchange`) recompile themselves on
  first post after recovery; :meth:`repro.core.tac.CommGroup.cart` /
  :meth:`~repro.core.tac.CommGroup.graph` re-shape the shrunken group
  with a fresh topology; the benchmarks resume from
  :mod:`repro.checkpoint` at the last completed step.

:class:`FaultInjector` is the test-first half: it kills a rank either
immediately (:meth:`FaultInjector.kill`) or deterministically at the
victim's N-th posted operation (:meth:`FaultInjector.arm`) — mid-send,
mid-collective, or between schedule rounds, depending on N — which is
what the hypothesis property suite in ``tests/test_resilience.py``
sweeps.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

from . import tac
from .tac import CommGroup, CommWorld, CommRevokedError, RankFailedError

__all__ = ["FaultInjector", "RankFailedError", "CommRevokedError",
           "shrink_world", "recover"]


class FaultInjector:
    """Kill ranks of a :class:`~repro.core.tac.CommWorld` on cue.

    Two triggers:

    * :meth:`kill` — immediate: the rank dies *now*, failing its pending
      traffic (a crash between schedule rounds or between iterations).
    * :meth:`arm` — deterministic mid-operation injection: the victim
      dies the instant it posts its ``after_ops``-th send/recv.  Because
      the hook fires *before* the op reaches the matching engine, the
      op that pulls the trigger itself fails — ``after_ops=1`` is death
      on first contact (mid-collective round 0), larger values land the
      failure deeper into a schedule.

    The injector taps ``CommWorld._fault_hook``, which the transport
    invokes synchronously on the posting thread — injection points are
    reproducible for a fixed schedule and driver, which is what lets
    hypothesis shrink failing cases.
    """

    def __init__(self, world: CommWorld) -> None:
        self.world = world
        self.killed: List[int] = []

    def kill(self, rank: int) -> None:
        """Fail ``rank`` immediately (idempotent, like ``fail_rank``)."""
        self.world.fail_rank(rank)
        if rank not in self.killed:
            self.killed.append(rank)

    def arm(self, victim: int, *, after_ops: int = 1,
            kinds: Sequence[str] = ("isend", "irecv")) -> None:
        """Kill ``victim`` when it posts its ``after_ops``-th operation.

        ``kinds`` restricts which posts count (``isend``/``irecv``).  An
        op is attributed to the rank that *posted* it: the source of a
        send, the destination of a recv.  Only one armed trap at a time;
        it disarms itself when it fires.
        """
        if not 0 <= victim < self.world.size:
            raise ValueError(f"victim {victim} out of range for world "
                             f"size {self.world.size}")
        if after_ops < 1:
            raise ValueError(f"after_ops must be >= 1, got {after_ops}")
        state = {"n": 0}

        def hook(kind: str, src: int, dst: int, tag: Any) -> None:
            if kind not in kinds:
                return
            poster = src if kind == "isend" else dst
            if poster != victim:
                return
            state["n"] += 1
            if state["n"] >= after_ops:
                self.world._fault_hook = None
                self.kill(victim)

        self.world._fault_hook = hook

    def disarm(self) -> None:
        """Remove an armed trap that has not fired."""
        self.world._fault_hook = None

    @property
    def armed(self) -> bool:
        return self.world._fault_hook is not None


def shrink_world(world: CommWorld) -> CommGroup:
    """Run the shrink agreement for every survivor and return the group.

    The single-driver convenience (tests, benchmarks): votes for all
    live ranks are cast from the calling thread, so the agreement
    completes synchronously.  All survivors share the returned
    :class:`~repro.core.tac.CommGroup` (dense group-local ranks in
    ascending world order), exactly as if each had called
    ``world.shrink(rank=r)`` itself.
    """
    survivors = world.alive
    if not survivors:
        raise RankFailedError(message="no survivors to shrink onto")
    handles = [world.shrink(rank=r) for r in survivors]
    groups = [h.wait() for h in handles]
    return groups[0]


def recover(world: CommWorld) -> CommGroup:
    """The full ULFM recovery step: revoke, then shrink.

    Call from the survivor that observed a
    :class:`~repro.core.tac.RankFailedError` (e.g. out of ``taskwait``):
    the revoke unsticks any peer still parked on the dead rank's
    traffic, the shrink agreement produces the working communicator.
    Rebuild topologies/persistent objects on the returned group and
    resume from the last checkpoint.
    """
    world.revoke()
    return shrink_world(world)
